"""Sharded execution layer: the adaptive filter under ``jax.shard_map``.

This is where the paper's central design decision (§2.2 — *where does the
adaptive metadata live?*) becomes executable instead of descriptive. One
``ShardedAdaptiveFilter`` runs the single-shard ``AdaptiveFilter.step``
under ``shard_map`` over a data mesh axis; the ``OrderState`` pytree gains
a leading shard axis (one per-executor state per mesh row) and the scope
policy decides what crosses the network:

  PER_SHARD    — the paper's choice: every shard adapts to its own slice,
                 zero collectives. The lowered HLO of the step contains NO
                 all-reduce (pinned by tests/test_sharded_filter.py), the
                 machine-checkable analogue of "no data transferred through
                 the network". Shards diverge under heterogeneous drift —
                 which is the feature, not a bug.
  CENTRALIZED  — the driver-state alternative the paper rejects for
                 contention: batch monitor counters are psum-merged across
                 the axis (``scope.reduce_stats``) before they fold into the
                 epoch accumulators, so every shard accumulates identical
                 global statistics and adopts the identical global order at
                 every epoch boundary. With ``exchange="eager"`` that is one
                 small (2P+G+1 floats) all-reduce per step; with
                 ``exchange="deferred"`` the counters accumulate locally and
                 ONE collective fires per ``calculate_rate`` rows at the
                 epoch boundary (``sharded_exchange`` — a separate jitted
                 call, so the per-step module compiles with no all-reduce;
                 sums are associative, so the adopted perm is identical).
                 ``"deferred-async"`` folds the merged stats in one epoch
                 late, overlapping the collective with filter work.
  PER_BATCH    — the per-task strawman: evidence dies with each batch on
                 each shard (monitor stride and epoch counter persist).

Data contract: ``columns`` is f32[C, S·R] with shard i owning the
contiguous row block [i·R, (i+1)·R) — exactly what ``in_specs=P(None,
"data")`` hands each mesh row, and what ``data.pipeline.ShardedPipeline``
assembles from per-shard ``LogStream``s. Epochs fire per *local* rows
(``calculate_rate`` rows per shard, as per-executor counters do in Spark);
under CENTRALIZED all shards fire in lockstep because every shard sees the
same batch shape.

With ``compact_output`` the per-shard survivors additionally come back as a
padded on-device [S, C, cap] gather + counts (``filter_exec.compact_fixed``
applied inside the shard_map body), so a multi-shard ingestion step moves
exactly one dense buffer per shard to the host — never a boolean index.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.adaptive_filter import (AdaptiveFilter, AdaptiveFilterConfig,
                                        drive_exchange)
from repro.core.ordering import OrderState
from repro.core.plan import validate_combo
from repro.core.predicates import Predicate


def stack_states(state: OrderState, num_shards: int) -> OrderState:
    """Replicate one OrderState onto a leading shard axis: leaf → [S, ...]."""
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (num_shards,) + (1,) * x.ndim), state)


def shard_slice(state: OrderState, shard: int) -> OrderState:
    """Extract shard ``shard``'s OrderState from the stacked pytree."""
    return jax.tree.map(lambda x: x[shard], state)


class ShardedAdaptiveFilter:
    """Data-parallel adaptive CNF filter: one OrderState per mesh shard.

    ``mesh`` defaults to a 1-axis mesh over every visible device. All three
    scopes of ``AdaptiveFilterConfig.scope`` are honoured as described in
    the module docstring; the backend must be a traceable engine (jnp /
    pallas) — host engines cannot run under shard_map.
    """

    def __init__(self, predicates: Sequence[Predicate],
                 config: AdaptiveFilterConfig | None = None,
                 *, mesh: jax.sharding.Mesh | None = None,
                 axis_name: str = "data"):
        cfg = config or AdaptiveFilterConfig()
        self.inner = AdaptiveFilter(predicates, cfg, axis_names=(axis_name,))
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), (axis_name,))
        if axis_name not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis_name!r}: "
                             f"{mesh.axis_names}")
        self.config = cfg
        self.mesh = mesh
        self.axis_name = axis_name
        self.num_shards = int(mesh.shape[axis_name])
        # the sharded-execution rules live with every other cross-field
        # rule in core.plan.validate_combo (single source of truth); the
        # sharded filter is the shards>=1-under-shard_map case
        validate_combo(scope=cfg.scope, cost_mode=cfg.cost_mode,
                       backend=cfg.backend,
                       compact_output=cfg.compact_output,
                       compact_capacity=cfg.compact_capacity,
                       compact_slack=cfg.compact_slack,
                       exchange=cfg.exchange,
                       shards=max(self.num_shards, 2),
                       skip_tier=cfg.skip_tier)
        self._jit_step = None
        self._jit_step_compact = None
        self._jit_exchange = None
        self._jit_exchange_with = None
        self._pending_stats = None   # deferred-async: last boundary's merge

    # ---------------------------------------------------------------- state
    def init_state(self) -> OrderState:
        """Stacked per-shard state: every leaf leads with the shard axis."""
        return stack_states(self.inner.init_state(), self.num_shards)

    # ----------------------------------------------------------------- step
    def _specs(self, n_out: int):
        a = self.axis_name
        return ((P(a), P(None, a)), (P(a),) * n_out)

    def sharded_step(self, state: OrderState, columns: jnp.ndarray):
        """One micro-batch on every shard: columns f32[C, S·R], row-sharded.

        Returns (new_state [S, ...], mask bool[S·R], metrics with leading
        shard axis on every field). Trace it with ``jax.jit`` (or use
        ``jit_step``) — shard_map placement only happens under jit.
        """

        def local(st, cols):
            st = shard_slice(st, 0)       # [1, ...] per-shard block → [...]
            new_st, mask, metrics = self.inner.step(st, cols)
            return (jax.tree.map(lambda x: x[None], new_st), mask,
                    jax.tree.map(lambda x: x[None], metrics))

        in_specs, out_specs = self._specs(3)
        return shard_map(local, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs)(state, columns)

    def sharded_step_compact(self, state: OrderState, columns: jnp.ndarray,
                             *, capacity: int | None = None):
        """``sharded_step`` + per-shard single-pass device compaction.

        Returns (new_state, packed f32[S, C, cap], n_kept i32[S],
        mask bool[S·R], metrics). ``packed[i, :, :n_kept[i]]`` equals shard
        i's host boolean-mask survivors bit-exactly. ``capacity`` is the
        per-shard width (static under jit; None → local batch width).
        """

        def local(st, cols):
            st = shard_slice(st, 0)
            new_st, packed, n_kept, mask, metrics = self.inner._step_compact(
                st, cols, capacity=capacity)
            return (jax.tree.map(lambda x: x[None], new_st), packed[None],
                    n_kept[None], mask, jax.tree.map(lambda x: x[None],
                                                     metrics))

        in_specs, out_specs = self._specs(5)
        return shard_map(local, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs)(state, columns)

    @property
    def jit_step(self):
        if self._jit_step is None:
            self._jit_step = jax.jit(self.sharded_step)
        return self._jit_step

    @property
    def _jit_compact(self):
        if self._jit_step_compact is None:
            self._jit_step_compact = jax.jit(
                self.sharded_step_compact, static_argnames=("capacity",))
        return self._jit_step_compact

    # ------------------------------------------------------ deferred epochs
    def _sharded_exchange(self, state: OrderState, use_stats=None):
        """Shard_mapped ``AdaptiveFilter.exchange_update``: the deferred
        mode's single per-epoch collective (psum inside the shard_map body),
        returning (new_state [S,...], merged_stats [S,...] — every shard row
        holds the identical global sums)."""

        def local(st, *maybe):
            st = shard_slice(st, 0)
            us = shard_slice(maybe[0], 0) if maybe else None
            new_st, merged = self.inner.exchange_update(st, us)
            return (jax.tree.map(lambda x: x[None], new_st),
                    jax.tree.map(lambda x: x[None], merged))

        a = self.axis_name
        n_in = 1 if use_stats is None else 2
        in_specs = (P(a),) * n_in
        out_specs = (P(a), P(a))
        args = (state,) if use_stats is None else (state, use_stats)
        return shard_map(local, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs)(*args)

    @property
    def jit_exchange(self):
        if self._jit_exchange is None:
            self._jit_exchange = jax.jit(lambda s: self._sharded_exchange(s))
        return self._jit_exchange

    @property
    def jit_exchange_with(self):
        if self._jit_exchange_with is None:
            self._jit_exchange_with = jax.jit(
                lambda s, st: self._sharded_exchange(s, st))
        return self._jit_exchange_with

    def exchange_due(self, state: OrderState) -> bool:
        return self.inner.exchange_due(state)

    def maybe_exchange(self, state: OrderState) -> OrderState:
        """Drive the deferred epoch boundary if due (host helper; the shared
        driver with the shard_mapped exchange callables)."""
        return drive_exchange(self, state)

    # -------------------------------------------------- capacity auto-tune
    def resolve_capacity(self, n_rows_local: int) -> int:
        return self.inner.resolve_capacity(n_rows_local)

    def observe_for_capacity(self, evidence_state, new_state,
                             n_rows_local: int) -> None:
        self.inner.observe_for_capacity(evidence_state, new_state,
                                        n_rows_local)

    # ------------------------------------------------------------- analysis
    def compiled_text(self, state: OrderState, columns: jnp.ndarray) -> str:
        """Compiled HLO of one sharded step — what the collective-freedom
        assertions grep (PER_SHARD ⇒ no all-reduce; deferred CENTRALIZED ⇒
        no all-reduce in the per-STEP module either)."""
        return jax.jit(self.sharded_step).lower(
            state, columns).compile().as_text()

    def compiled_exchange_text(self, state: OrderState) -> str:
        """Compiled HLO of the boundary exchange — deferred CENTRALIZED must
        show its one all-reduce HERE and only here."""
        return self.jit_exchange.lower(state).compile().as_text()
