"""FilterSession: a compiled FilterPlan with ONE step entry point.

``build_session(plan, mesh=None)`` compiles a declarative ``FilterPlan``
(core/plan.py) into a ``FilterSession`` that owns the jitted step /
exchange / retune callables and exposes exactly one

    state, result = session.step(state, batch)

for every engine × scope × compaction × exchange × tokenize combination —
the plan-then-compile shape of adaptive stream engines (Strider, arXiv
1705.05688), with the adaptivity itself a drop-in primitive (Cuttlefish,
arXiv 1802.09180). All of the driving logic — capacity resolution, the
skip-tier triage/tuner, deferred epoch exchange, auto-capacity retune,
overflow accounting, JSON metrics — lives here exactly once.

``StepResult`` is the uniform step ABI replacing the four divergent legacy
return shapes (mask-only, packed+count, sharded variants):

    mask      bool[R] | bool[S·R]   rows passing the chain (always)
    packed    f32[C, cap] | f32[S, C, cap] | None   compacted survivors
    n_kept    i32[] | i32[S] | None  survivors kept per shard (compaction)
    tokens    i32[N] | None          packed device token stream (tokenize)
    n_tokens  i32[] | None           live prefix length of ``tokens``
    metrics   StepMetrics            per-group monitor stats, ``n_dropped``
                                     (leading shard axis when sharded)

Checkpoints are versioned (schema v2: plan fingerprint + shard layout +
state arrays) and **elastic**: ``restore_state`` accepts a blob written on
S shards into a session over S′ shards. Epoch accumulators are sums, so
the S→S′ split/merge is exact (bit-exact for power-of-two rescale); ranks
and permutations are re-derived from the merged statistics when the source
shards disagree. Unversioned v1 blobs (the raw ``fstate_to_arrays`` dicts
every pre-session checkpoint holds) still load.
"""

from __future__ import annotations

import logging
import warnings
import zlib
from typing import Any, NamedTuple

import numpy as np

from repro.core import stats as stats_lib
from repro.core.adaptive_filter import (AdaptiveFilter, AdaptiveFilterConfig,
                                        StepMetrics)
from repro.core.ordering import OrderState
from repro.core.plan import FilterPlan, TokenizeSpec
from repro.core.sharded import ShardedAdaptiveFilter

log = logging.getLogger(__name__)

#: checkpoint schema written by ``FilterSession.save_state``
CKPT_VERSION = 2
CKPT_FORMAT = "filter-session"


# ================================================================== StepResult
class StepResult(NamedTuple):
    """Uniform per-step output of ``FilterSession.step`` (module docstring).

    Device arrays stay on device until a host accessor (``mask_np``,
    ``survivors``, ``host_tokens``, ``metrics_dict``) is called.
    """

    mask: Any
    packed: Any | None
    n_kept: Any | None
    tokens: Any | None
    n_tokens: Any | None
    metrics: StepMetrics
    capacity: int | None = None   # compaction width used (None = no limit)
    # per-result once-cell for the overflow warning (fresh list per step;
    # None disables — e.g. hand-built results)
    warn_cell: list | None = None
    # True when the guarded runtime refused this batch (poisoned data):
    # the mask is all-False, the metrics are zeros, and the state did NOT
    # advance — downstream consumers skip the batch instead of folding
    # garbage into the stream (see repro.runtime.guard.GuardedSession)
    quarantined: bool = False
    # host wall-clock seconds spent DRIVING this step (dispatch + the
    # sanctioned triage/boundary syncs; no extra device round-trip). The
    # admission server's per-step gate cost lives here per the ROADMAP
    # seam rule — on StepResult, not a side API.
    gate_s: float | None = None

    # ------------------------------------------------------- host accessors
    @property
    def mask_np(self) -> np.ndarray:
        return np.asarray(self.mask)

    @property
    def n_pass(self) -> int:
        """Survivors actually KEPT (what downstream stages see): the packed
        count under compaction (saturation-aware), the mask popcount
        otherwise."""
        self._maybe_warn_overflow()
        if self.n_kept is not None:
            return int(np.asarray(self.n_kept).sum())
        return int(self.mask_np.sum())

    def _maybe_warn_overflow(self) -> None:
        """Warn ONCE per step result when capacity overflow dropped rows.

        Hooked into every accessor that observes the survivors or the
        metrics (the step itself stays sync-free), so any consumer that
        looks at its output learns about the loss exactly once."""
        if self.capacity is None or self.warn_cell is None or self.warn_cell:
            return
        self.warn_cell.append(True)
        nd = int(np.asarray(self.metrics.n_dropped).sum())
        if nd:
            log.warning(
                "compaction overflow: %d survivors dropped this step "
                "(capacity %s); raise compact_capacity or use 'auto'",
                nd, self.capacity)

    @property
    def n_dropped(self) -> int:
        """Survivors lost to capacity overflow, summed over shards."""
        self._maybe_warn_overflow()
        return int(np.asarray(self.metrics.n_dropped).sum())

    @property
    def n_dropped_per_shard(self) -> list[int]:
        self._maybe_warn_overflow()
        nd = np.asarray(self.metrics.n_dropped)
        return [int(x) for x in np.atleast_1d(nd)]

    # skip-tier tile counters (all zero when the tier is off for this step)
    @property
    def n_tiles_skipped_pass(self) -> int:
        """128-row tiles bulk-kept by the zone-map proof (no row-level work)."""
        return int(np.sum(np.asarray(self.metrics.n_tiles_pass)))

    @property
    def n_tiles_skipped_fail(self) -> int:
        """128-row tiles dropped by the zone-map proof (no row-level work)."""
        return int(np.sum(np.asarray(self.metrics.n_tiles_fail)))

    @property
    def n_tiles_ambiguous(self) -> int:
        """128-row tiles that reached the row-level chain."""
        return int(np.sum(np.asarray(self.metrics.n_tiles_ambiguous)))

    def survivors(self, columns: np.ndarray | None = None) -> np.ndarray:
        """Surviving rows as a host f32[C, n_pass] array (shard-major).

        Under compaction (incl. tokenize plans) this slices the packed
        device buffer(s); otherwise it boolean-indexes ``columns``
        (required then). Tokenize-plan pipelines prefer ``host_tokens`` —
        only the dense token stream crosses to the host there."""
        self._maybe_warn_overflow()
        if self.packed is not None:
            packed = np.asarray(self.packed)
            counts = np.atleast_1d(np.asarray(self.n_kept))
            if packed.ndim == 2:                       # [C, cap]
                return packed[:, :int(counts[0])]
            return np.concatenate(                     # [S, C, cap]
                [packed[s][:, :int(counts[s])]
                 for s in range(packed.shape[0])], axis=1)
        if columns is None:
            raise ValueError("no compaction in this session: pass the "
                             "original columns to slice by mask")
        return np.asarray(columns)[:, self.mask_np]

    def host_tokens(self) -> np.ndarray:
        """Dense packed token stream (device tokenize sessions only).

        Sharded sessions tokenize+pack per shard (no cross-shard
        collectives); the shard-major concatenation here is bit-identical
        to the single-stream pack."""
        if self.tokens is None:
            raise ValueError("session has no tokenize stage "
                            "(FilterPlan.tokenize is None)")
        toks = np.asarray(self.tokens)
        if toks.ndim == 2:                    # [S, cap·T] per-shard packs
            counts = np.asarray(self.n_tokens)
            return np.concatenate([toks[s, :int(counts[s])]
                                   for s in range(toks.shape[0])])
        return toks[:int(self.n_tokens)]

    def metrics_dict(self) -> dict:
        """THE JSON metrics encoding (pipelines / serve / train all agree).

        ``n_pass`` is the mask popcount (monitor semantics, matching the
        host streaming path); ``n_dropped`` is summed over shards with the
        per-shard breakdown alongside when the step was sharded."""
        self._maybe_warn_overflow()
        nd = np.asarray(self.metrics.n_dropped)
        out = {
            "work_units": float(np.sum(np.asarray(self.metrics.work_units))),
            "n_pass": int(np.sum(np.asarray(self.metrics.n_pass))),
            "perm": np.asarray(self.metrics.perm).tolist(),
            "epoch": int(np.max(np.asarray(self.metrics.epoch))),
            "n_dropped": int(nd.sum()),
            "n_tiles_skipped_pass": self.n_tiles_skipped_pass,
            "n_tiles_skipped_fail": self.n_tiles_skipped_fail,
            "n_tiles_ambiguous": self.n_tiles_ambiguous,
            "quarantined": bool(self.quarantined),
        }
        if nd.ndim >= 1:
            out["n_dropped_per_shard"] = [int(x) for x in nd]
        return out


# ============================================================ state validation
def state_invariants(state: OrderState, *, n_predicates: int, n_groups: int,
                     collect_rate: int, calculate_rate: int,
                     rows_bounded: bool = True, xp=None):
    """ONE fused boolean over every structural invariant of an OrderState.

    Works on single ``[P]``-shaped states and stacked ``[S, P]`` sharded
    states alike (every check broadcasts over a leading shard axis):

      * all stat accumulators and ``adj_rank`` finite; accumulators and
        counters non-negative (a NaN/Inf here poisons every future rank);
      * ``num_cut``/``group_cut`` never exceed ``n_monitored`` (a count
        above the monitored total cannot arise from any real batch);
      * ``perm`` is a permutation of [0, P) and ``group_perm`` of [0, G)
        (a clamped out-of-bounds gather silently evaluates the wrong
        predicate — the worst kind of corruption: no crash, wrong masks);
      * ``rows_into_epoch`` >= 0 and — when the session owns its epoch
        boundaries (``rows_bounded``) — below ``calculate_rate``;
        ``sample_phase`` in [0, collect_rate); ``epoch`` >= 0.

    Returns a scalar bool ARRAY (no host sync): callers jit this and
    choose where to pay the one transfer (``FilterSession.validate_state``).
    """
    if xp is None:
        import jax.numpy as xp

    st = state.stats

    def clean(a):      # finite AND non-negative
        return xp.all(xp.isfinite(a)) & xp.all(a >= 0)

    ok = clean(st.num_cut) & clean(st.cost_acc) & clean(st.n_monitored)
    ok &= xp.all(xp.isfinite(state.adj_rank))
    n_mon = st.n_monitored[..., None]        # broadcast over [.., P]
    ok &= xp.all(st.num_cut <= n_mon)
    if st.group_cut is not None:
        ok &= clean(st.group_cut) & xp.all(st.group_cut <= n_mon)
    ok &= xp.all(xp.sort(state.perm.astype(xp.int32), axis=-1)
                 == xp.arange(n_predicates, dtype=xp.int32))
    if state.group_perm is not None:
        ok &= xp.all(xp.sort(state.group_perm.astype(xp.int32), axis=-1)
                     == xp.arange(n_groups, dtype=xp.int32))
    ok &= xp.all(state.rows_into_epoch >= 0)
    if rows_bounded:
        ok &= xp.all(state.rows_into_epoch < calculate_rate)
    ok &= xp.all((state.sample_phase >= 0)
                 & (state.sample_phase < collect_rate))
    ok &= xp.all(state.epoch >= 0)
    return ok


# ======================================================== checkpoint integrity
def arrays_crc32(arrays: dict) -> int:
    """CRC32 over a state-arrays dict (key order canonicalized).

    Folds each array's name, dtype, shape, and raw bytes into one running
    checksum. Computed on the HOST numpy views, after any serialization
    round trip — the TrainDriver's JSON ``tolist``/``asarray(dtype)``
    round trip is value- and dtype-exact, so the checksum survives it.
    """
    crc = 0
    for k in sorted(arrays):
        v = np.ascontiguousarray(np.asarray(arrays[k]))
        crc = zlib.crc32(f"{k}|{v.dtype.str}|{v.shape}".encode(), crc)
        crc = zlib.crc32(v.tobytes(), crc)
    return crc


# ================================================================== session
class FilterSession:
    """A compiled ``FilterPlan``; see the module docstring.

    Build with ``build_session`` (or ``FilterSession.from_filter`` to adopt
    a legacy filter instance). The underlying ``AdaptiveFilter`` /
    ``ShardedAdaptiveFilter`` is the functional math core; every host-side
    driving decision goes through here.
    """

    def __init__(self, plan: FilterPlan, mesh=None, *, _filter=None):
        self.plan = plan
        if _filter is not None:
            self.filter = _filter
        else:
            cfg = AdaptiveFilterConfig(
                ordering=plan.ordering, scope=plan.scope,
                cost_mode=plan.cost_mode, backend=plan.engine,
                adaptive=plan.adaptive, compact_output=plan.compact,
                compact_capacity=plan.capacity, compact_slack=plan.slack,
                exchange=plan.exchange, skip_tier=plan.skip_tier)
            # an explicit mesh forces the shard_mapped execution layer even
            # for shards=1 (a live 1-device mesh is how the sharded path is
            # exercised without multiple devices — benches/tests rely on it)
            if plan.shards > 1 or mesh is not None:
                if plan.skip_tier != "off":
                    raise ValueError(
                        "skip_tier needs the unsharded execution layer: "
                        "a mesh forces shard_map, whose static shapes the "
                        "per-step ambiguous-tile sync cannot drive")
                import jax
                if mesh is None:
                    mesh = jax.make_mesh((plan.shards,), (plan.axis_name,))
                elif plan.axis_name in mesh.axis_names \
                        and int(mesh.shape[plan.axis_name]) != plan.shards:
                    raise ValueError(
                        f"plan.shards={plan.shards} but mesh axis "
                        f"{plan.axis_name!r} has size "
                        f"{mesh.shape[plan.axis_name]}")
                self.filter = ShardedAdaptiveFilter(
                    list(plan.predicates), cfg, mesh=mesh,
                    axis_name=plan.axis_name)
            else:
                self.filter = AdaptiveFilter(list(plan.predicates), cfg)
        self._jit_tokenize = None   # sharded per-shard tokenize (lazy)
        # skip_tier="auto": the online us_per_row tuner (lazy; host-owned)
        self._skip_tuner = None
        # guarded-runtime integrity probe (lazy jit of state_invariants)
        self._jit_validate = None
        # host-side mirror of rows_into_epoch for the deferred-exchange
        # boundary check: rows per shard are deterministic (every step adds
        # the static local batch width), so the due-test needs NO
        # device→host sync in the hot loop; re-anchored by init_state /
        # restore_state, reduced modulo calculate_rate at each boundary
        self._rows_local = 0

    # -------------------------------------------------------------- shape
    @property
    def sharded(self) -> bool:
        return isinstance(self.filter, ShardedAdaptiveFilter)

    @property
    def num_shards(self) -> int:
        return self.filter.num_shards if self.sharded else 1

    @property
    def _core(self) -> AdaptiveFilter:
        """The unsharded math core (engine, specs, ordering config)."""
        return self.filter.inner if self.sharded else self.filter

    @classmethod
    def from_filter(cls, filt, tokenize: TokenizeSpec | None = None
                    ) -> "FilterSession":
        """Adopt a legacy filter instance under a synthesized plan."""
        core = filt.inner if isinstance(filt, ShardedAdaptiveFilter) \
            else filt
        cfg = core.config
        plan = FilterPlan(
            predicates=tuple(core.predicates), ordering=cfg.ordering,
            engine=cfg.backend, scope=cfg.scope,
            shards=filt.num_shards
            if isinstance(filt, ShardedAdaptiveFilter) else 1,
            axis_name=filt.axis_name
            if isinstance(filt, ShardedAdaptiveFilter) else "data",
            adaptive=cfg.adaptive, cost_mode=cfg.cost_mode,
            compact=cfg.compact_output, capacity=cfg.compact_capacity,
            slack=cfg.compact_slack, exchange=cfg.exchange,
            tokenize=tokenize, skip_tier=cfg.skip_tier)
        return cls(plan, _filter=filt)

    def with_tokenize(self, tokenize: TokenizeSpec) -> "FilterSession":
        """Same compiled filter, plus the device tokenize stage."""
        import dataclasses
        plan = dataclasses.replace(self.plan, tokenize=tokenize)
        return FilterSession(plan, _filter=self.filter)

    # -------------------------------------------------------------- state
    def init_state(self) -> OrderState:
        self._rows_local = 0
        return self.filter.init_state()

    # ------------------------------------------------------------ skip tier
    def _skip_step_mode(self) -> str:
        """The skip-tier arm for the CURRENT step ("off" disables it).

        Fixed tiers pass through; "auto" asks the online tuner
        (``skip_tier.SkipTierTuner``) which arm to run — it alternates
        during warmup, then follows the faster measured us_per_row, and
        structurally forces "off" when the observed ambiguous-tile
        fraction says the tier cannot pay (shuffled layouts).
        """
        from repro.core import skip_tier as skip_tier_lib

        tier = self.plan.skip_tier
        if tier in ("off", None) or self.sharded:
            return "off"
        if tier != "auto":
            return tier
        if self._skip_tuner is None:
            self._skip_tuner = skip_tier_lib.SkipTierTuner(
                self._core.skip_on_mode())
        return self._skip_tuner.choose()

    @property
    def skip_tier_active(self) -> str:
        """The arm a step would run right now (bench/telemetry hook)."""
        if self.plan.skip_tier != "auto":
            return "off" if self.sharded else self.plan.skip_tier
        return self._skip_tuner.active_mode if self._skip_tuner else "auto"

    # ---------------------------------------------------------------- step
    def step(self, state: OrderState, batch) -> tuple[OrderState, StepResult]:
        """One micro-batch through the whole compiled plan.

        ``batch``: f32[C, R] (host or device; [C, S·R] when sharded, shard i
        owning the contiguous block i). Drives — in order — the skip-tier
        triage (when the plan enables it), the jitted
        filter(+compact+tokenize) step, the deferred epoch exchange if one
        is due, and the auto-capacity retune; returns the post-exchange
        state and a uniform ``StepResult``.
        """
        import time

        import jax.numpy as jnp

        t_gate = time.perf_counter()
        cols = jnp.asarray(batch, jnp.float32)
        n_local = int(cols.shape[1]) // self.num_shards
        f = self.filter
        prev = state
        packed = n_kept = tokens = n_tokens = None
        cap = None
        skip_mode = self._skip_step_mode()
        auto = self.plan.skip_tier == "auto" and not self.sharded
        if auto:
            t0 = time.perf_counter()
        info = None
        if skip_mode != "off":
            # the tier's one host sync: the triage result sizes the jnp
            # gather width (quantized — bounded jit cache); the pallas
            # engine predicates in-kernel and skips the sync entirely
            info = f._jit_triage(cols, bloom=skip_mode == "zonemap+bloom")
            amb_cap = f.skip_amb_cap(info, n_local)
        if self.plan.compact:
            cap = f.resolve_capacity(n_local)
            if info is not None:
                state, packed, n_kept, mask, metrics = f._jit_skip_compact(
                    state, cols, info.pass_tiles, info.fail_tiles,
                    amb_cap=amb_cap, capacity=cap)
            else:
                state, packed, n_kept, mask, metrics = f._jit_compact(
                    state, cols, capacity=cap)
            if self.plan.tokenize is not None:
                if self.sharded:
                    tokens, n_tokens = self._tokenize_sharded(packed, n_kept)
                else:
                    from repro.data import tokenizer
                    ts = self.plan.tokenize
                    tokens, n_tokens = tokenizer.tokens_from_padded(
                        packed, n_kept, ts.vocab_size, ts.tokens_per_row)
        elif info is not None:
            state, mask, metrics = f._jit_skip(
                state, cols, info.pass_tiles, info.fail_tiles,
                amb_cap=amb_cap)
        else:
            state, mask, metrics = f.jit_step(state, cols)
        if auto:
            self._observe_skip_arm(skip_mode, mask, metrics, t0,
                                   int(cols.shape[1]))
        if self._core.exchange_deferred:
            # host-counted boundary: no per-step device sync (the jitted
            # exchange itself checks/derives everything it needs). One
            # session drives one state stream; if the counter has drifted
            # anyway (states advanced outside this session), the
            # authoritative device check below self-heals it at the cost
            # of one sync per presumed boundary.
            self._rows_local += n_local
            if self._rows_local >= self.plan.ordering.calculate_rate:
                if f.exchange_due(state):
                    state = f.maybe_exchange(state)
                    self._rows_local %= self.plan.ordering.calculate_rate
                else:
                    self._rows_local = self._sync_rows_into_epoch(state)
        f.observe_for_capacity(prev, state, n_local)
        # a deferred exchange may have just fired the epoch boundary — the
        # metrics must report the post-exchange epoch (one uniform answer)
        metrics = metrics._replace(epoch=state.epoch)
        # no host sync here — overflow accounting surfaces through the
        # StepResult accessors (which warn once per result), keeping the
        # hot step free of forced device round-trips
        return state, StepResult(mask, packed, n_kept, tokens, n_tokens,
                                 metrics, cap, warn_cell=[],
                                 gate_s=time.perf_counter() - t_gate)

    # ------------------------------------------------- sanctioned host syncs
    # These two helpers are the session driver's ONLY deliberate
    # device→host syncs outside the skip-tier/boundary counters owned by
    # AdaptiveFilter; each is allowlisted by qualname (with its reason) in
    # ``repro.analysis.hotpath_lint.ALLOWLIST`` — a new sync anywhere else
    # in the reachable step graph fails the hot-path lint.
    def _observe_skip_arm(self, skip_mode: str, mask, metrics,
                          t0: float, n_rows: int) -> None:
        """Feed the skip_tier="auto" tuner one honest per-arm wall clock.

        The tuner compares ARMS, so both pay the same block_until_ready
        sync; the ambiguous-tile fraction rides along for the structural
        shutoff on adversarial (shuffled) layouts.
        """
        import time

        import jax

        jax.block_until_ready(mask)
        dt = time.perf_counter() - t0
        ambig_frac = None
        if skip_mode != "off":
            n_amb = float(np.sum(np.asarray(metrics.n_tiles_ambiguous)))
            n_tot = n_amb \
                + float(np.sum(np.asarray(metrics.n_tiles_pass))) \
                + float(np.sum(np.asarray(metrics.n_tiles_fail)))
            ambig_frac = n_amb / max(n_tot, 1.0)
        self._skip_tuner.observe(skip_mode, dt * 1e6 / max(n_rows, 1),
                                 ambig_frac)

    def _sync_rows_into_epoch(self, state: OrderState) -> int:
        """Re-anchor the host boundary counter from the device state — one
        sync per presumed boundary, only when the counter drifted (states
        advanced outside this session)."""
        return int(np.max(np.asarray(state.rows_into_epoch)))

    def _tokenize_sharded(self, packed, counts):
        """Per-shard device tokenize+pack under shard_map.

        The hash is elementwise and the pack cumsum is per-shard, so the
        whole stage is collective-free on the mesh (a GLOBAL pack over the
        shard-sharded buffer would drag a cross-device cumsum through the
        SPMD partitioner — pathological; per-shard packs concatenated
        shard-major by ``StepResult.host_tokens`` are bit-identical).
        Returns (tokens i32[S, cap·T] packed-front, n_valid i32[S]).
        """
        if self._jit_tokenize is None:
            import jax
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map
            from repro.data import tokenizer

            ts = self.plan.tokenize
            mesh, a = self.filter.mesh, self.filter.axis_name
            tok = tokenizer._jit_tokens_from_padded()

            def local(p, c):          # p f32[1, C, cap], c i32[1]
                t, n = tok(p, c, vocab_size=ts.vocab_size,
                           tokens_per_row=ts.tokens_per_row)
                return t[None], n[None]

            self._jit_tokenize = jax.jit(shard_map(
                local, mesh=mesh, in_specs=(P(a), P(a)),
                out_specs=(P(a), P(a))))
        return self._jit_tokenize(packed, counts)

    # ----------------------------------------------------------- validation
    def validate_state(self, state: OrderState) -> bool:
        """On-device structural integrity check of an ``OrderState``.

        Every invariant — finite, non-negative accumulators; counts within
        ``n_monitored``; ``perm``/``group_perm`` true permutations;
        epoch/rows/phase counters in range — is fused into ONE jitted
        boolean, so the whole probe costs a single device→host sync. The
        guarded runtime (``repro.runtime.guard``) calls this once per
        validation boundary, never per step; the qualname is allowlisted in
        ``hotpath_lint`` with that contract.
        """
        if self._jit_validate is None:
            import jax

            self._jit_validate = jax.jit(self._invariants_fn())
        return bool(np.asarray(self._jit_validate(state)))

    def _invariants_fn(self):
        """The fused invariant check ``validate_state`` jits (also traced
        un-jitted by ``make_jaxprs`` for the IR lint)."""
        cfg = self.plan.ordering
        n_p = len(self.plan.predicates)
        n_g = self._core.specs.n_groups
        # deferred exchange legitimately lets rows_into_epoch overshoot
        # calculate_rate until the driver fires the boundary
        bounded = not self._core.exchange_deferred

        def check(s):
            return state_invariants(
                s, n_predicates=n_p, n_groups=n_g,
                collect_rate=cfg.collect_rate,
                calculate_rate=cfg.calculate_rate, rows_bounded=bounded)

        return check

    def make_jaxprs(self, batch) -> dict:
        """Traced (uncompiled) ``ClosedJaxpr`` per jitted callable this
        session drives — the IR surface ``repro.analysis.jaxpr_lint``
        audits.

        Keys: ``step``, ``exchange``, ``validate_state``, plus
        ``compact`` / ``tokenize`` / ``skip_step`` / ``skip_compact``
        when the plan enables them. ``batch``: f32[C, R] shaped like a
        live step's input ([C, S·R] when sharded). Tracing only — nothing
        compiles or executes except the skip tier's triage, which sizes
        the static gather width exactly the way a live step would.
        """
        import jax
        import jax.numpy as jnp

        cols = jnp.asarray(batch, jnp.float32)
        n_local = int(cols.shape[1]) // self.num_shards
        f = self.filter
        state = self.init_state()
        out: dict = {}
        if self.sharded:
            out["step"] = jax.make_jaxpr(f.sharded_step)(state, cols)
            out["exchange"] = jax.make_jaxpr(
                lambda s: f._sharded_exchange(s))(state)
        else:
            out["step"] = jax.make_jaxpr(f.step)(state, cols)
            out["exchange"] = jax.make_jaxpr(
                lambda s: f.exchange_update(s))(state)
        if self.plan.compact:
            cap = f.resolve_capacity(n_local)
            if self.sharded:
                out["compact"] = jax.make_jaxpr(
                    lambda s, c: f.sharded_step_compact(
                        s, c, capacity=cap))(state, cols)
            else:
                out["compact"] = jax.make_jaxpr(
                    lambda s, c: f._step_compact(
                        s, c, capacity=cap))(state, cols)
            if self.plan.tokenize is not None:
                from repro.data import tokenizer
                ts = self.plan.tokenize
                # per-shard local shapes: the sharded path shard_maps the
                # same per-shard tokenize body, so this IS its local IR
                packed = jax.ShapeDtypeStruct((int(cols.shape[0]), cap),
                                              jnp.float32)
                cnt = jax.ShapeDtypeStruct((), jnp.int32)
                out["tokenize"] = jax.make_jaxpr(
                    lambda p, c: tokenizer.tokens_from_padded(
                        p, c, ts.vocab_size, ts.tokens_per_row))(packed,
                                                                 cnt)
        skip_mode = self._skip_step_mode()
        if skip_mode != "off":
            info = f._jit_triage(cols, bloom=skip_mode == "zonemap+bloom")
            amb_cap = f.skip_amb_cap(info, n_local)
            if self.plan.compact:
                cap = f.resolve_capacity(n_local)
                out["skip_compact"] = jax.make_jaxpr(
                    lambda s, c, p, fl: f._step_skip_compact(
                        s, c, p, fl, amb_cap=amb_cap, capacity=cap))(
                    state, cols, info.pass_tiles, info.fail_tiles)
            else:
                out["skip_step"] = jax.make_jaxpr(
                    lambda s, c, p, fl: f._step_skip(
                        s, c, p, fl, amb_cap=amb_cap))(
                    state, cols, info.pass_tiles, info.fail_tiles)
        out["validate_state"] = jax.make_jaxpr(self._invariants_fn())(state)
        return out

    # ------------------------------------------------------------ analysis
    def compiled_step_text(self, state: OrderState, batch) -> str:
        """Compiled HLO of one step (collective-freedom assertions)."""
        if self.sharded:
            return self.filter.compiled_text(state, batch)
        import jax
        return jax.jit(self.filter.step).lower(
            state, batch).compile().as_text()

    def compiled_exchange_text(self, state: OrderState) -> str:
        return self.filter.compiled_exchange_text(state) if self.sharded \
            else self.filter.jit_exchange.lower(state).compile().as_text()

    # =========================================================== checkpoints
    @property
    def _stats_replicated(self) -> bool:
        """Accumulator layout of THIS session's states.

        Under eager CENTRALIZED every batch's monitor counters are
        psum-merged BEFORE they fold in, so each shard's epoch accumulator
        already holds the identical GLOBAL totals (replicated). Every
        other combination accumulates locally (partitioned) and merges —
        if ever — at the boundary. Elastic restore must convert between
        the two or it over/under-counts carried evidence by S×.
        """
        return (self.sharded and self.plan.scope == "centralized"
                and self.plan.exchange == "eager" and self.plan.adaptive)

    def save_state(self, state: OrderState) -> dict:
        """Versioned checkpoint blob (schema v2).

        Embeds the plan fingerprint (semantic identity of the adaptive
        state), the shard layout, and the accumulator layout
        (replicated vs partitioned — see ``_stats_replicated``), so a
        restore can verify compatibility and reshard elastically."""
        from repro.data.pipeline import fstate_to_arrays
        arrays = fstate_to_arrays(state)
        return {
            "format": CKPT_FORMAT,
            "version": CKPT_VERSION,
            "fingerprint": self.plan.fingerprint(),
            "shards": self.num_shards if self.sharded else 0,
            "stats_layout": "replicated" if self._stats_replicated
            else "partitioned",
            "crc32": arrays_crc32(arrays),
            "arrays": arrays,
        }

    def restore_state(self, blob: dict) -> OrderState:
        """Load a v1 (raw arrays) or v2 (versioned) blob, resharding S→S′.

        * fingerprint mismatch (v2 only) → ValueError with both prints;
        * same shard + accumulator layout → verbatim (bit-identical);
        * otherwise → elastic reshard: epoch accumulators are merged to
          one logical executor (sum over shards when the source
          accumulated locally; first row when the source was
          replicated-global, i.e. eager CENTRALIZED) and re-laid-out for
          this session (split evenly for partitioned targets — the next
          boundary merge recovers the source totals exactly, bit-exact
          for power-of-two rescales; broadcast whole for replicated
          targets); ranks/perms are re-derived from the merged stats when
          the source shards disagree.
        """
        from repro.data.pipeline import fstate_from_arrays
        src_replicated = None
        if "arrays" in blob:                     # versioned (v2) envelope
            fmt = blob.get("format")
            if fmt is not None and fmt != CKPT_FORMAT:
                raise ValueError(
                    f"not a filter-session checkpoint (format {fmt!r})")
            version = blob.get("version")
            if version not in (CKPT_VERSION,):
                raise ValueError(
                    f"unknown filter-session checkpoint version {version!r} "
                    f"(this build reads v1 raw-array blobs and v2)")
            want = self.plan.fingerprint()
            got = blob.get("fingerprint")
            if got is not None and got != want:
                raise ValueError(
                    f"checkpoint plan fingerprint {got} does not match this "
                    f"session's {want}: the predicate chain, ordering "
                    "config, scope, adaptivity, or cost mode differ — "
                    "elastic restore only spans engines and shard counts")
            if "stats_layout" in blob:
                src_replicated = blob["stats_layout"] == "replicated"
            arrays = blob["arrays"]
            stored_crc = blob.get("crc32")
            if stored_crc is None:
                warnings.warn(
                    "repro: loading a checksum-less v2 filter-session "
                    "checkpoint (written before the crc32 integrity field); "
                    "corruption cannot be detected — re-save to upgrade",
                    UserWarning, stacklevel=2)
            else:
                got_crc = arrays_crc32(arrays)
                if got_crc != int(stored_crc):
                    raise ValueError(
                        f"corrupt checkpoint: crc32 mismatch (stored "
                        f"{int(stored_crc):#010x}, computed {got_crc:#010x})"
                        " — the blob was truncated or bit-flipped in "
                        "storage; refusing to deserialize garbage state")
        else:                                    # v1: raw fstate_to_arrays
            arrays = blob
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        old_shards = _layout_of(arrays)
        if blob.get("shards") is not None and blob["shards"] != old_shards:
            raise ValueError(
                f"corrupt checkpoint: envelope says {blob['shards']} "
                f"shard(s) but the state arrays carry {old_shards}")
        if src_replicated is None:
            # v1 blobs carry no layout tag: replicated shards are bitwise
            # identical (the eager-CENTRALIZED invariant); anything else
            # accumulated locally
            src_replicated = old_shards > 1 and all(
                bool(np.all(arrays[k] == arrays[k][0]))
                for k in _SUM_KEYS if k in arrays)
        new_shards = self.num_shards if self.sharded else 0
        if old_shards != new_shards \
                or src_replicated != self._stats_replicated:
            arrays = reshard_state_arrays(
                arrays, new_shards, groups=self._core.specs.groups,
                src_replicated=src_replicated,
                tgt_replicated=self._stats_replicated)
        restored = fstate_from_arrays(arrays)
        # re-anchor the host-side deferred-boundary row counter
        self._rows_local = int(np.max(np.asarray(restored.rows_into_epoch)))
        return restored


#: chain-lint findings already warned about this process (warn once per
#: (code, location) — plans are rebuilt constantly in benches/tests)
_LINT_WARNED: set[tuple[str, str]] = set()


def _lint_plan_chain(plan: FilterPlan) -> None:
    """Plan-compile-time chain lint (the Liu & Ives point: canonicalize
    BEFORE adaptive re-optimization). Error findings — unsatisfiable
    predicates/groups/conjunctions — raise; redundancy findings warn once;
    info notes stay silent (the CLI surfaces them)."""
    import warnings

    from repro.analysis import chain_lint, diagnostics

    diags = chain_lint.lint_chain(plan.predicates)
    errs = diagnostics.errors(diags)
    if errs:
        raise ValueError(
            "FilterPlan chain fails the semantics lint:\n"
            + diagnostics.render_report(errs)
            + "\n(run `python -m repro.analysis --chain` for the full "
            "report)")
    for d in diagnostics.warnings_of(diags):
        key = (d.code, d.location)
        if key not in _LINT_WARNED:
            _LINT_WARNED.add(key)
            warnings.warn(f"repro chain lint: {d.render()}", UserWarning,
                          stacklevel=3)


def build_session(plan: FilterPlan, mesh=None) -> FilterSession:
    """Compile a declarative ``FilterPlan`` into a ``FilterSession``.

    ``mesh``: optional ``jax.sharding.Mesh`` carrying ``plan.axis_name``
    (default when ``plan.shards > 1``: a fresh 1-axis mesh over
    ``plan.shards`` devices). Passing a mesh forces the shard_mapped
    execution layer even for ``shards=1``.

    Runs the chain semantics linter (``repro.analysis.chain_lint``) before
    compiling: a provably-unsatisfiable chain raises here — at plan time,
    with the predicate named — instead of silently cutting every row;
    provably-redundant predicates warn once per process. (The legacy
    ``FilterSession.from_filter`` path skips the lint: it adopts an
    already-validated filter.)
    """
    _lint_plan_chain(plan)
    return FilterSession(plan, mesh=mesh)


# ========================================================== elastic reshard
def _layout_of(arrays: dict) -> int:
    """Shard layout of a state-arrays dict: 0 = unsharded (no leading
    axis), S >= 1 = stacked [S, ...] leaves."""
    rows = np.asarray(arrays["rows_into_epoch"])
    return 0 if rows.ndim == 0 else int(rows.shape[0])

_SUM_KEYS = ("stats.num_cut", "stats.cost_acc", "stats.n_monitored",
             "stats.group_cut", "rows_into_epoch")


def reshard_state_arrays(arrays: dict, new_shards: int, groups: tuple,
                         src_replicated: bool = False,
                         tgt_replicated: bool = False) -> dict:
    """S→S′ elastic reshard of a checkpointed OrderState (pure numpy).

    Epoch stat accumulators (``stats.*``) are merged to one logical
    executor according to the SOURCE layout — sum over the shard axis when
    the source accumulated locally (partitioned: per_shard / per_batch /
    deferred CENTRALIZED), first row when every shard already held the
    psum-merged global totals (replicated: eager CENTRALIZED) — and laid
    out for the TARGET: an even split for partitioned targets (the next
    boundary merge recovers the global totals exactly; bit-exact when S′
    is a power of two, since f32 division by 2^k only changes the
    exponent), the whole merged value broadcast for replicated targets and
    for ``new_shards=0`` (unsharded — its boundary merge is the identity).

    ``rows_into_epoch`` is a per-shard PHASE counter in every mode (the
    lockstep pipelines feed every shard the same batch width), so the
    maximum phase is broadcast — boundary cadence survives the rescale.

    Derived quantities (perm, group_perm, adj_rank) are taken verbatim
    when every source shard agrees (the CENTRALIZED invariant) and
    otherwise re-derived from the merged statistics via the same
    ``cnf_order`` math the epoch boundary uses.
    """
    old = _layout_of(arrays)
    stacked = {k: np.asarray(v) for k, v in arrays.items()}
    if old == 0:                      # promote to a 1-shard stack
        stacked = {k: v[None] for k, v in stacked.items()}
        old = 1

    # ---- merge to one logical executor ------------------------------------
    merged: dict[str, np.ndarray] = {}
    for k, v in stacked.items():
        if k == "rows_into_epoch":
            merged[k] = v.max(axis=0)
        elif k in _SUM_KEYS:
            if src_replicated:
                merged[k] = v[0].astype(np.float64)
            elif np.issubdtype(v.dtype, np.integer):
                merged[k] = v.sum(axis=0, dtype=np.int64)
            else:
                merged[k] = v.astype(np.float64).sum(axis=0)
        else:
            merged[k] = v[0]
    shards_agree = all(
        bool(np.all(v == v[0])) for k, v in stacked.items()
        if k not in _SUM_KEYS)

    if not shards_agree:
        # heterogeneous source shards (PER_SHARD scope): re-derive one
        # consensus order from the merged evidence — the exact rank math of
        # the epoch boundary, on the summed accumulators.
        mstats = stats_lib.FilterStats(
            num_cut=merged["stats.num_cut"].astype(np.float32),
            cost_acc=merged["stats.cost_acc"].astype(np.float32),
            n_monitored=merged["stats.n_monitored"].astype(np.float32),
            group_cut=merged.get("stats.group_cut",
                                 merged["stats.num_cut"]).astype(np.float32))
        adj = stacked["adj_rank"].astype(np.float64).mean(axis=0) \
            .astype(np.float32)
        merged["adj_rank"] = adj
        if float(mstats.n_monitored) > 0.0:
            grank = stats_lib.group_ranks(mstats, groups, xp=np)
            mrank = stats_lib.member_ranks(mstats, xp=np)
            perm, gperm = stats_lib.cnf_order(grank, mrank, groups, xp=np)
            merged["perm"] = perm.astype(np.int32)
            merged["group_perm"] = gperm.astype(np.int32)
        merged["epoch"] = stacked["epoch"].max(axis=0)

    # ---- split over the new layout ----------------------------------------
    split_by = 1 if (tgt_replicated or new_shards == 0) \
        else max(new_shards, 1)
    out: dict[str, np.ndarray] = {}
    for k, v in merged.items():
        src_dtype = stacked[k].dtype
        if k in _SUM_KEYS and k != "rows_into_epoch":
            if np.issubdtype(src_dtype, np.integer):
                piece = (v // split_by).astype(src_dtype)
            else:
                piece = (v / split_by).astype(src_dtype)
        else:
            piece = v.astype(src_dtype)
        if new_shards == 0:
            out[k] = piece
        else:
            out[k] = np.broadcast_to(
                piece[None], (new_shards,) + piece.shape).copy()
    return out
