"""FilterPlan: the one declarative description of an adaptive-filter stage.

The paper's thesis is that adaptive reordering should be a property of the
execution engine, not something the user wires by hand. A ``FilterPlan``
is therefore the *whole* user-facing configuration surface — chain, engine,
scope, shard count, compaction, exchange cadence, and device tokenization —
validated once, here, with every cross-field rule in one place. Compiling a
plan (``repro.core.session.build_session``) yields a ``FilterSession`` with
exactly one ``step`` entry point; nothing downstream re-checks combinations.

Valid field combinations (the single source of truth):

  engine      any registered engine name ("jnp", "pallas", "numpy", ...).
              Host (non-traceable) engines stream via
              ``AdaptiveFilter.process_stream``; a session's jitted step
              falls back to the jnp reference engine for them.
  cost_mode   "static" works everywhere; "measured" (host wall clocks)
              needs the numpy engine.
  scope       "per_shard" | "centralized" | "per_batch" (paper §2.2).
  shards      1 = single executor; > 1 runs the step under ``shard_map``
              over a data mesh axis (needs a traceable engine and that
              many visible devices).
  compact     device-side survivor compaction (padded [.., C, cap] gather
              + count). Needs a traceable engine — host engines already
              emit compacted rows.
  capacity    only with ``compact``: None (batch width, lossless), an
              int >= 1 (fixed width; overflow is counted + warned), or
              "auto" (tracks the monitor lane's pass-rate × ``slack``,
              re-quantized to 128s at epoch boundaries).
  slack       >= 1.0; headroom factor for "auto" capacity.
  exchange    "eager" | "deferred" | "deferred-async"; anything but
              "eager" requires scope="centralized" (other scopes never
              exchange statistics).
  tokenize    TokenizeSpec(vocab_size, tokens_per_row) to hash+pack the
              survivors on device; requires ``compact`` (it consumes the
              padded buffers) and vocab_size < 2**24 (u32-limb modulo).
  skip_tier   "off" | "zonemap" | "zonemap+bloom" | "auto": the
              tile-statistics skip tier (``core.skip_tier``) — 128-row
              zone maps (+ Bloom bits for equality predicates) resolve
              whole tiles before the row-level chain. Needs shards == 1
              (the jnp path sizes a gather from a per-step host sync,
              which cannot drive static shapes under shard_map); "auto"
              needs a traceable engine — the session's online tuner
              drives it by measured us_per_row. Survivors, tokens, and
              ordering statistics are bit-identical with the tier on or
              off; only speed changes.

Two plans are checkpoint-compatible iff their *fingerprints* match: the
fingerprint hashes the semantic identity of the adaptive state (predicate
chain, ordering config, scope, adaptivity, cost mode) and deliberately
excludes execution details (engine, shard count, compaction, exchange,
tokenize) — so a checkpoint moves freely across engines and shard counts
(elastic reshard) but refuses to load into a session whose ordering math
would disagree with the one that wrote it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from typing import Sequence

from repro.core import engine as engine_lib
from repro.core.engine import get_engine
from repro.core.ordering import OrderingConfig
from repro.core.predicates import Predicate
from repro.core.scope import EXCHANGE_MODES, scope_from_str
from repro.core.skip_tier import SKIP_TIER_MODES

#: vocab ceiling of the u32-limb device tokenizer's byte-fold modulo —
#: THE definition (``repro.data.tokenizer`` imports it lazily; it lives
#: here because the plan layer must validate it without importing jax)
MAX_DEVICE_VOCAB = 1 << 24

#: FilterPlan fields EXCLUDED from ``fingerprint()`` by design: execution
#: details a checkpoint is portable across (engine swap, elastic reshard,
#: compaction/tokenize wiring, skip-tier speed knobs). Every plan field
#: must be either hashed by ``fingerprint()`` or listed here —
#: ``repro.analysis.plan_matrix.fingerprint_coverage`` enforces the
#: partition behaviorally, so a new field cannot silently break
#: checkpoint-restore compatibility. Extending this set is a reviewed
#: diff, exactly like the hotpath allowlist.
FINGERPRINT_RUNTIME_ONLY = frozenset({
    "engine", "shards", "axis_name", "compact", "capacity", "slack",
    "exchange", "tokenize", "skip_tier",
})


# ------------------------------------------------------------- deprecation
_WARNED: set[str] = set()


def warn_deprecated(key: str, message: str) -> None:
    """Emit ``message`` as a DeprecationWarning once per process per key.

    Messages carry a ``repro:`` prefix so CI can promote exactly THIS
    package's deprecations to errors (``-W "error:repro:DeprecationWarning"``
    matches on the message prefix) without flaking on third-party
    DeprecationWarnings from jax/numpy releases.
    """
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(f"repro: {message}", DeprecationWarning, stacklevel=3)


# ------------------------------------------------------ cross-field rules
def validate_combo(*, scope: str, cost_mode: str, backend: str,
                   compact_output: bool, compact_capacity,
                   compact_slack: float, exchange: str, shards: int = 1,
                   device_tokenize: bool = False,
                   skip_tier: str = "off") -> None:
    """THE cross-field validation for every engine × scope × compaction ×
    exchange × tokenize combination.

    ``AdaptiveFilterConfig``, ``ShardedAdaptiveFilter``, the pipelines, and
    ``FilterPlan`` all funnel through here, so the rules cannot drift.

    Every violated rule is reported — ONE aggregated ``ValueError`` listing
    all of them, each enumerating the valid choices for its field — so a
    plan with three bad fields costs one round trip, not three. Rules that
    depend on a field that already failed (e.g. engine-capability checks
    when the backend name is unknown) are skipped rather than reported as
    spurious extra failures.
    """
    problems: list[str] = []
    try:
        scope_from_str(scope)
        scope_ok = True
    except ValueError as e:
        problems.append(str(e))
        scope_ok = False
    if cost_mode not in ("static", "measured"):
        problems.append(f"bad cost_mode {cost_mode}; pick from "
                        "('static', 'measured')")
    backend_ok = backend in engine_lib.available_engines()
    if not backend_ok:
        problems.append(f"bad backend {backend}; registered engines: "
                        f"{engine_lib.available_engines()}")
    if cost_mode == "measured" and backend != "numpy":
        problems.append(
            "measured cost mode needs the host (numpy) backend; "
            f"cost_mode='static' works on every engine, got {backend!r}")
    if shards < 1:
        problems.append(f"shards must be >= 1, got {shards}")
    traceable = backend_ok and get_engine(backend).traceable
    if backend_ok and shards > 1 and not traceable:
        problems.append(
            f"backend {backend!r} is a host engine; the sharded "
            "filter needs a traceable engine (jnp / pallas)")
    if backend_ok and compact_output and not traceable:
        problems.append(
            "compact_output is the device-side gather; the host "
            f"engine {backend!r} already emits compacted rows "
            "(boolean-index short-circuit) — drop the flag")
    if compact_capacity is not None:
        if not compact_output:
            problems.append("compact_capacity needs compact_output=True")
        if isinstance(compact_capacity, str):
            if compact_capacity != "auto":
                problems.append(
                    f"compact_capacity {compact_capacity!r}: pass "
                    "an int, None (batch width), or 'auto'")
        elif compact_capacity < 1:
            problems.append(
                f"compact_capacity must be >= 1, got {compact_capacity!r} "
                "(or None for batch width, or 'auto')")
    if compact_slack < 1.0:
        problems.append(f"compact_slack must be >= 1.0 (headroom factor), "
                        f"got {compact_slack!r}")
    if exchange not in EXCHANGE_MODES:
        problems.append(
            f"bad exchange {exchange!r}; pick from {EXCHANGE_MODES}")
    elif exchange != "eager" and scope_ok and scope != "centralized":
        problems.append(
            "deferred exchange only changes the CENTRALIZED scope's "
            f"collective cadence; scope {scope!r} never exchanges "
            "— drop the flag")
    if device_tokenize and not compact_output:
        problems.append("device_tokenize consumes the padded compacted "
                        "buffers — it needs compact_output=True")
    if skip_tier not in SKIP_TIER_MODES:
        problems.append(
            f"bad skip_tier {skip_tier!r}; pick from {SKIP_TIER_MODES}")
    elif skip_tier != "off":
        if shards > 1:
            problems.append(
                "skip_tier needs shards == 1: the jnp skip path sizes its "
                "ambiguous-tile gather from a per-step host sync, which "
                "cannot drive static shapes under shard_map — run the "
                "tier per-executor or drop it")
        if backend_ok and not getattr(get_engine(backend), "supports_skip",
                                      False):
            problems.append(
                f"backend {backend!r} does not implement the skip tier; "
                "pick an engine with tile-statistics support (jnp / "
                "pallas / numpy) or skip_tier='off'")
        if backend_ok and skip_tier == "auto" and not traceable:
            problems.append(
                "skip_tier='auto' is driven by the session's online "
                "us_per_row tuner, which needs a traceable engine — pick "
                "'zonemap'/'zonemap+bloom' explicitly for host engines")
    if problems:
        if len(problems) == 1:
            raise ValueError(problems[0])
        raise ValueError(
            f"{len(problems)} invalid plan field combinations:\n  - "
            + "\n  - ".join(problems))


# ----------------------------------------------------------------- the plan
@dataclasses.dataclass(frozen=True)
class TokenizeSpec:
    """On-device tokenize/pack stage appended to the compacted survivors."""

    vocab_size: int
    tokens_per_row: int = 8

    def __post_init__(self) -> None:
        if not 1 <= self.vocab_size < MAX_DEVICE_VOCAB:
            raise ValueError(
                f"tokenize vocab_size must be in [1, {MAX_DEVICE_VOCAB}) "
                f"(u32-limb modulo), got {self.vocab_size}")
        if self.tokens_per_row < 1:
            raise ValueError("tokens_per_row must be >= 1")


@dataclasses.dataclass(frozen=True)
class FilterPlan:
    """Declarative adaptive-filter stage; see the module docstring for the
    full table of valid field combinations (this class IS the single source
    of truth — everything else delegates its validation here).

    Compile with ``repro.core.session.build_session(plan, mesh=None)``.
    """

    predicates: Sequence[Predicate]      # the chain (CNF via Predicate.group)
    ordering: OrderingConfig = OrderingConfig()
    engine: str = "jnp"
    scope: str = "per_shard"
    shards: int = 1
    axis_name: str = "data"
    adaptive: bool = True
    cost_mode: str = "static"
    compact: bool = False
    capacity: int | str | None = None    # None | int | "auto"
    slack: float = 1.5
    exchange: str = "eager"
    tokenize: TokenizeSpec | None = None
    skip_tier: str = "off"               # off | zonemap | zonemap+bloom | auto

    def __post_init__(self) -> None:
        object.__setattr__(self, "predicates", tuple(self.predicates))
        if not self.predicates:
            raise ValueError("need at least one predicate")
        validate_combo(scope=self.scope, cost_mode=self.cost_mode,
                       backend=self.engine, compact_output=self.compact,
                       compact_capacity=self.capacity,
                       compact_slack=self.slack, exchange=self.exchange,
                       shards=self.shards,
                       device_tokenize=self.tokenize is not None,
                       skip_tier=self.skip_tier)

    # ------------------------------------------------------------ identity
    def fingerprint(self) -> str:
        """Semantic identity of the adaptive state this plan produces.

        Covers the chain, the ordering config, scope, adaptivity, and cost
        mode; excludes engine / shards / compaction / exchange / tokenize /
        skip_tier (execution details a checkpoint is portable across —
        shard count explicitly so, that is what elastic reshard is; the
        skip tier never changes survivors or statistics, only speed).
        """
        payload = {
            "predicates": [
                (p.name, p.column, p.op, p.t1, p.t2, p.rounds,
                 p.static_cost, None if p.group is None else str(p.group))
                for p in self.predicates],
            "ordering": dataclasses.asdict(self.ordering),
            "scope": self.scope,
            "adaptive": self.adaptive,
            "cost_mode": self.cost_mode,
        }
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]
