"""Statistics scope policies (paper §2.2).

The paper weighs three lifetimes for the adaptive metadata (ranks + epoch
accumulators) and picks *per-executor*:

  per-task     — state dies with each task: too little evidence accumulates.
  centralized  — one global state at the driver: network traffic + contention.
  per-executor — JVM-global state per executor: long-lived, zero network
                 cost, and locally adaptive under heterogeneous data.

Mapping here: an "executor" is one data shard of the ingestion pipeline (one
host process, or one mesh data-row when the filter runs jitted under
``shard_map``). A "task" is one micro-batch step.

  PER_BATCH    — reset the epoch evidence every step (per-task analogue);
                 the monitor stride and the re-rank counter persist — they
                 are stream properties, not evidence.
  PER_SHARD    — default; state persists per shard, NO collectives: the
                 lowered HLO of the sharded filter step contains no
                 all-reduce (asserted by tests/test_sharded_filter.py),
                 matching the paper's "no data transferred through the
                 network".
  CENTRALIZED  — batch monitor counters are merged across the given mesh
                 axes so every shard accumulates identical global statistics
                 and adopts the global order at each epoch boundary. WHEN
                 they merge is the ``AdaptiveFilterConfig.exchange`` policy:

                   eager          — psum every step (one small 2P+G+1-float
                                    all-reduce per micro-batch; the original
                                    behaviour, still the default).
                   deferred       — accumulate locally, psum ONCE per
                                    ``calculate_rate`` rows at the epoch
                                    boundary (``exchange_update``); the
                                    per-step compiled module contains no
                                    all-reduce at all (HLO-pinned). Sums are
                                    associative, so the merged epoch totals
                                    — and hence the adopted perm — are
                                    IDENTICAL to eager's.
                   deferred-async — same single boundary collective, but its
                                    result is folded in one epoch LATE (the
                                    paper's deferred per-executor update
                                    generalized to the mesh), so the merge
                                    can overlap the next epoch's filter
                                    work.

``core.sharded.ShardedAdaptiveFilter`` is the execution layer that runs all
of it under real ``shard_map``.
"""

from __future__ import annotations

import enum
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.stats import FilterStats


class Scope(enum.Enum):
    PER_BATCH = "per_batch"
    PER_SHARD = "per_shard"
    CENTRALIZED = "centralized"


#: Statistics-exchange cadence for CENTRALIZED (see module docstring).
EXCHANGE_MODES = ("eager", "deferred", "deferred-async")


def reduce_stats(stats: FilterStats, scope: Scope,
                 axis_names: Sequence[str] = ()) -> FilterStats:
    """Apply the scope's reduction to epoch accumulators.

    Must be called inside ``shard_map``/``pmap`` for CENTRALIZED to see the
    named axes; PER_SHARD / PER_BATCH are identity (no communication).
    """
    if scope is Scope.CENTRALIZED and axis_names:
        return FilterStats(
            num_cut=jax.lax.psum(stats.num_cut, axis_names),
            cost_acc=jax.lax.psum(stats.cost_acc, axis_names),
            n_monitored=jax.lax.psum(stats.n_monitored, axis_names),
            group_cut=None if stats.group_cut is None
            else jax.lax.psum(stats.group_cut, axis_names),
        )
    return stats


def scope_from_str(name: str) -> Scope:
    try:
        return Scope(name)
    except ValueError as exc:
        raise ValueError(
            f"unknown scope {name!r}; pick from "
            f"{[s.value for s in Scope]}") from exc
