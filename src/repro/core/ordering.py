"""Epoch controller: collectRate sampling, calculateRate epochs, momentum.

Functional port of the paper's per-executor metadata (§2.2). One
``OrderState`` is the JVM-global state of one Spark executor; in the JAX
pipeline one lives per data shard (see ``scope.py``). Because the state is
threaded functionally through ``jax.lax`` control flow, the paper's lock is
unnecessary here — exactly one epoch update happens per boundary by
construction. (The thread/lock semantics of real Spark executors, including
deferred updates, are reproduced separately in ``executor_sim.py``.)

Counters are kept modulo the relevant rates in int32 so the state never
overflows on unbounded streams (the paper's counters are JVM longs; we keep
an epoch counter + in-epoch offsets instead, which is equivalent and
checkpoint-friendly).

Backend-agnostic: every function takes an array-namespace ``xp``
(``jax.numpy`` for the jitted device path, ``numpy`` for host streaming) and
runs the SAME code on both — this module is the single source of truth for
the ordering math; there is no host-side mirror. The only divergence is the
epoch-boundary conditional, which lowers to ``jax.lax.cond`` under jnp and a
plain python branch under numpy.

CNF (AND of OR-groups): ranks are computed per *group* (selectivity =
exact P(group passes) from the monitor lane, cost = Σ member costs) and
momentum-smoothed at group granularity; members are ordered within their
group by miss-rate each epoch. For flat chains (all singleton groups) this
reduces bit-exactly to the paper's per-predicate ordering.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import stats as stats_lib
from repro.core.stats import FilterStats


@dataclasses.dataclass(frozen=True)
class OrderingConfig:
    """Table 1 of the paper (defaults reproduced verbatim)."""

    collect_rate: int = 1000        # sample 1 row in every collect_rate
    calculate_rate: int = 1_000_000  # re-rank after this many rows
    momentum: float = 0.3            # past-preservation factor
    # Beyond-paper (EXPERIMENTS §Perf): snap-on-flip. Momentum smooths noisy
    # epochs but delays regime changes; if the CURRENT order's expected
    # per-row cost under the FRESH epoch stats exceeds snap_threshold × the
    # fresh-optimal order's cost, the update bypasses momentum entirely.
    # 0.0 disables (paper-faithful default).
    snap_threshold: float = 0.0

    def __post_init__(self) -> None:
        if self.collect_rate < 1:
            raise ValueError("collect_rate must be >= 1")
        if self.calculate_rate < 1:
            raise ValueError("calculate_rate must be >= 1")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError("momentum must be in [0, 1)")
        if self.snap_threshold < 0.0:
            raise ValueError("snap_threshold must be >= 0")


class OrderState(NamedTuple):
    """The adaptive filter's full mutable state (checkpointable pytree).

    Arrays are jnp on the device path and numpy on the host path; the shapes
    and dtypes match element-wise (f32/i32), so checkpoints round-trip.
    """

    perm: Any          # i32[P] current evaluation order (groups contiguous)
    adj_rank: Any      # f32[G] momentum-smoothed GROUP ranks (G == P if flat)
    stats: FilterStats  # accumulators for the current epoch
    rows_into_epoch: Any   # i32[] rows processed since last re-rank
    sample_phase: Any      # i32[] global row offset mod collect_rate
    epoch: Any             # i32[] completed epochs (0 → no history yet)
    group_perm: Any = None  # i32[G] current group evaluation order


def init_order_state(n_predicates: int, n_groups: int | None = None,
                     xp=jnp) -> OrderState:
    """Initial order = the user-given statement order, as in Spark."""
    if n_groups is None:
        n_groups = n_predicates
    return OrderState(
        perm=xp.arange(n_predicates, dtype=xp.int32),
        adj_rank=xp.zeros((n_groups,), xp.float32),
        stats=stats_lib.init_stats(n_predicates, n_groups, xp=xp),
        rows_into_epoch=xp.zeros((), xp.int32),
        sample_phase=xp.zeros((), xp.int32),
        epoch=xp.zeros((), xp.int32),
        group_perm=xp.arange(n_groups, dtype=xp.int32),
    )


def _default_groups(state: OrderState) -> tuple:
    return tuple(range(int(state.perm.shape[0])))


def epoch_update(state: OrderState, cfg: OrderingConfig,
                 groups: tuple | None = None, xp=jnp) -> OrderState:
    """Re-rank at an epoch boundary; reset accumulators; keep momentum memory.

    ``groups`` is the static CNF structure (dense group id per predicate);
    None means all-singleton groups (flat conjunction).

    Guard: if the epoch collected no monitored rows (possible with tiny
    epochs), keep the previous order — reordering on zero evidence is the
    kind of thrash the momentum term exists to prevent.
    """
    groups = tuple(groups) if groups is not None else _default_groups(state)
    n_preds = int(state.perm.shape[0])
    n_groups = int(state.adj_rank.shape[0])
    have_evidence = state.stats.n_monitored > 0.0

    rank_now = stats_lib.group_ranks(state.stats, groups, xp=xp)
    adj = stats_lib.momentum_update(state.adj_rank, rank_now, cfg.momentum,
                                    first_epoch=state.epoch == 0, xp=xp)
    if cfg.snap_threshold > 0.0:
        nc = stats_lib.group_normalized_costs(state.stats, groups, xp=xp)
        s = stats_lib.group_selectivities(state.stats, xp=xp)
        cost_cur = stats_lib.expected_chain_cost(nc, s, state.group_perm,
                                                 xp=xp)
        fresh = stats_lib.order_from_ranks(rank_now, xp=xp)
        cost_fresh = stats_lib.expected_chain_cost(nc, s, fresh, xp=xp)
        snap = cost_cur > cfg.snap_threshold * cost_fresh
        adj = xp.where(snap, rank_now, adj)
    mrank = stats_lib.member_ranks(state.stats, xp=xp)
    new_perm, new_group_perm = stats_lib.cnf_order(adj, mrank, groups, xp=xp)

    perm = xp.where(have_evidence, new_perm, state.perm)
    group_perm = xp.where(have_evidence, new_group_perm, state.group_perm)
    adj_rank = xp.where(have_evidence, adj, state.adj_rank)
    epoch = state.epoch + xp.where(have_evidence, 1, 0).astype(xp.int32)

    return OrderState(
        perm=perm,
        adj_rank=adj_rank,
        stats=stats_lib.init_stats(n_preds, n_groups, xp=xp),
        rows_into_epoch=xp.zeros((), xp.int32),
        sample_phase=state.sample_phase,
        epoch=epoch,
        group_perm=group_perm,
    )


def advance(state: OrderState, cfg: OrderingConfig,
            cut_counts, costs, n_monitored, n_rows: int,
            group_cut=None, groups: tuple | None = None,
            xp=jnp, defer_epoch: bool = False) -> OrderState:
    """Fold one batch's monitor results in; fire the epoch boundary if crossed.

    Epoch boundaries are honored at batch granularity (a batch is the unit of
    work, like a Spark task's row group); with batch ≪ calculate_rate this is
    the paper's behavior. ``n_rows`` must be a static python int (batch
    shape), so the modulo bookkeeping stays in int32 regardless of stream
    length.

    ``defer_epoch=True`` (static) accumulates evidence but NEVER fires the
    boundary — the caller owns it (deferred epoch exchange: the driver calls
    ``exchange_update`` once per ``calculate_rate`` rows, merging stats
    across the mesh in ONE collective instead of one per step; the per-step
    compiled module then contains no all-reduce at all).
    """
    new_stats = stats_lib.accumulate(state.stats, cut_counts, costs,
                                     n_monitored, group_cut=group_cut, xp=xp)
    rows = state.rows_into_epoch + xp.asarray(n_rows, xp.int32)
    state = state._replace(
        stats=new_stats,
        rows_into_epoch=rows,
        sample_phase=(state.sample_phase + n_rows) % cfg.collect_rate,
    )
    if defer_epoch:
        return state

    def fire(s: OrderState) -> OrderState:
        updated = epoch_update(s, cfg, groups=groups, xp=xp)
        # carry the overshoot so epoch length is exact on average
        return updated._replace(rows_into_epoch=s.rows_into_epoch % cfg.calculate_rate)

    if xp is jnp:
        return jax.lax.cond(rows >= cfg.calculate_rate, fire, lambda s: s,
                            state)
    return fire(state) if rows >= cfg.calculate_rate else state


def boundary_update(state: OrderState, cfg: OrderingConfig,
                    groups: tuple | None = None, xp=jnp,
                    stats_override: FilterStats | None = None) -> OrderState:
    """Explicit epoch-boundary update for the deferred-exchange path.

    Equivalent to the ``fire`` branch of ``advance`` — re-rank, reset
    accumulators, keep the row overshoot — but driven by the caller instead
    of the per-step conditional. ``stats_override`` substitutes the evidence
    used for the re-rank (the psum-merged global stats under deferred
    CENTRALIZED, or the one-epoch-stale merged stats under deferred-async).
    """
    if stats_override is not None:
        state = state._replace(stats=stats_override)
    updated = epoch_update(state, cfg, groups=groups, xp=xp)
    return updated._replace(
        rows_into_epoch=state.rows_into_epoch % cfg.calculate_rate)
