"""Epoch controller: collectRate sampling, calculateRate epochs, momentum.

Functional port of the paper's per-executor metadata (§2.2). One
``OrderState`` is the JVM-global state of one Spark executor; in the JAX
pipeline one lives per data shard (see ``scope.py``). Because the state is
threaded functionally through ``jax.lax`` control flow, the paper's lock is
unnecessary here — exactly one epoch update happens per boundary by
construction. (The thread/lock semantics of real Spark executors, including
deferred updates, are reproduced separately in ``executor_sim.py``.)

Counters are kept modulo the relevant rates in int32 so the state never
overflows on unbounded streams (the paper's counters are JVM longs; we keep
an epoch counter + in-epoch offsets instead, which is equivalent and
checkpoint-friendly).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import stats as stats_lib
from repro.core.stats import FilterStats


@dataclasses.dataclass(frozen=True)
class OrderingConfig:
    """Table 1 of the paper (defaults reproduced verbatim)."""

    collect_rate: int = 1000        # sample 1 row in every collect_rate
    calculate_rate: int = 1_000_000  # re-rank after this many rows
    momentum: float = 0.3            # past-preservation factor
    # Beyond-paper (EXPERIMENTS §Perf): snap-on-flip. Momentum smooths noisy
    # epochs but delays regime changes; if the CURRENT order's expected
    # per-row cost under the FRESH epoch stats exceeds snap_threshold × the
    # fresh-optimal order's cost, the update bypasses momentum entirely.
    # 0.0 disables (paper-faithful default).
    snap_threshold: float = 0.0

    def __post_init__(self) -> None:
        if self.collect_rate < 1:
            raise ValueError("collect_rate must be >= 1")
        if self.calculate_rate < 1:
            raise ValueError("calculate_rate must be >= 1")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError("momentum must be in [0, 1)")
        if self.snap_threshold < 0.0:
            raise ValueError("snap_threshold must be >= 0")


class OrderState(NamedTuple):
    """The adaptive filter's full mutable state (checkpointable pytree)."""

    perm: jnp.ndarray          # i32[P] current evaluation order
    adj_rank: jnp.ndarray      # f32[P] momentum-smoothed ranks
    stats: FilterStats         # accumulators for the current epoch
    rows_into_epoch: jnp.ndarray   # i32[] rows processed since last re-rank
    sample_phase: jnp.ndarray      # i32[] global row offset mod collect_rate
    epoch: jnp.ndarray             # i32[] completed epochs (0 → no history yet)


def init_order_state(n_predicates: int) -> OrderState:
    """Initial order = the user-given statement order, as in Spark."""
    return OrderState(
        perm=jnp.arange(n_predicates, dtype=jnp.int32),
        adj_rank=jnp.zeros((n_predicates,), jnp.float32),
        stats=stats_lib.init_stats(n_predicates),
        rows_into_epoch=jnp.zeros((), jnp.int32),
        sample_phase=jnp.zeros((), jnp.int32),
        epoch=jnp.zeros((), jnp.int32),
    )


def epoch_update(state: OrderState, cfg: OrderingConfig) -> OrderState:
    """Re-rank at an epoch boundary; reset accumulators; keep momentum memory.

    Guard: if the epoch collected no monitored rows (possible with tiny
    epochs), keep the previous order — reordering on zero evidence is the
    kind of thrash the momentum term exists to prevent.
    """
    have_evidence = state.stats.n_monitored > 0.0

    rank_now = stats_lib.ranks(state.stats)
    adj = stats_lib.momentum_update(state.adj_rank, rank_now, cfg.momentum,
                                    first_epoch=state.epoch == 0)
    if cfg.snap_threshold > 0.0:
        nc = stats_lib.normalized_costs(state.stats)
        s = stats_lib.selectivities(state.stats)
        cost_cur = stats_lib.expected_chain_cost(nc, s, state.perm)
        fresh = stats_lib.order_from_ranks(rank_now)
        cost_fresh = stats_lib.expected_chain_cost(nc, s, fresh)
        snap = cost_cur > cfg.snap_threshold * cost_fresh
        adj = jnp.where(snap, rank_now, adj)
    new_perm = stats_lib.order_from_ranks(adj)

    perm = jnp.where(have_evidence, new_perm, state.perm)
    adj_rank = jnp.where(have_evidence, adj, state.adj_rank)
    epoch = state.epoch + jnp.where(have_evidence, 1, 0).astype(jnp.int32)

    return OrderState(
        perm=perm,
        adj_rank=adj_rank,
        stats=stats_lib.init_stats(int(state.perm.shape[0])),
        rows_into_epoch=jnp.zeros((), jnp.int32),
        sample_phase=state.sample_phase,
        epoch=epoch,
    )


def advance(state: OrderState, cfg: OrderingConfig,
            cut_counts: jnp.ndarray, costs: jnp.ndarray,
            n_monitored, n_rows: int) -> OrderState:
    """Fold one batch's monitor results in; fire the epoch boundary if crossed.

    Epoch boundaries are honored at batch granularity (a batch is the unit of
    work, like a Spark task's row group); with batch ≪ calculate_rate this is
    the paper's behavior. ``n_rows`` must be a static python int (batch
    shape), so the modulo bookkeeping stays in int32 regardless of stream
    length.
    """
    new_stats = stats_lib.accumulate(state.stats, cut_counts, costs, n_monitored)
    rows = state.rows_into_epoch + jnp.asarray(n_rows, jnp.int32)
    state = state._replace(
        stats=new_stats,
        rows_into_epoch=rows,
        sample_phase=(state.sample_phase + n_rows) % cfg.collect_rate,
    )

    def fire(s: OrderState) -> OrderState:
        updated = epoch_update(s, cfg)
        # carry the overshoot so epoch length is exact on average
        return updated._replace(rows_into_epoch=s.rows_into_epoch % cfg.calculate_rate)

    return jax.lax.cond(rows >= cfg.calculate_rate, fire, lambda s: s, state)
