"""Tile-statistics skip tier: zone maps + Bloom bits ahead of the chain.

The paper's controller decides per *row*; this tier decides per *chunk* —
the zone-map / Bloom data-skipping pattern of Delta/Iceberg, put in front
of the CNF chain kernel. Every batch is summarized in 128-row tiles
(``SKIP_TILE``): per-column min/max, plus an optional 128-bit Bloom bitmap
of ``round(x) mod 128`` keys for equality predicates. A pre-pass then
resolves whole tiles against the current chain:

  provably PASS  — every OR-group has a member whose zone range proves
                   every row passes → the tile skips the row-level kernel
                   and is bulk-copied into the survivor set;
  provably FAIL  — some OR-group's every member provably fails every row
                   → the tile is dropped without row-level work;
  ambiguous      — the tile reaches the existing row-level chain.

Provability per op (f32 min/max ``mn``/``mx`` of the tile's column):

  GT       pass: mn > t1            fail: mx <= t1
  LT       pass: mx < t1            fail: mn >= t1
  BETWEEN  pass: mn > t1 & mx < t2  fail: mx <= t1 | mn >= t2
  EQ       pass: round(mn) == round(mx) == round(t1)   (round is monotone)
           fail: round(t1) outside [round(mn), round(mx)], or the Bloom
                 bit of round(t1) mod 128 is clear (zonemap+bloom mode)
  HASHMIX  never provable (the mix destroys ordering) — always ambiguous.

All proofs are conservative: padding lanes (NaN here, zeros in the Pallas
glue) can only *weaken* a proof, never fire one spuriously, and provably-
pass tiles are still intersected with row validity downstream. The monitor
lane is deliberately untouched by the tier — sampled rows always execute
row-level on the full batch, so cut counts, group selectivities, and the
adopted permutations are bit-identical with the tier on or off (pinned by
``tests/test_skip_tier.py``).

``SkipTierTuner`` is the Cuttlefish-style online arm (arXiv 1802.09180):
``skip_tier="auto"`` scores the tier by measured ``us_per_row`` against the
plain path and — structurally — disables it when the ambiguous-tile
fraction says it cannot pay (shuffled layouts), so adversarial row orders
degrade gracefully to the current path.
"""

from __future__ import annotations

import numpy as np

from repro.core import predicates as pred_lib
from repro.core.engine.base import ChainResult, SkipInfo

SKIP_TILE = 128          # rows per zone-map tile (VPU lane quantum)
BLOOM_BITS = 128         # Bloom bitmap width per (column, tile) — 4 u32
#: jnp gather capacities are quantized to this many tiles (bounded jit
#: cache churn, same trick as compaction's CAPACITY_QUANTUM)
AMBIG_QUANTUM_TILES = 16

SKIP_TIER_MODES = ("off", "zonemap", "zonemap+bloom", "auto")


def eq_round(t1: float) -> float:
    """The EQ threshold key: round() in f32, exactly as the packed specs
    and the row-level ``jnp.round(x) == jnp.round(t1)`` see it.

    Single-sourced here so the tile resolver and the chain linter
    (``repro.analysis.chain_lint``) cannot disagree on quantization —
    ``Predicate.t1`` is a python float64 but every engine compares against
    its float32 packing, so any analysis that reasons from the f64 value
    can prove facts the runtime will contradict.
    """
    return float(np.round(np.float32(t1)))


def bloom_key(t1: float) -> int:
    """Bloom bit index of an EQ threshold: round32(t1) mod BLOOM_BITS —
    the same fold ``bloom_bitmap`` applies to the data side."""
    return int(np.mod(eq_round(t1), float(BLOOM_BITS)))


def host_pred_rows(specs) -> list[tuple[int, int, float, float]]:
    """Static per-predicate (column, op, t1, t2) rows read host-side.

    The chain is a trace-time constant (specs are closed over, never traced
    arguments), so tile resolution can branch on the op in python — unlike
    the row-level engines, which must dispatch dynamically under ``perm``.
    """
    col = np.asarray(specs.column)
    op = np.asarray(specs.op)
    t1 = np.asarray(specs.t1)
    t2 = np.asarray(specs.t2)
    return [(int(col[i]), int(op[i]), float(t1[i]), float(t2[i]))
            for i in range(specs.n)]


# ------------------------------------------------------------- summaries
def pad_to_tiles(columns, *, xp, fill=np.nan):
    """Pad f32[C, R] to a SKIP_TILE multiple; NaN lanes stay ambiguous."""
    n_rows = columns.shape[1]
    pad = (-n_rows) % SKIP_TILE
    if pad:
        columns = xp.pad(columns, ((0, 0), (0, pad)),
                         constant_values=np.float32(fill))
    return columns


def tile_summaries(columns, *, bloom: bool, xp):
    """Zone maps (+ optional Bloom bitmap) of one batch.

    ``columns``: f32[C, R]. Returns (mins f32[C, T], maxs f32[C, T],
    bloom bool[C, T, BLOOM_BITS] | None) with T = ceil(R / SKIP_TILE).
    NaN padding propagates into min/max, keeping ragged tail tiles
    unprovable. The Bloom bitmap is carried unpacked (one lane per bit) —
    a TPU lowering packs it into 4 u32 words per (column, tile), which is
    what ``benchmarks/roofline.py`` charges.
    """
    padded = pad_to_tiles(columns, xp=xp)
    n_cols = padded.shape[0]
    n_tiles = padded.shape[1] // SKIP_TILE
    tiles = padded.reshape(n_cols, n_tiles, SKIP_TILE)
    mins = tiles.min(axis=2)
    maxs = tiles.max(axis=2)
    bl = bloom_bitmap(padded, xp=xp) if bloom else None
    return mins, maxs, bl


def bloom_bitmap(columns, *, xp):
    """Bloom bitmap bool[C, T, BLOOM_BITS] of an already-padded batch.

    Key = round(x) mod BLOOM_BITS. Padding lanes (NaN here, zeros in the
    pallas glue) fold to key 0, which only ADDS a bit — weakening fail
    proofs, never strengthening them (conservative).
    """
    padded = pad_to_tiles(columns, xp=xp)
    n_cols = padded.shape[0]
    n_tiles = padded.shape[1] // SKIP_TILE
    tiles = padded.reshape(n_cols, n_tiles, SKIP_TILE)
    vals = xp.where(xp.isnan(tiles), xp.zeros_like(tiles), xp.round(tiles))
    keys = xp.mod(vals, float(BLOOM_BITS)).astype(np.int32)
    return (keys[..., None] ==
            xp.arange(BLOOM_BITS, dtype=np.int32)).any(axis=2)


# ------------------------------------------------------------ resolution
def resolve_tiles(mins, maxs, bloom, specs, *, xp) -> tuple:
    """Tri-state tile resolution against the chain's CNF structure.

    Returns (pass_tiles bool[T], fail_tiles bool[T]). A group provably
    passes a tile iff ANY member provably passes every row; it provably
    fails iff EVERY member provably fails every row. The tile passes the
    chain iff every group passes, fails iff any group fails. Evaluation
    order is irrelevant (proofs are order-free), so resolution needs no
    ``perm`` — the adopted permutation only steers the ambiguous tiles'
    row-level work.
    """
    rows = host_pred_rows(specs)
    n_tiles = mins.shape[1]
    all_pass, all_fail = [], []
    for col, op, t1, t2 in rows:
        mn, mx = mins[col], maxs[col]
        if op == pred_lib.OP_GT:
            ap, af = mn > t1, mx <= t1
        elif op == pred_lib.OP_LT:
            ap, af = mx < t1, mn >= t1
        elif op == pred_lib.OP_BETWEEN:
            ap = (mn > t1) & (mx < t2)
            af = (mx <= t1) | (mn >= t2)
        elif op == pred_lib.OP_EQ:
            r1 = eq_round(t1)
            rmn, rmx = xp.round(mn), xp.round(mx)
            ap = (rmn == r1) & (rmx == r1)
            af = (rmn > r1) | (rmx < r1)
            if bloom is not None:
                af = af | ~bloom[col, :, bloom_key(t1)]
        else:                                   # OP_HASHMIX: never provable
            ap = xp.zeros((n_tiles,), bool)
            af = xp.zeros((n_tiles,), bool)
        all_pass.append(ap)
        all_fail.append(af)

    groups = specs.groups
    pass_t = xp.ones((n_tiles,), bool)
    fail_t = xp.zeros((n_tiles,), bool)
    for members in specs.group_members:
        gp = all_pass[members[0]]
        gf = all_fail[members[0]]
        for m in members[1:]:
            gp = gp | all_pass[m]
            gf = gf & all_fail[m]
        pass_t = pass_t & gp
        fail_t = fail_t | gf
    return pass_t & ~fail_t, fail_t


def triage(columns, specs, *, bloom: bool, xp) -> SkipInfo:
    """Summaries + resolution in one call (the engine ``triage`` body)."""
    mins, maxs, bl = tile_summaries(columns, bloom=bloom, xp=xp)
    pass_t, fail_t = resolve_tiles(mins, maxs, bl, specs, xp=xp)
    n_amb = (~(pass_t | fail_t)).sum().astype(np.int32) if xp is np \
        else (~(pass_t | fail_t)).sum(dtype=np.int32)
    return SkipInfo(pass_tiles=pass_t, fail_tiles=fail_t, n_ambiguous=n_amb)


def quantize_amb_cap(n_ambiguous: int, n_tiles: int) -> int:
    """Static gather width (in tiles) for the jnp skip path.

    Rounded up to ``AMBIG_QUANTUM_TILES`` so the jit cache sees a bounded
    set of widths, capped at the batch's tile count (shuffled layouts peg
    at the full width — the tier then degenerates to the plain chain plus
    the summary pass, which is exactly what ``auto`` detects and disables).
    """
    q = AMBIG_QUANTUM_TILES
    want = max(int(n_ambiguous), 1)
    return min(int(-(-want // q)) * q, max(int(n_tiles), 1))


def tile_counters(skip: SkipInfo, xp):
    """(n_pass, n_fail, n_ambiguous) i32 scalars from a SkipInfo."""
    n_pass = skip.pass_tiles.sum(dtype=np.int32)
    n_fail = skip.fail_tiles.sum(dtype=np.int32)
    n_tiles = skip.pass_tiles.shape[0]
    return n_pass, n_fail, np.int32(n_tiles) - n_pass - n_fail


# --------------------------------------------------------- jnp skip chain
def run_chain_skip_jnp(columns, specs, perm, monitor, skip: SkipInfo,
                       *, amb_cap: int) -> ChainResult:
    """The jnp engine's skip-tier chain: gather → row-level → scatter.

    Only the ambiguous tiles' rows reach the row-level CNF evaluation: they
    are gathered into a static [C, amb_cap·SKIP_TILE] buffer (``amb_cap``
    from ``quantize_amb_cap`` — the caller syncs the ambiguous count once
    per step), evaluated there, and their mask scattered back; provably-
    pass tiles are bulk-set, provably-fail tiles stay cut. Unlike the
    masked off-path — which evaluates every predicate full-width — the
    expensive predicates here genuinely run at the ambiguous width, which
    is where the measured clustered-layout win comes from. The monitor
    lane runs on the FULL columns exactly as the off path does, so the
    ordering statistics are bit-identical with the tier on or off. Work
    counters charge only the (valid) ambiguous rows — the row-level work a
    short-circuiting engine behind this tier would actually do.
    """
    import jax.numpy as jnp

    from repro.core import filter_exec

    n_cols, n_rows = columns.shape
    n_tiles = skip.pass_tiles.shape[0]
    amb = ~(skip.pass_tiles | skip.fail_tiles)

    # gather map: the k-th ambiguous tile's index lands in slot k; tiles
    # beyond amb_cap (caller guarantees none) and non-ambiguous tiles dump
    pos = jnp.cumsum(amb.astype(jnp.int32)) - 1
    dest = jnp.where(amb & (pos < amb_cap), pos, amb_cap)
    tile_idx = jnp.full((amb_cap + 1,), n_tiles, jnp.int32) \
        .at[dest].set(jnp.arange(n_tiles, dtype=jnp.int32), mode="drop") \
        [:amb_cap]

    padded = pad_to_tiles(columns, xp=jnp)
    tiles = padded.reshape(n_cols, n_tiles, SKIP_TILE)
    g = jnp.take(tiles, tile_idx, axis=1, mode="fill",
                 fill_value=float("nan"))
    gcols = g.reshape(n_cols, amb_cap * SKIP_TILE)
    gid = tile_idx[:, None] * SKIP_TILE + jnp.arange(SKIP_TILE)[None, :]
    valid = (gid < n_rows).reshape(-1)           # unused slots + ragged tail

    amb_mask, work, active = filter_exec.run_chain_masks(
        gcols, specs, perm, valid=valid)

    mask_tiles = jnp.broadcast_to(skip.pass_tiles[:, None],
                                  (n_tiles, SKIP_TILE))
    mask_tiles = mask_tiles.at[tile_idx].set(
        amb_mask.reshape(amb_cap, SKIP_TILE), mode="drop")
    mask = mask_tiles.reshape(-1)[:n_rows]

    cut, gcut, n_mon, mon_cost = filter_exec.run_monitor(
        columns, specs, monitor.collect_rate, monitor.sample_phase)

    n_pass_t, n_fail_t, n_amb_t = tile_counters(skip, jnp)
    return ChainResult(
        mask=mask, work_units=work, active_before=active,
        cut_counts=cut, n_monitored=n_mon, monitor_cost=mon_cost,
        group_cut_counts=gcut,
        n_tiles_pass=n_pass_t, n_tiles_fail=n_fail_t,
        n_tiles_ambiguous=n_amb_t)


# ------------------------------------------------------------- auto tuner
class SkipTierTuner:
    """Online decision for ``skip_tier="auto"`` (one per session).

    Deterministic schedule, two arms ("off" vs the zone-map tier): the
    first ``2·warmup`` steps alternate arms to seed both EMAs (each arm's
    first sample is discarded — it pays compilation); afterwards the
    faster EMA wins, re-probing the losing arm every ``probe_period``
    steps so drifting layouts can flip the decision. One structural rule
    overrides the clocks: when the observed ambiguous-tile fraction says
    nearly every tile reaches the row-level kernel anyway
    (``>= ambig_off_frac``), the tier provably cannot pay — choose "off"
    without waiting for wall-clock evidence. That is the graceful
    degradation on shuffled layouts, and it is what the conformance test
    pins (timing EMAs alone would be CI-noise-flaky).
    """

    def __init__(self, on_mode: str, *, warmup: int = 3,
                 probe_period: int = 64, ambig_off_frac: float = 0.9,
                 ema: float = 0.3):
        if on_mode not in ("zonemap", "zonemap+bloom"):
            raise ValueError(on_mode)
        self.on_mode = on_mode
        self.warmup = warmup
        self.probe_period = probe_period
        self.ambig_off_frac = ambig_off_frac
        self.ema = ema
        self.step_idx = 0
        self.us_ema = {"off": None, on_mode: None}
        self.samples = {"off": 0, on_mode: 0}
        self.ambig_frac: float | None = None

    @property
    def active_mode(self) -> str:
        """The arm a non-probe step would run right now."""
        if self.ambig_frac is not None \
                and self.ambig_frac >= self.ambig_off_frac:
            return "off"
        on, off = self.us_ema[self.on_mode], self.us_ema["off"]
        if on is None:
            return self.on_mode
        if off is None:
            return "off"
        return self.on_mode if on <= off else "off"

    def choose(self) -> str:
        """Arm for the CURRENT step (advance with ``observe`` afterwards)."""
        if self.step_idx < 2 * self.warmup:
            return self.on_mode if self.step_idx % 2 == 0 else "off"
        active = self.active_mode
        if self.probe_period and self.step_idx % self.probe_period == 0:
            other = "off" if active != "off" else self.on_mode
            # never probe the tier back on when the layout structurally
            # rules it out — that is the adversarial case auto defends
            if not (other != "off" and self.ambig_frac is not None
                    and self.ambig_frac >= self.ambig_off_frac):
                return other
        return active

    def observe(self, mode: str, us_per_row: float,
                ambig_frac: float | None = None) -> None:
        self.step_idx += 1
        if ambig_frac is not None:
            self.ambig_frac = float(ambig_frac)
        self.samples[mode] += 1
        if self.samples[mode] <= 1:
            return                     # first sample per arm pays compile
        prev = self.us_ema[mode]
        self.us_ema[mode] = us_per_row if prev is None \
            else (1 - self.ema) * prev + self.ema * us_per_row
