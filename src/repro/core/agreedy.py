"""Beyond-paper extension: A-greedy-style conditional-selectivity ordering.

The paper names A-greedy [Babu et al., SIGMOD'04] as future work (§4). The
rank ordering is only optimal when predicate outcomes are independent; under
correlation, ordering by *conditional* selectivity does better. Because the
monitor lane already evaluates every predicate on every sampled row (that is
the paper's own bias-avoidance trick), the full outcome matrix is available
for free — we accumulate pairwise pass counts and order greedily:

  1. first predicate: min unconditional rank  c_i / (1 - s_i)
  2. next: min  c_j / (1 - s_{j|S})  where the conditional pass fraction
     given the already-chosen set S is approximated from pairwise counts by
     min_{i∈S} P(pass j | pass i)  — exact for chains of pairwise-dominant
     correlations, conservative otherwise (documented approximation; the
     full profile of Babu et al. needs O(2^P) counters).

Used by ``benchmarks/fig1_permutations.py --strategy agreedy`` and compared
against the paper-faithful rank policy in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-6


class PairStats(NamedTuple):
    pass_count: jnp.ndarray   # f32[P]   rows passing i
    pair_pass: jnp.ndarray    # f32[P,P] rows passing both i and j
    n: jnp.ndarray            # f32[]    monitored rows


def init_pair_stats(n_predicates: int) -> PairStats:
    return PairStats(
        pass_count=jnp.zeros((n_predicates,), jnp.float32),
        pair_pass=jnp.zeros((n_predicates, n_predicates), jnp.float32),
        n=jnp.zeros((), jnp.float32),
    )


def accumulate_pairs(stats: PairStats, results: jnp.ndarray,
                     valid: jnp.ndarray) -> PairStats:
    """``results``: bool[P, M] monitor-lane outcomes; ``valid``: bool[M]."""
    r = jnp.logical_and(results, valid[None, :]).astype(jnp.float32)
    return PairStats(
        pass_count=stats.pass_count + jnp.sum(r, axis=1),
        pair_pass=stats.pair_pass + r @ r.T,
        n=stats.n + jnp.sum(valid).astype(jnp.float32),
    )


def conditional_greedy_order(stats: PairStats, costs: jnp.ndarray) -> jnp.ndarray:
    """Greedy conditional-rank ordering (host-side; P is tiny)."""
    import numpy as np

    p = int(costs.shape[0])
    n = float(jnp.maximum(stats.n, 1.0))
    passc = np.asarray(stats.pass_count, dtype=np.float64)
    pair = np.asarray(stats.pair_pass, dtype=np.float64)
    c = np.asarray(costs, dtype=np.float64)
    c = c / max(c.max(), _EPS)

    s_uncond = np.clip(passc / n, 0.0, 1.0)
    remaining = list(range(p))
    order: list[int] = []
    while remaining:
        best, best_rank = None, None
        for j in remaining:
            if not order:
                s = s_uncond[j]
            else:
                # min over chosen i of P(pass j | pass i)
                conds = [pair[i, j] / max(passc[i], 1.0) for i in order]
                s = float(np.clip(min(conds), 0.0, 1.0))
            rank = c[j] / max(1.0 - s, _EPS)
            if best_rank is None or rank < best_rank:
                best, best_rank = j, rank
        order.append(best)
        remaining.remove(best)
    return jnp.asarray(order, jnp.int32)
