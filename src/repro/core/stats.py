"""Per-predicate statistics: the paper's numCut / cost arrays and rank math.

Faithful to §2.1 of the paper:

  * ``num_cut[i]``   — monitored rows that did NOT satisfy predicate i
  * ``cost_acc[i]``  — accumulated evaluation cost attributed to predicate i
  * selectivity      s_i  = 1 - num_cut_i / n_monitored        (pass fraction)
  * normalized cost  nc_i = avg_cost_i / max_j avg_cost_j  ∈ [0, 1]
  * rank             rank_i = nc_i / (1 - s_i)
  * momentum         adj_rank_i(t) = (1-m)·rank_i(t) + m·adj_rank_i(t-1)

Ordering predicates by adj_rank ascending minimizes the expected per-row
chain cost  Σ_i c_i Π_{j<i} s_j  (see tests/test_property_hypothesis.py for
the machine-checked proof-by-enumeration).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-6


class FilterStats(NamedTuple):
    """Accumulators collected since the start of the current epoch."""

    num_cut: jnp.ndarray      # f32[P]
    cost_acc: jnp.ndarray     # f32[P]
    n_monitored: jnp.ndarray  # f32[]


def init_stats(n_predicates: int) -> FilterStats:
    return FilterStats(
        num_cut=jnp.zeros((n_predicates,), jnp.float32),
        cost_acc=jnp.zeros((n_predicates,), jnp.float32),
        n_monitored=jnp.zeros((), jnp.float32),
    )


def merge_stats(a: FilterStats, b: FilterStats) -> FilterStats:
    """Associative merge (used by the centralized scope's psum and by tests)."""
    return FilterStats(a.num_cut + b.num_cut, a.cost_acc + b.cost_acc,
                       a.n_monitored + b.n_monitored)


def accumulate(stats: FilterStats, cut_counts: jnp.ndarray,
               costs: jnp.ndarray, n_monitored) -> FilterStats:
    """Fold one batch's monitor-lane results into the epoch accumulators."""
    return FilterStats(
        num_cut=stats.num_cut + cut_counts.astype(jnp.float32),
        cost_acc=stats.cost_acc + costs.astype(jnp.float32),
        n_monitored=stats.n_monitored + jnp.asarray(n_monitored, jnp.float32),
    )


def selectivities(stats: FilterStats) -> jnp.ndarray:
    """Pass fraction per predicate, from monitored rows only (paper §2.1)."""
    n = jnp.maximum(stats.n_monitored, 1.0)
    s = 1.0 - stats.num_cut / n
    return jnp.clip(s, 0.0, 1.0)


def normalized_costs(stats: FilterStats) -> jnp.ndarray:
    """Average per-row cost, min-max-free normalization to [0,1] by the max."""
    n = jnp.maximum(stats.n_monitored, 1.0)
    avg = stats.cost_acc / n
    return avg / jnp.maximum(jnp.max(avg), _EPS)


def ranks(stats: FilterStats) -> jnp.ndarray:
    """rank_i = nc_i / (1 - s_i); selective-and-cheap predicates rank lowest.

    The 1-s denominator is floored so an all-pass predicate gets a large but
    finite rank (it should run last — it cuts nothing).
    """
    s = selectivities(stats)
    nc = normalized_costs(stats)
    return nc / jnp.maximum(1.0 - s, _EPS)


def momentum_update(adj_prev: jnp.ndarray, rank_now: jnp.ndarray,
                    momentum, first_epoch) -> jnp.ndarray:
    """First-order difference equation from the paper, with cold-start.

    On the very first epoch there is no history: adj_rank(0) = rank(0)
    (equivalently momentum is ignored once).
    """
    m = jnp.asarray(momentum, jnp.float32)
    blended = (1.0 - m) * rank_now + m * adj_prev
    return jnp.where(first_epoch, rank_now, blended)


def order_from_ranks(adj_rank: jnp.ndarray) -> jnp.ndarray:
    """Ascending stable sort → evaluation permutation (ties by user order)."""
    return jnp.argsort(adj_rank, stable=True).astype(jnp.int32)


def expected_chain_cost(costs: jnp.ndarray, pass_probs: jnp.ndarray,
                        perm: jnp.ndarray) -> jnp.ndarray:
    """Σ_i c_{perm[i]} Π_{j<i} s_{perm[j]} — the quantity rank order minimizes."""
    c = costs[perm]
    s = pass_probs[perm]
    surv = jnp.concatenate([jnp.ones((1,), s.dtype), jnp.cumprod(s)[:-1]])
    return jnp.sum(c * surv)
