"""Per-predicate statistics: the paper's numCut / cost arrays and rank math.

Faithful to §2.1 of the paper:

  * ``num_cut[i]``   — monitored rows that did NOT satisfy predicate i
  * ``cost_acc[i]``  — accumulated evaluation cost attributed to predicate i
  * selectivity      s_i  = 1 - num_cut_i / n_monitored        (pass fraction)
  * normalized cost  nc_i = avg_cost_i / max_j avg_cost_j  ∈ [0, 1]
  * rank             rank_i = nc_i / (1 - s_i)
  * momentum         adj_rank_i(t) = (1-m)·rank_i(t) + m·adj_rank_i(t-1)

Ordering predicates by adj_rank ascending minimizes the expected per-row
chain cost  Σ_i c_i Π_{j<i} s_j  (see tests/test_property_hypothesis.py for
the machine-checked proof-by-enumeration).

CNF extension (AND of OR-groups): the same machinery lifts to *groups* —

  * ``group_cut[g]`` — monitored rows cut by group g (no member passed)
  * group selectivity  S_g = 1 - group_cut_g / n_monitored  (exact, not the
    independence product — the monitor lane sees the full outcome matrix)
  * group cost         Σ_{i∈g} avg_cost_i, normalized by the max group
  * group rank         gnc_g / (1 - S_g); groups evaluated rank-ascending
  * within a group, members are ordered by miss-rate: an OR short-circuits
    on the first PASS, so cheap high-pass-rate members go first
    (member rank = nc_i / s_i — the conjunction formula with s ↔ 1-s).

Every function here is **backend-agnostic**: it takes an array-namespace
argument ``xp`` (``jax.numpy`` or ``numpy``) and runs the identical code
path on either, so there is exactly one implementation of the rank math for
the jitted device pipeline and the host (numpy) streaming path. A parity
test pins the two bit-close.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

_EPS = 1e-6

#: Saturation guard for the f32 epoch accumulators. f32 stops absorbing
#: +1-sized increments at 2^24 (ulp = 2), which silently freezes the
#: selectivity/cost estimates — and with them the adaptive ordering — on
#: long epochs (collect_rate=1 with a 10^8-row calculate_rate is a real
#: long-stream config). ``accumulate`` therefore decays every accumulator
#: by ``SAT_DECAY`` whenever ``n_monitored`` crosses ``SAT_THRESHOLD``:
#: multiplying an f32 by 0.5 only decrements the exponent (exact, never
#: rounds), so selectivities and average costs — the RATIOS the rank math
#: consumes — are preserved bit-for-bit, rank order is untouched, and the
#: accumulators stay in a range where integer increments remain exact.
#: Within a super-long epoch the evidence becomes exponentially weighted
#: toward recent batches, which is the behavior an *adaptive* filter
#: wants anyway. Epochs shorter than SAT_THRESHOLD monitored rows (every
#: paper configuration) never trigger it: the scale factor is exactly 1.0
#: and ``x * 1.0`` is a bit-exact no-op.
SAT_THRESHOLD = float(1 << 22)
SAT_DECAY = 0.5


def argsort_stable(a, xp=jnp):
    """Stable ascending argsort; the only API seam between numpy and jnp."""
    if xp is jnp:
        return jnp.argsort(a, stable=True)
    return np.argsort(a, kind="stable")


class FilterStats(NamedTuple):
    """Accumulators collected since the start of the current epoch.

    ``group_cut`` is None for consumers that predate CNF (flat chains treat
    every predicate as its own group, where group_cut ≡ num_cut).
    """

    num_cut: Any       # f32[P]
    cost_acc: Any      # f32[P]
    n_monitored: Any   # f32[]
    group_cut: Any = None  # f32[G] | None


def init_stats(n_predicates: int, n_groups: int | None = None,
               xp=jnp) -> FilterStats:
    if n_groups is None:
        n_groups = n_predicates
    return FilterStats(
        num_cut=xp.zeros((n_predicates,), xp.float32),
        cost_acc=xp.zeros((n_predicates,), xp.float32),
        n_monitored=xp.zeros((), xp.float32),
        group_cut=xp.zeros((n_groups,), xp.float32),
    )


def merge_stats(a: FilterStats, b: FilterStats) -> FilterStats:
    """Associative merge (used by the centralized scope's psum and by tests)."""
    gc = None
    if a.group_cut is not None and b.group_cut is not None:
        gc = a.group_cut + b.group_cut
    return FilterStats(a.num_cut + b.num_cut, a.cost_acc + b.cost_acc,
                       a.n_monitored + b.n_monitored, gc)


def accumulate(stats: FilterStats, cut_counts, costs, n_monitored,
               group_cut=None, xp=jnp) -> FilterStats:
    """Fold one batch's monitor-lane results into the epoch accumulators.

    Saturation guard (see ``SAT_THRESHOLD``): once the epoch has monitored
    2^22 rows, every accumulator is decayed by the exact-in-f32 factor 0.5
    BEFORE the batch folds in, so increments keep landing in a range where
    f32 absorbs them and the adaptive ordering never freezes. The decay is
    branchless (``xp.where`` on a scalar) and a bit-exact no-op (×1.0)
    below the threshold; because ``n_monitored`` advances deterministically
    (static batch widths), sharded replicas trigger it in lockstep.
    """
    scale = xp.where(stats.n_monitored >= SAT_THRESHOLD,
                     xp.float32(SAT_DECAY), xp.float32(1.0))
    if stats.group_cut is None:
        new_gc = None
    else:
        inc = cut_counts if group_cut is None else group_cut
        new_gc = stats.group_cut * scale + inc.astype(xp.float32)
    return FilterStats(
        num_cut=stats.num_cut * scale + cut_counts.astype(xp.float32),
        cost_acc=stats.cost_acc * scale + costs.astype(xp.float32),
        n_monitored=stats.n_monitored * scale
        + xp.asarray(n_monitored, xp.float32),
        group_cut=new_gc,
    )


def selectivities(stats: FilterStats, xp=jnp):
    """Pass fraction per predicate, from monitored rows only (paper §2.1)."""
    n = xp.maximum(stats.n_monitored, 1.0)
    s = 1.0 - stats.num_cut / n
    return xp.clip(s, 0.0, 1.0)


def normalized_costs(stats: FilterStats, xp=jnp):
    """Average per-row cost, min-max-free normalization to [0,1] by the max."""
    n = xp.maximum(stats.n_monitored, 1.0)
    avg = stats.cost_acc / n
    return avg / xp.maximum(xp.max(avg), _EPS)


def ranks(stats: FilterStats, xp=jnp):
    """rank_i = nc_i / (1 - s_i); selective-and-cheap predicates rank lowest.

    The 1-s denominator is floored so an all-pass predicate gets a large but
    finite rank (it should run last — it cuts nothing).
    """
    s = selectivities(stats, xp=xp)
    nc = normalized_costs(stats, xp=xp)
    return nc / xp.maximum(1.0 - s, _EPS)


def member_ranks(stats: FilterStats, xp=jnp):
    """Within-OR-group order key: nc_i / s_i ascending.

    An OR group short-circuits when a member PASSES, so the optimal member
    order puts cheap, *high*-pass-rate (low miss-rate) members first — the
    mirror image of the conjunction rank (s ↔ 1-s).
    """
    s = selectivities(stats, xp=xp)
    nc = normalized_costs(stats, xp=xp)
    return nc / xp.maximum(s, _EPS)


def _group_matrix(groups, xp=jnp):
    """f32[G, P] membership one-hot built from the static group tuple."""
    g = np.asarray(groups, np.int64)
    m = np.zeros((int(g.max()) + 1, len(groups)), np.float32)
    m[g, np.arange(len(groups))] = 1.0
    return xp.asarray(m)


def group_selectivities(stats: FilterStats, xp=jnp):
    """Exact P(group passes) from the monitor lane's group-cut counters."""
    gcut = stats.group_cut if stats.group_cut is not None else stats.num_cut
    n = xp.maximum(stats.n_monitored, 1.0)
    return xp.clip(1.0 - gcut / n, 0.0, 1.0)


def group_normalized_costs(stats: FilterStats, groups, xp=jnp):
    """Group cost = Σ member avg costs, normalized to [0,1] by the max group.

    For all-singleton groups this reduces exactly to ``normalized_costs``
    (same max normalizer), keeping flat chains bit-identical to the paper
    math.
    """
    n = xp.maximum(stats.n_monitored, 1.0)
    avg = stats.cost_acc / n
    gavg = _group_matrix(groups, xp=xp) @ avg
    return gavg / xp.maximum(xp.max(gavg), _EPS)


def group_ranks(stats: FilterStats, groups, xp=jnp):
    """Group-level rank = gnc_g / (1 - S_g); ≡ ``ranks`` on flat chains."""
    s = group_selectivities(stats, xp=xp)
    nc = group_normalized_costs(stats, groups, xp=xp)
    return nc / xp.maximum(1.0 - s, _EPS)


def momentum_update(adj_prev, rank_now, momentum, first_epoch, xp=jnp):
    """First-order difference equation from the paper, with cold-start.

    On the very first epoch there is no history: adj_rank(0) = rank(0)
    (equivalently momentum is ignored once).
    """
    m = xp.asarray(momentum, xp.float32)
    blended = (1.0 - m) * rank_now + m * adj_prev
    return xp.where(first_epoch, rank_now, blended)


def order_from_ranks(adj_rank, xp=jnp):
    """Ascending stable sort → evaluation permutation (ties by user order)."""
    return argsort_stable(adj_rank, xp=xp).astype(xp.int32)


def cnf_order(group_adj_rank, member_rank, groups, xp=jnp):
    """Full CNF evaluation order from group + member ranks.

    Returns (perm i32[P], group_perm i32[G]): groups concatenated in
    group-rank-ascending order (ties by group id), members within each group
    in member-rank-ascending order (ties by user order). Group members are
    always CONTIGUOUS in ``perm`` — the execution engines rely on that to
    close one OR accumulator at a time.

    Built from two composed stable sorts so it is traceable under jit with
    dynamic ranks: the primary key is each group's *position* in the sorted
    group order (a distinct integer per group, so equal group ranks can
    never interleave members of different groups).
    """
    garr = xp.asarray(np.asarray(groups, np.int32))
    group_perm = argsort_stable(group_adj_rank, xp=xp).astype(xp.int32)
    group_pos = argsort_stable(group_perm, xp=xp)   # inverse permutation
    primary = group_pos[garr]                        # i32[P]
    by_member = argsort_stable(member_rank, xp=xp)
    perm = by_member[argsort_stable(primary[by_member], xp=xp)]
    return perm.astype(xp.int32), group_perm


def expected_chain_cost(costs, pass_probs, perm, xp=jnp):
    """Σ_i c_{perm[i]} Π_{j<i} s_{perm[j]} — the quantity rank order minimizes."""
    c = costs[perm]
    s = pass_probs[perm]
    surv = xp.concatenate([xp.ones((1,), s.dtype), xp.cumprod(s)[:-1]])
    return xp.sum(c * surv)
