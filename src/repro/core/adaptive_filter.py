"""The user-facing adaptive filter operator.

This is the framework's analogue of the paper's Catalyst extension: a
pipeline stage that can replace any static conjunctive filter. Plug it into
``repro.data.pipeline.Pipeline`` (ingestion for training) or call
``step``/``process_stream`` directly (serving guardrails, benchmarks).

  cfg.adaptive=False  → behaves exactly like Spark's default Filter
                        (user-statement order, no monitoring) — the paper's
                        baseline, kept so both can be benchmarked.
  cfg.backend         → "jnp" (jit-able vectorized), "pallas" (fused TPU
                        kernel; interpret-mode on CPU), "numpy" (row-exact
                        host path used by benchmarks).
  cfg.cost_mode       → "static" (calibrated per-predicate weights; works
                        inside jit) or "measured" (host clock per predicate
                        per batch over the monitor sample — the paper's
                        System.nanoTime, at epoch granularity).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter_exec, np_exec
from repro.core import ordering as ordering_lib
from repro.core import predicates as pred_lib
from repro.core.ordering import OrderingConfig, OrderState
from repro.core.predicates import Predicate
from repro.core.scope import Scope, reduce_stats, scope_from_str


@dataclasses.dataclass(frozen=True)
class AdaptiveFilterConfig:
    ordering: OrderingConfig = OrderingConfig()
    scope: str = "per_shard"
    cost_mode: str = "static"
    backend: str = "jnp"
    adaptive: bool = True
    compact_output: bool = False

    def __post_init__(self) -> None:
        scope_from_str(self.scope)
        if self.cost_mode not in ("static", "measured"):
            raise ValueError(f"bad cost_mode {self.cost_mode}")
        if self.backend not in ("jnp", "pallas", "numpy"):
            raise ValueError(f"bad backend {self.backend}")
        if self.cost_mode == "measured" and self.backend != "numpy":
            raise ValueError("measured cost mode needs the host (numpy) backend")


class StepMetrics(NamedTuple):
    work_units: jnp.ndarray     # row-level cost-weighted work for this batch
    n_pass: jnp.ndarray         # surviving rows
    perm: jnp.ndarray           # order used for this batch
    epoch: jnp.ndarray          # epochs completed so far
    adj_rank: jnp.ndarray       # current smoothed ranks


class AdaptiveFilter:
    """Adaptive conjunctive filter with epoch-based predicate reordering."""

    def __init__(self, predicates: Sequence[Predicate],
                 config: AdaptiveFilterConfig | None = None,
                 axis_names: Sequence[str] = ()):
        if not predicates:
            raise ValueError("need at least one predicate")
        self.predicates = list(predicates)
        self.config = config or AdaptiveFilterConfig()
        self.specs = pred_lib.pack(self.predicates)
        self.axis_names = tuple(axis_names)
        self._scope = scope_from_str(self.config.scope)

    # ---------------------------------------------------------------- state
    def init_state(self) -> OrderState:
        return ordering_lib.init_order_state(len(self.predicates))

    # ----------------------------------------------------------- jit'd step
    def step(self, state: OrderState, columns: jnp.ndarray,
             measured_costs: jnp.ndarray | None = None
             ) -> tuple[OrderState, jnp.ndarray, StepMetrics]:
        """One micro-batch: filter + monitor + (maybe) epoch re-rank.

        ``columns``: f32[C, R]. jit/shard_map-compatible for jnp/pallas
        backends. Returns (new_state, mask bool[R], metrics).
        """
        cfg = self.config
        perm = state.perm if cfg.adaptive else jnp.arange(
            len(self.predicates), dtype=jnp.int32)

        if cfg.backend == "pallas":
            from repro.kernels.filter_chain import ops as kernel_ops
            res = kernel_ops.filter_chain(
                columns, self.specs, perm,
                collect_rate=cfg.ordering.collect_rate,
                sample_phase=state.sample_phase)
        else:
            res = filter_exec.run_chain(
                columns, self.specs, perm,
                collect_rate=cfg.ordering.collect_rate,
                sample_phase=state.sample_phase)

        costs = res.monitor_cost if measured_costs is None else measured_costs

        if cfg.adaptive:
            if self._scope is Scope.PER_BATCH:
                state = self.init_state()
            stats_in = filter_exec.ChainResult(*res)  # no-op; keeps names clear
            cut, n_mon = stats_in.cut_counts, stats_in.n_monitored
            if self._scope is Scope.CENTRALIZED and self.axis_names:
                from repro.core.stats import FilterStats
                merged = reduce_stats(
                    FilterStats(cut, costs, n_mon), self._scope, self.axis_names)
                cut, costs, n_mon = merged.num_cut, merged.cost_acc, merged.n_monitored
            new_state = ordering_lib.advance(
                state, cfg.ordering, cut, costs, n_mon,
                n_rows=int(columns.shape[1]))
        else:
            new_state = state._replace(
                sample_phase=(state.sample_phase + columns.shape[1])
                % cfg.ordering.collect_rate)

        metrics = StepMetrics(
            work_units=res.work_units,
            n_pass=jnp.sum(res.mask.astype(jnp.int32)),
            perm=perm,
            epoch=new_state.epoch,
            adj_rank=new_state.adj_rank,
        )
        return new_state, res.mask, metrics

    # ------------------------------------------------------- host streaming
    def process_stream(self, batches: Iterable[np.ndarray]
                       ) -> Iterator[tuple[np.ndarray, np.ndarray, dict]]:
        """Drive the filter over a host-side stream of f32[C, R] batches.

        Yields (surviving_rows f32[C, n_pass], mask, metrics_dict) per batch.
        Uses the numpy backend when configured (row-exact wall time,
        measured costs); otherwise calls the jitted step.
        """
        cfg = self.config
        if cfg.backend == "numpy":
            yield from self._process_stream_numpy(batches)
            return

        jit_step = jax.jit(self.step)
        state = self.init_state()
        for batch in batches:
            cols = jnp.asarray(batch, jnp.float32)
            state, mask, metrics = jit_step(state, cols)
            mask_np = np.asarray(mask)
            yield batch[:, mask_np], mask_np, {
                "work_units": float(metrics.work_units),
                "n_pass": int(metrics.n_pass),
                "perm": np.asarray(metrics.perm).tolist(),
                "epoch": int(metrics.epoch),
            }

    def _process_stream_numpy(self, batches):
        cfg = self.config
        preds = self.predicates
        n_preds = len(preds)
        state = _HostOrderState(n_preds, cfg.ordering)
        for batch in batches:
            perm = state.perm if cfg.adaptive else np.arange(n_preds)
            mask, work, _ = np_exec.run_chain_np(batch, preds, perm)
            if cfg.adaptive:
                cut, n_mon, secs = np_exec.run_monitor_np(
                    batch, preds, cfg.ordering.collect_rate, state.sample_phase)
                if cfg.cost_mode == "measured":
                    costs = secs
                else:
                    costs = np.array([p.static_cost for p in preds]) * n_mon
                state.advance(cut, costs, n_mon, batch.shape[1])
            else:
                state.sample_phase = (state.sample_phase + batch.shape[1]) \
                    % cfg.ordering.collect_rate
            yield batch[:, mask], mask, {
                "work_units": work,
                "n_pass": int(mask.sum()),
                "perm": [int(i) for i in perm],
                "epoch": state.epoch,
            }


class _HostOrderState:
    """Numpy mirror of ``OrderState`` (same math, host types)."""

    def __init__(self, n_preds: int, cfg: OrderingConfig):
        self.cfg = cfg
        self.perm = np.arange(n_preds)
        self.adj_rank = np.zeros(n_preds, np.float64)
        self.num_cut = np.zeros(n_preds, np.float64)
        self.cost_acc = np.zeros(n_preds, np.float64)
        self.n_monitored = 0.0
        self.rows_into_epoch = 0
        self.sample_phase = 0
        self.epoch = 0

    def advance(self, cut, costs, n_mon, n_rows):
        self.num_cut += cut
        self.cost_acc += np.asarray(costs, np.float64)
        self.n_monitored += n_mon
        self.rows_into_epoch += n_rows
        self.sample_phase = (self.sample_phase + n_rows) % self.cfg.collect_rate
        if self.rows_into_epoch >= self.cfg.calculate_rate:
            self._epoch_update()
            self.rows_into_epoch %= self.cfg.calculate_rate

    def _epoch_update(self):
        if self.n_monitored <= 0:
            return
        n = max(self.n_monitored, 1.0)
        s = np.clip(1.0 - self.num_cut / n, 0.0, 1.0)
        avg = self.cost_acc / n
        nc = avg / max(avg.max(), 1e-12)
        rank = nc / np.maximum(1.0 - s, 1e-6)
        m = self.cfg.momentum
        self.adj_rank = rank if self.epoch == 0 else (1 - m) * rank + m * self.adj_rank
        if self.cfg.snap_threshold > 0.0 and self.epoch > 0:
            def cost_of(perm):
                surv = np.concatenate([[1.0], np.cumprod(s[perm])[:-1]])
                return float(np.sum(nc[perm] * surv))
            fresh = np.argsort(rank, kind="stable")
            if cost_of(self.perm) > self.cfg.snap_threshold * cost_of(fresh):
                self.adj_rank = rank          # snap: drop stale momentum
        self.perm = np.argsort(self.adj_rank, kind="stable")
        self.num_cut[:] = 0.0
        self.cost_acc[:] = 0.0
        self.n_monitored = 0.0
        self.epoch += 1


def static_filter(predicates: Sequence[Predicate],
                  order: Sequence[int] | None = None,
                  backend: str = "jnp") -> AdaptiveFilter:
    """Spark's default Filter: fixed order, no monitoring overhead.

    ``order`` permutes the user statement order up-front (used by the fig-1
    benchmark to sweep all 24 static orders).
    """
    preds = list(predicates)
    if order is not None:
        preds = [preds[i] for i in order]
    cfg = AdaptiveFilterConfig(adaptive=False, backend=backend)
    return AdaptiveFilter(preds, cfg)
