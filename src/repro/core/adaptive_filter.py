"""The adaptive filter operator: the functional step math.

This is the framework's analogue of the paper's Catalyst extension: a
pipeline stage that can replace any static conjunctive (or CNF) filter.
The USER-FACING surface is the plan/session API (``core.plan.FilterPlan``
→ ``core.session.build_session`` → one ``session.step``); this class is
the math core a session compiles — ``step``/``_step_compact`` (and their
skip-tier variants) are pure functions of (state, batch) traced under
jit/shard_map.

All execution semantics live behind the ``FilterEngine`` registry
(``core/engine/``) and all ordering math in ``core.ordering`` /
``core.stats`` (one implementation, numpy or jnp via the ``xp`` namespace
argument) — this module only wires them together:

  cfg.adaptive=False  → behaves exactly like Spark's default Filter
                        (user-statement order, no monitoring) — the paper's
                        baseline, kept so both can be benchmarked.
  cfg.backend         → any registered engine: "jnp" (jit-able vectorized),
                        "pallas" (fused TPU kernel; interpret-mode on CPU),
                        "numpy" (row-exact host path used by benchmarks).
  cfg.cost_mode       → "static" (calibrated per-predicate weights; works
                        inside jit) or "measured" (host clock per predicate
                        per batch over the monitor sample — the paper's
                        System.nanoTime, at epoch granularity).
  cfg.exchange        → when CENTRALIZED: "eager" psum-merges monitor
                        counters every step; "deferred" accumulates locally
                        and issues ONE collective per ``calculate_rate``
                        rows at the epoch boundary (``exchange_update``,
                        driven by ``maybe_exchange``); "deferred-async"
                        additionally folds the merged stats in one epoch
                        LATE (the paper's deferred per-executor update,
                        generalized to the mesh).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Iterable, Iterator, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ordering as ordering_lib
from repro.core import predicates as pred_lib
from repro.core.engine import MonitorSpec, get_engine
from repro.core.ordering import OrderingConfig, OrderState
from repro.core import skip_tier as skip_tier_lib
from repro.core.plan import validate_combo
from repro.core.scope import Scope, reduce_stats, scope_from_str
from repro.core.predicates import Predicate
from repro.core.stats import FilterStats

log = logging.getLogger(__name__)

CAPACITY_QUANTUM = 128   # auto capacities are multiples of this (VPU lanes)


def drive_exchange(owner, state: OrderState) -> OrderState:
    """Shared deferred-exchange driver (host side).

    ``owner`` is an ``AdaptiveFilter`` or ``ShardedAdaptiveFilter`` — any
    object with ``config.exchange``, ``exchange_due``, the two jitted
    exchange callables, and a ``_pending_stats`` slot. One implementation so
    the subtle deferred-async stash semantics (first boundary falls back to
    the synchronous merge; stash is transient across restores) cannot drift
    between the single and sharded drivers.
    """
    if not owner.exchange_due(state):
        return state
    if owner.config.exchange == "deferred-async" \
            and owner._pending_stats is not None:
        state, merged = owner.jit_exchange_with(state, owner._pending_stats)
    else:
        state, merged = owner.jit_exchange(state)
    owner._pending_stats = merged \
        if owner.config.exchange == "deferred-async" else None
    return state


@dataclasses.dataclass(frozen=True)
class AdaptiveFilterConfig:
    ordering: OrderingConfig = OrderingConfig()
    scope: str = "per_shard"
    cost_mode: str = "static"
    backend: str = "jnp"
    adaptive: bool = True
    # Device-side survivor compaction: ``step_compact`` packs survivors
    # into a padded fixed-width [C, capacity] buffer + count entirely on
    # device (fused in-kernel for pallas, O(R) cumsum scatter for jnp), so
    # downstream stages never host-boolean-index the batch.
    #   capacity None   → batch width (lossless)
    #   capacity int    → fixed width (survivors beyond it are dropped and
    #                     counted in ``StepMetrics.n_dropped``)
    #   capacity "auto" → derived from the monitor lane's observed
    #                     pass-rate × batch width × ``compact_slack``,
    #                     re-quantized to a multiple of 128 at epoch
    #                     boundaries (bounded jit-cache churn).
    compact_output: bool = False
    compact_capacity: int | str | None = None
    compact_slack: float = 1.5
    # Statistics exchange cadence for the CENTRALIZED scope (see module
    # docstring): "eager" | "deferred" | "deferred-async".
    exchange: str = "eager"
    # Tile-statistics skip tier (``core.skip_tier``): "off" | "zonemap" |
    # "zonemap+bloom" | "auto". Zone maps (+ Bloom bits) resolve whole
    # 128-row tiles before the row-level chain; never changes survivors or
    # ordering statistics, only speed. "auto" is driven by the session.
    skip_tier: str = "off"

    def __post_init__(self) -> None:
        # every cross-field rule lives in ONE place: core.plan.validate_combo
        # (the FilterPlan docstring is the single source of truth for valid
        # combinations; this config is the legacy per-filter surface).
        validate_combo(scope=self.scope, cost_mode=self.cost_mode,
                       backend=self.backend,
                       compact_output=self.compact_output,
                       compact_capacity=self.compact_capacity,
                       compact_slack=self.compact_slack,
                       exchange=self.exchange,
                       skip_tier=self.skip_tier)


class StepMetrics(NamedTuple):
    work_units: jnp.ndarray     # row-level cost-weighted work for this batch
    n_pass: jnp.ndarray         # surviving rows (mask popcount)
    perm: jnp.ndarray           # order used for this batch
    epoch: jnp.ndarray          # epochs completed so far
    adj_rank: jnp.ndarray       # current smoothed GROUP ranks
    n_dropped: jnp.ndarray      # survivors lost to compact_capacity overflow
    # skip-tier tile counters (i32; all zero when the tier is off)
    n_tiles_pass: jnp.ndarray       # tiles bulk-kept by the zone-map proof
    n_tiles_fail: jnp.ndarray       # tiles dropped without row-level work
    n_tiles_ambiguous: jnp.ndarray  # tiles that reached the row-level chain


class AdaptiveFilter:
    """Adaptive CNF filter with epoch-based predicate/group reordering."""

    def __init__(self, predicates: Sequence[Predicate],
                 config: AdaptiveFilterConfig | None = None,
                 axis_names: Sequence[str] = ()):
        if not predicates:
            raise ValueError("need at least one predicate")
        self.predicates = list(predicates)
        self.config = config or AdaptiveFilterConfig()
        self.specs = pred_lib.pack(self.predicates)
        self.groups = self.specs.groups          # static CNF structure
        self.axis_names = tuple(axis_names)
        self._scope = scope_from_str(self.config.scope)
        self._engine = get_engine(self.config.backend)
        # the jit-traceable engine driving ``step`` (host engines run via
        # ``process_stream``; step falls back to the jnp reference engine)
        self._step_engine = self._engine if self._engine.traceable \
            else get_engine("jnp")
        self._jit_step = None
        self._jit_step_compact = None
        self._jit_step_triage = None
        self._jit_step_skip = None
        self._jit_step_skip_compact = None
        self._jit_exchange = None
        self._jit_exchange_with = None
        # deferred-async: merged stats from the previous boundary, applied
        # one epoch late (host-held; transient across checkpoint restarts —
        # the first post-restore boundary falls back to synchronous merge).
        self._pending_stats: FilterStats | None = None
        # auto-capacity: current quantized width + last epoch it was tuned
        self._auto_cap: int | None = None
        self._auto_cap_epoch = 0

    # ---------------------------------------------------------------- state
    def init_state(self, xp=jnp) -> OrderState:
        return ordering_lib.init_order_state(
            len(self.predicates), self.specs.n_groups, xp=xp)

    @property
    def jit_step(self):
        """``jax.jit(self.step)``, compiled once per instance and reused."""
        if self._jit_step is None:
            self._jit_step = jax.jit(self.step)
        return self._jit_step

    @property
    def _jit_compact(self):
        """Jitted ``_step_compact``; ``capacity`` is static (one compile per
        distinct quantized width — auto mode changes it only at epoch
        boundaries, in multiples of 128)."""
        if self._jit_step_compact is None:
            self._jit_step_compact = jax.jit(
                self._step_compact, static_argnames=("capacity",))
        return self._jit_step_compact

    # ------------------------------------------------------------ skip tier
    @property
    def _jit_triage(self):
        """Jitted zone-map triage; ``bloom`` is static (two traces max)."""
        if self._jit_step_triage is None:
            self._jit_step_triage = jax.jit(
                lambda columns, bloom: self._step_engine.triage(
                    columns, self.specs, bloom=bloom),
                static_argnames=("bloom",))
        return self._jit_step_triage

    @property
    def _jit_skip(self):
        """Jitted ``_step_skip``; ``amb_cap`` is static (quantized widths)."""
        if self._jit_step_skip is None:
            self._jit_step_skip = jax.jit(
                self._step_skip, static_argnames=("amb_cap",))
        return self._jit_step_skip

    @property
    def _jit_skip_compact(self):
        if self._jit_step_skip_compact is None:
            self._jit_step_skip_compact = jax.jit(
                self._step_skip_compact,
                static_argnames=("capacity", "amb_cap"))
        return self._jit_step_skip_compact

    def skip_on_mode(self) -> str:
        """The arm ``skip_tier="auto"`` tunes against "off": Bloom bits only
        pay when the chain has an equality predicate to consult them."""
        return "zonemap+bloom" \
            if any(p.op == pred_lib.OP_EQ for p in self.predicates) \
            else "zonemap"

    def skip_amb_cap(self, info, n_rows: int) -> int:
        """Static gather width (tiles) for one step — 0 when the engine
        predicates in-kernel instead of gathering (no host sync needed)."""
        if not getattr(self._step_engine, "skip_gathers", False):
            return 0
        n_tiles = -(-n_rows // skip_tier_lib.SKIP_TILE)
        return skip_tier_lib.quantize_amb_cap(int(info.n_ambiguous), n_tiles)

    # ----------------------------------------------------------- jit'd step
    def _advance_state(self, state: OrderState, res, costs,
                       n_rows: int) -> OrderState:
        """Fold one batch's monitor evidence into the order state."""
        cfg = self.config
        if not cfg.adaptive:
            return state._replace(
                sample_phase=(state.sample_phase + n_rows)
                % cfg.ordering.collect_rate)
        if self._scope is Scope.PER_BATCH:
            # per-task analogue: evidence dies with the batch — but the
            # monitor lane's stride and the re-rank counter are *stream*
            # properties, not evidence. Resetting sample_phase too would
            # make every batch sample the same row offsets (correlation
            # bias the deterministic stride exists to avoid).
            state = self.init_state()._replace(
                sample_phase=state.sample_phase, epoch=state.epoch)
        cut, gcut, n_mon = (res.cut_counts, res.group_cut_counts,
                            res.n_monitored)
        deferred = self.exchange_deferred
        if (self._scope is Scope.CENTRALIZED and self.axis_names
                and not deferred):
            merged = reduce_stats(
                FilterStats(cut, costs, n_mon, gcut), self._scope,
                self.axis_names)
            cut, costs, n_mon, gcut = (merged.num_cut, merged.cost_acc,
                                       merged.n_monitored, merged.group_cut)
        return ordering_lib.advance(
            state, cfg.ordering, cut, costs, n_mon, n_rows=n_rows,
            group_cut=gcut, groups=self.groups, defer_epoch=deferred)

    def _metrics(self, res, perm, new_state, n_dropped=None) -> StepMetrics:
        return StepMetrics(
            work_units=res.work_units,
            n_pass=jnp.sum(res.mask.astype(jnp.int32)),
            perm=perm,
            epoch=new_state.epoch,
            adj_rank=new_state.adj_rank,
            n_dropped=jnp.zeros((), jnp.int32) if n_dropped is None
            else n_dropped,
            # concrete i32 arrays always (ChainResult defaults them to the
            # python int 0, which tree ops downstream cannot stack)
            n_tiles_pass=jnp.asarray(res.n_tiles_pass, jnp.int32),
            n_tiles_fail=jnp.asarray(res.n_tiles_fail, jnp.int32),
            n_tiles_ambiguous=jnp.asarray(res.n_tiles_ambiguous, jnp.int32),
        )

    def _perm(self, state: OrderState):
        return state.perm if self.config.adaptive else jnp.arange(
            len(self.predicates), dtype=jnp.int32)

    def _monitor_spec(self, state: OrderState) -> MonitorSpec:
        return MonitorSpec(collect_rate=self.config.ordering.collect_rate,
                           sample_phase=state.sample_phase)

    def step(self, state: OrderState, columns: jnp.ndarray,
             measured_costs: jnp.ndarray | None = None
             ) -> tuple[OrderState, jnp.ndarray, StepMetrics]:
        """One micro-batch: filter + monitor + (maybe) epoch re-rank.

        ``columns``: f32[C, R]. jit/shard_map-compatible for traceable
        engines. Returns (new_state, mask bool[R], metrics).
        """
        perm = self._perm(state)
        res = self._step_engine.run_chain(
            columns, self.specs, perm, self._monitor_spec(state))
        costs = res.monitor_cost if measured_costs is None else measured_costs
        new_state = self._advance_state(state, res, costs,
                                        int(columns.shape[1]))
        return new_state, res.mask, self._metrics(res, perm, new_state)

    def _step_skip(self, state: OrderState, columns: jnp.ndarray,
                   pass_tiles, fail_tiles, *, amb_cap: int
                   ) -> tuple[OrderState, jnp.ndarray, StepMetrics]:
        """``step`` behind the zone-map skip tier.

        ``pass_tiles``/``fail_tiles`` come from ``_jit_triage`` on the same
        batch; ``amb_cap`` (static) from ``skip_amb_cap`` — the one host
        sync of the tier. Ordering statistics advance identically to
        ``step``: the monitor lane runs row-level on the full batch.
        """
        perm = self._perm(state)
        skip = skip_tier_lib.SkipInfo(pass_tiles, fail_tiles, None)
        res = self._step_engine.run_chain_skip(
            columns, self.specs, perm, self._monitor_spec(state), skip,
            amb_cap=amb_cap)
        new_state = self._advance_state(state, res, res.monitor_cost,
                                        int(columns.shape[1]))
        return new_state, res.mask, self._metrics(res, perm, new_state)

    def _step_skip_compact(self, state: OrderState, columns: jnp.ndarray,
                           pass_tiles, fail_tiles, *, amb_cap: int,
                           capacity: int):
        """``_step_compact`` behind the zone-map skip tier."""
        perm = self._perm(state)
        skip = skip_tier_lib.SkipInfo(pass_tiles, fail_tiles, None)
        res, packed, n_kept = self._step_engine.run_chain_compact_skip(
            columns, self.specs, perm, self._monitor_spec(state), skip,
            amb_cap=amb_cap, capacity=capacity)
        new_state = self._advance_state(state, res, res.monitor_cost,
                                        int(columns.shape[1]))
        n_pass = jnp.sum(res.mask.astype(jnp.int32))
        metrics = self._metrics(res, perm, new_state,
                                n_dropped=n_pass - n_kept)
        return new_state, packed, n_kept, res.mask, metrics

    def _step_compact(self, state: OrderState, columns: jnp.ndarray,
                      measured_costs: jnp.ndarray | None = None,
                      *, capacity: int | None = None):
        """``step`` + single-pass device-side survivor compaction.

        Returns (new_state, packed f32[C, cap], n_kept i32[], mask bool[R],
        metrics). ``packed[:, :n_kept]`` is bit-identical to the host
        boolean-mask path ``columns[:, mask]`` (up to padding) but never
        leaves the device unpacked — and never takes a second full-width
        pass over HBM: the pallas engine packs survivors in-kernel while
        each tile is in VMEM, the jnp engine fuses an O(R) cumsum scatter
        (no argsort). jit/shard_map-compatible; ``capacity`` must be static
        under jit (``_jit_compact`` handles that).
        """
        if capacity is None:
            if self.config.compact_capacity == "auto":
                # capacity=None bakes the width into the trace and the jit
                # cache would never see later re-tunes — auto callers must
                # thread resolve_capacity() per call (the pipelines do).
                raise ValueError(
                    "compact_capacity='auto' needs an explicit per-call "
                    "capacity: pass capacity=filt.resolve_capacity(n_rows)")
            capacity = self.resolve_capacity(int(columns.shape[1]))
        cap = capacity
        perm = self._perm(state)
        res, packed, n_kept = self._step_engine.run_chain_compact(
            columns, self.specs, perm, self._monitor_spec(state),
            capacity=cap)
        costs = res.monitor_cost if measured_costs is None else measured_costs
        new_state = self._advance_state(state, res, costs,
                                        int(columns.shape[1]))
        n_pass = jnp.sum(res.mask.astype(jnp.int32))
        metrics = self._metrics(res, perm, new_state,
                                n_dropped=n_pass - n_kept)
        return new_state, packed, n_kept, res.mask, metrics

    # --------------------------------------------------- capacity auto-tune
    def resolve_capacity(self, n_rows: int) -> int:
        """Current compaction width for an ``n_rows``-wide batch."""
        cap = self.config.compact_capacity
        if cap is None:
            return n_rows
        if cap == "auto":
            return min(self._auto_cap, n_rows) if self._auto_cap else n_rows
        return int(cap)

    def observe_for_capacity(self, evidence_state: OrderState,
                             new_state: OrderState, n_rows: int) -> None:
        """Host hook: re-derive the auto capacity at epoch boundaries.

        ``evidence_state`` is the state whose ``stats`` still hold the
        (almost) full epoch's monitor accumulators — i.e. the state BEFORE
        the step/exchange that fired the boundary. Estimated pass-rate =
        Π_g S_g over the exact per-group selectivities; correlation between
        groups is absorbed by ``compact_slack``. No-op unless
        ``compact_capacity="auto"`` and an epoch boundary was crossed.
        """
        if self.config.compact_capacity != "auto":
            return
        epoch = int(np.max(np.asarray(new_state.epoch)))
        if epoch <= self._auto_cap_epoch:
            return
        self._auto_cap_epoch = epoch
        stats = jax.tree.map(np.asarray, evidence_state.stats)
        n_mon = np.maximum(np.asarray(stats.n_monitored, np.float64), 0.0)
        if np.max(n_mon) <= 0.0:
            return                      # no evidence — keep current width
        gcut = np.asarray(stats.group_cut, np.float64)
        sel = np.clip(1.0 - gcut / np.maximum(n_mon, 1.0)[..., None],
                      0.0, 1.0)
        pass_rate = float(np.max(np.prod(sel, axis=-1)))  # max over shards
        want = pass_rate * n_rows * self.config.compact_slack
        quant = int(np.ceil(want / CAPACITY_QUANTUM)) * CAPACITY_QUANTUM
        self._auto_cap = int(np.clip(quant, CAPACITY_QUANTUM, n_rows))

    # ------------------------------------------------------ deferred epochs
    @property
    def exchange_deferred(self) -> bool:
        """True when epoch boundaries are driver-owned (deferred modes)."""
        return (self.config.adaptive and self.config.exchange != "eager"
                and self._scope is Scope.CENTRALIZED)

    def exchange_due(self, state: OrderState) -> bool:
        """Host-side boundary check for the deferred-exchange driver."""
        if not self.exchange_deferred:
            return False
        rows = int(np.max(np.asarray(state.rows_into_epoch)))
        return rows >= self.config.ordering.calculate_rate

    def exchange_update(self, state: OrderState,
                        use_stats: FilterStats | None = None
                        ) -> tuple[OrderState, FilterStats]:
        """One epoch boundary: merge stats across the mesh, re-rank.

        The ONLY collective of the deferred CENTRALIZED mode lives here —
        one psum of (2P + G + 1) floats per ``calculate_rate`` rows, issued
        from a separate jitted call so the per-step module compiles with no
        all-reduce at all. Returns (new_state, merged_stats); with
        ``use_stats`` the re-rank consumes those (one-epoch-stale) stats
        instead while the freshly merged ones are returned for the next
        boundary (deferred-async).
        """
        merged = state.stats
        if self.axis_names:
            merged = reduce_stats(merged, Scope.CENTRALIZED, self.axis_names)
        new_state = ordering_lib.boundary_update(
            state, self.config.ordering, groups=self.groups,
            stats_override=merged if use_stats is None else use_stats)
        return new_state, merged

    @property
    def jit_exchange(self):
        if self._jit_exchange is None:
            self._jit_exchange = jax.jit(lambda s: self.exchange_update(s))
        return self._jit_exchange

    @property
    def jit_exchange_with(self):
        if self._jit_exchange_with is None:
            self._jit_exchange_with = jax.jit(
                lambda s, st: self.exchange_update(s, st))
        return self._jit_exchange_with

    def maybe_exchange(self, state: OrderState) -> OrderState:
        """Drive the deferred epoch boundary if one is due (host helper).

        Eager mode / off-boundary: returns ``state`` unchanged. In
        "deferred-async" the merged stats are stashed and applied at the
        NEXT boundary (first boundary degenerates to the synchronous
        merge), overlapping the collective with an epoch of filter work.
        """
        return drive_exchange(self, state)

    # ------------------------------------------------------- host streaming
    def process_stream(self, batches: Iterable[np.ndarray]
                       ) -> Iterator[tuple[np.ndarray, np.ndarray, dict]]:
        """Drive the filter over a host-side stream of f32[C, R] batches.

        Yields (surviving_rows f32[C, n_pass], mask, metrics_dict) per batch.
        Uses the configured host engine when one is selected (row-exact wall
        time, measured costs); otherwise calls the jitted step. Under
        ``compact_output`` the survivors come back through the device-side
        packed buffer; overflow (``n_dropped``) is surfaced in the metrics
        dict and warned about once per offending batch.
        """
        if not self._engine.traceable:
            yield from self._process_stream_host(batches)
            return

        # the session owns ALL of the driving (jit dispatch, capacity
        # resolution, deferred exchange, auto-retune, overflow warning,
        # metrics encoding) — this loop is a thin host iterator over it.
        # A FRESH session per invocation: each drives exactly one state
        # stream (its deferred-boundary row counter must not be shared
        # with a pipeline or another stream over the same filter; the jit
        # caches live on the filter and stay shared).
        from repro.core.session import FilterSession
        session = FilterSession.from_filter(self)
        state = session.init_state()
        for batch in batches:
            state, res = session.step(state, batch)
            yield res.survivors(batch), res.mask_np, res.metrics_dict()

    def _process_stream_host(self, batches):
        """Host streaming loop: SAME ordering math as the jitted step, run
        through ``ordering.advance(..., xp=numpy)`` — no host-side mirror."""
        cfg = self.config
        n_preds = len(self.predicates)
        state = self.init_state(xp=np)
        defer = self.exchange_deferred
        for batch in batches:
            perm = state.perm if cfg.adaptive else np.arange(n_preds)
            monitor = MonitorSpec(collect_rate=cfg.ordering.collect_rate,
                                  sample_phase=int(state.sample_phase),
                                  cost_mode=cfg.cost_mode)
            if cfg.skip_tier != "off":
                # "auto" is rejected for host engines by validate_combo;
                # the host engine triages internally (skip=None)
                res = self._engine.run_chain_skip(
                    batch, self.specs, perm, monitor,
                    bloom=cfg.skip_tier == "zonemap+bloom")
            else:
                res = self._engine.run_chain(batch, self.specs, perm,
                                             monitor)
            if cfg.adaptive:
                state = ordering_lib.advance(
                    state, cfg.ordering, res.cut_counts, res.monitor_cost,
                    res.n_monitored, n_rows=batch.shape[1],
                    group_cut=res.group_cut_counts, groups=self.groups,
                    xp=np, defer_epoch=defer)
                if defer and state.rows_into_epoch >= \
                        cfg.ordering.calculate_rate:
                    # no mesh on the host path: the "exchange" is the
                    # identity merge — the boundary cadence still matches
                    # the deferred device path.
                    state = ordering_lib.boundary_update(
                        state, cfg.ordering, groups=self.groups, xp=np)
            else:
                state = state._replace(
                    sample_phase=(state.sample_phase + batch.shape[1])
                    % cfg.ordering.collect_rate)
            yield batch[:, res.mask], res.mask, {
                "work_units": float(res.work_units),
                "n_pass": int(res.mask.sum()),
                "perm": [int(i) for i in perm],
                "epoch": int(state.epoch),
                "n_dropped": 0,
                "n_tiles_skipped_pass": int(res.n_tiles_pass),
                "n_tiles_skipped_fail": int(res.n_tiles_fail),
                "n_tiles_ambiguous": int(res.n_tiles_ambiguous),
            }


def static_filter(predicates: Sequence[Predicate],
                  order: Sequence[int] | None = None,
                  backend: str = "jnp") -> AdaptiveFilter:
    """Spark's default Filter: fixed order, no monitoring overhead.

    ``order`` permutes the user statement order up-front (used by the fig-1
    benchmark to sweep all 24 static orders).
    """
    preds = list(predicates)
    if order is not None:
        preds = [preds[i] for i in order]
    cfg = AdaptiveFilterConfig(adaptive=False, backend=backend)
    return AdaptiveFilter(preds, cfg)
