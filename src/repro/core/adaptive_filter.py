"""The user-facing adaptive filter operator — a thin orchestrator.

This is the framework's analogue of the paper's Catalyst extension: a
pipeline stage that can replace any static conjunctive (or CNF) filter.
Plug it into ``repro.data.pipeline.Pipeline`` (ingestion for training) or
call ``step``/``process_stream`` directly (serving guardrails, benchmarks).

All execution semantics live behind the ``FilterEngine`` registry
(``core/engine/``) and all ordering math in ``core.ordering`` /
``core.stats`` (one implementation, numpy or jnp via the ``xp`` namespace
argument) — this module only wires them together:

  cfg.adaptive=False  → behaves exactly like Spark's default Filter
                        (user-statement order, no monitoring) — the paper's
                        baseline, kept so both can be benchmarked.
  cfg.backend         → any registered engine: "jnp" (jit-able vectorized),
                        "pallas" (fused TPU kernel; interpret-mode on CPU),
                        "numpy" (row-exact host path used by benchmarks).
  cfg.cost_mode       → "static" (calibrated per-predicate weights; works
                        inside jit) or "measured" (host clock per predicate
                        per batch over the monitor sample — the paper's
                        System.nanoTime, at epoch granularity).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_lib
from repro.core import ordering as ordering_lib
from repro.core import predicates as pred_lib
from repro.core.engine import MonitorSpec, get_engine
from repro.core.ordering import OrderingConfig, OrderState
from repro.core.predicates import Predicate
from repro.core.scope import Scope, reduce_stats, scope_from_str


@dataclasses.dataclass(frozen=True)
class AdaptiveFilterConfig:
    ordering: OrderingConfig = OrderingConfig()
    scope: str = "per_shard"
    cost_mode: str = "static"
    backend: str = "jnp"
    adaptive: bool = True
    # Device-side survivor compaction: ``step_compact`` gathers survivors
    # into a padded fixed-width [C, compact_capacity] buffer + count on
    # device (``filter_exec.compact_fixed``), so downstream stages never
    # host-boolean-index the batch. capacity None → batch width (lossless).
    compact_output: bool = False
    compact_capacity: int | None = None

    def __post_init__(self) -> None:
        scope_from_str(self.scope)
        if self.cost_mode not in ("static", "measured"):
            raise ValueError(f"bad cost_mode {self.cost_mode}")
        if self.backend not in engine_lib.available_engines():
            raise ValueError(
                f"bad backend {self.backend}; registered engines: "
                f"{engine_lib.available_engines()}")
        if self.cost_mode == "measured" and self.backend != "numpy":
            raise ValueError("measured cost mode needs the host (numpy) backend")
        if self.compact_output and not get_engine(self.backend).traceable:
            raise ValueError(
                "compact_output is the device-side gather; the host "
                f"engine {self.backend!r} already emits compacted rows "
                "(boolean-index short-circuit) — drop the flag")
        if self.compact_capacity is not None:
            if not self.compact_output:
                raise ValueError("compact_capacity needs compact_output=True")
            if self.compact_capacity < 1:
                raise ValueError("compact_capacity must be >= 1")


class StepMetrics(NamedTuple):
    work_units: jnp.ndarray     # row-level cost-weighted work for this batch
    n_pass: jnp.ndarray         # surviving rows
    perm: jnp.ndarray           # order used for this batch
    epoch: jnp.ndarray          # epochs completed so far
    adj_rank: jnp.ndarray       # current smoothed GROUP ranks


class AdaptiveFilter:
    """Adaptive CNF filter with epoch-based predicate/group reordering."""

    def __init__(self, predicates: Sequence[Predicate],
                 config: AdaptiveFilterConfig | None = None,
                 axis_names: Sequence[str] = ()):
        if not predicates:
            raise ValueError("need at least one predicate")
        self.predicates = list(predicates)
        self.config = config or AdaptiveFilterConfig()
        self.specs = pred_lib.pack(self.predicates)
        self.groups = self.specs.groups          # static CNF structure
        self.axis_names = tuple(axis_names)
        self._scope = scope_from_str(self.config.scope)
        self._engine = get_engine(self.config.backend)
        # the jit-traceable engine driving ``step`` (host engines run via
        # ``process_stream``; step falls back to the jnp reference engine)
        self._step_engine = self._engine if self._engine.traceable \
            else get_engine("jnp")
        self._jit_step = None
        self._jit_step_compact = None

    # ---------------------------------------------------------------- state
    def init_state(self, xp=jnp) -> OrderState:
        return ordering_lib.init_order_state(
            len(self.predicates), self.specs.n_groups, xp=xp)

    @property
    def jit_step(self):
        """``jax.jit(self.step)``, compiled once per instance and reused."""
        if self._jit_step is None:
            self._jit_step = jax.jit(self.step)
        return self._jit_step

    @property
    def jit_step_compact(self):
        """``jax.jit(self.step_compact)``, compiled once and reused."""
        if self._jit_step_compact is None:
            self._jit_step_compact = jax.jit(self.step_compact)
        return self._jit_step_compact

    # ----------------------------------------------------------- jit'd step
    def step(self, state: OrderState, columns: jnp.ndarray,
             measured_costs: jnp.ndarray | None = None
             ) -> tuple[OrderState, jnp.ndarray, StepMetrics]:
        """One micro-batch: filter + monitor + (maybe) epoch re-rank.

        ``columns``: f32[C, R]. jit/shard_map-compatible for traceable
        engines. Returns (new_state, mask bool[R], metrics).
        """
        cfg = self.config
        perm = state.perm if cfg.adaptive else jnp.arange(
            len(self.predicates), dtype=jnp.int32)

        res = self._step_engine.run_chain(
            columns, self.specs, perm,
            MonitorSpec(collect_rate=cfg.ordering.collect_rate,
                        sample_phase=state.sample_phase))

        costs = res.monitor_cost if measured_costs is None else measured_costs

        if cfg.adaptive:
            if self._scope is Scope.PER_BATCH:
                # per-task analogue: evidence dies with the batch — but the
                # monitor lane's stride and the re-rank counter are *stream*
                # properties, not evidence. Resetting sample_phase too would
                # make every batch sample the same row offsets (correlation
                # bias the deterministic stride exists to avoid).
                state = self.init_state()._replace(
                    sample_phase=state.sample_phase, epoch=state.epoch)
            cut, gcut, n_mon = (res.cut_counts, res.group_cut_counts,
                                res.n_monitored)
            if self._scope is Scope.CENTRALIZED and self.axis_names:
                from repro.core.stats import FilterStats
                merged = reduce_stats(
                    FilterStats(cut, costs, n_mon, gcut), self._scope,
                    self.axis_names)
                cut, costs, n_mon, gcut = (merged.num_cut, merged.cost_acc,
                                           merged.n_monitored,
                                           merged.group_cut)
            new_state = ordering_lib.advance(
                state, cfg.ordering, cut, costs, n_mon,
                n_rows=int(columns.shape[1]),
                group_cut=gcut, groups=self.groups)
        else:
            new_state = state._replace(
                sample_phase=(state.sample_phase + columns.shape[1])
                % cfg.ordering.collect_rate)

        metrics = StepMetrics(
            work_units=res.work_units,
            n_pass=jnp.sum(res.mask.astype(jnp.int32)),
            perm=perm,
            epoch=new_state.epoch,
            adj_rank=new_state.adj_rank,
        )
        return new_state, res.mask, metrics

    def step_compact(self, state: OrderState, columns: jnp.ndarray,
                     measured_costs: jnp.ndarray | None = None):
        """``step`` + device-side survivor compaction (``compact_output``).

        Returns (new_state, packed f32[C, cap], n_kept i32[], mask bool[R],
        metrics). ``packed[:, :n_kept]`` is bit-identical to the host
        boolean-mask path ``columns[:, mask]`` (up to padding) but never
        leaves the device unpacked. jit/shard_map-compatible.
        """
        from repro.core import filter_exec
        state, mask, metrics = self.step(state, columns, measured_costs)
        cap = self.config.compact_capacity or int(columns.shape[1])
        packed, n_kept = filter_exec.compact_fixed(columns, mask, cap)
        return state, packed, n_kept, mask, metrics

    # ------------------------------------------------------- host streaming
    def process_stream(self, batches: Iterable[np.ndarray]
                       ) -> Iterator[tuple[np.ndarray, np.ndarray, dict]]:
        """Drive the filter over a host-side stream of f32[C, R] batches.

        Yields (surviving_rows f32[C, n_pass], mask, metrics_dict) per batch.
        Uses the configured host engine when one is selected (row-exact wall
        time, measured costs); otherwise calls the jitted step.
        """
        if not self._engine.traceable:
            yield from self._process_stream_host(batches)
            return

        state = self.init_state()
        for batch in batches:
            cols = jnp.asarray(batch, jnp.float32)
            if self.config.compact_output:
                state, packed, n_kept, mask, metrics = self.jit_step_compact(
                    state, cols)
                survivors = np.asarray(packed)[:, :int(n_kept)]
            else:
                state, mask, metrics = self.jit_step(state, cols)
                survivors = None
            mask_np = np.asarray(mask)
            if survivors is None:
                survivors = batch[:, mask_np]
            yield survivors, mask_np, {
                "work_units": float(metrics.work_units),
                "n_pass": int(metrics.n_pass),
                "perm": np.asarray(metrics.perm).tolist(),
                "epoch": int(metrics.epoch),
            }

    def _process_stream_host(self, batches):
        """Host streaming loop: SAME ordering math as the jitted step, run
        through ``ordering.advance(..., xp=numpy)`` — no host-side mirror."""
        cfg = self.config
        n_preds = len(self.predicates)
        state = self.init_state(xp=np)
        for batch in batches:
            perm = state.perm if cfg.adaptive else np.arange(n_preds)
            res = self._engine.run_chain(
                batch, self.specs, perm,
                MonitorSpec(collect_rate=cfg.ordering.collect_rate,
                            sample_phase=int(state.sample_phase),
                            cost_mode=cfg.cost_mode))
            if cfg.adaptive:
                state = ordering_lib.advance(
                    state, cfg.ordering, res.cut_counts, res.monitor_cost,
                    res.n_monitored, n_rows=batch.shape[1],
                    group_cut=res.group_cut_counts, groups=self.groups,
                    xp=np)
            else:
                state = state._replace(
                    sample_phase=(state.sample_phase + batch.shape[1])
                    % cfg.ordering.collect_rate)
            yield batch[:, res.mask], res.mask, {
                "work_units": float(res.work_units),
                "n_pass": int(res.mask.sum()),
                "perm": [int(i) for i in perm],
                "epoch": int(state.epoch),
            }


def static_filter(predicates: Sequence[Predicate],
                  order: Sequence[int] | None = None,
                  backend: str = "jnp") -> AdaptiveFilter:
    """Spark's default Filter: fixed order, no monitoring overhead.

    ``order`` permutes the user statement order up-front (used by the fig-1
    benchmark to sweep all 24 static orders).
    """
    preds = list(predicates)
    if order is not None:
        preds = [preds[i] for i in order]
    cfg = AdaptiveFilterConfig(adaptive=False, backend=backend)
    return AdaptiveFilter(preds, cfg)
