"""Predicate algebra for the adaptive filter operator.

A predicate is a vectorizable boolean test over one column of a columnar
``RecordBatch``.  The paper's predicates (comparisons on date / int / string
attributes of a structured log stream) map onto five op codes:

  OP_GT       x > t1
  OP_LT       x < t1
  OP_BETWEEN  t1 < x < t2
  OP_EQ       round(x) == round(t1)     (hashed-categorical equality)
  OP_HASHMIX  iterated arithmetic mix of x, ``rounds`` times, then > t1.
              This is the *expensive* predicate class (stands in for
              regex / string matching in the paper): its per-row cost is
              tunable and genuinely higher, so cost-aware ordering matters.

All columns are carried as float32.  String attributes are pre-hashed into
[0, 2^24) (exactly representable in f32) by the data layer.  The same op
semantics are implemented three times and cross-checked by tests:
pure-jnp (here), the Pallas kernel, and the row-level oracle in
``kernels/filter_chain/ref.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

OP_GT = 0
OP_LT = 1
OP_BETWEEN = 2
OP_EQ = 3
OP_HASHMIX = 4

_OP_NAMES = {OP_GT: "gt", OP_LT: "lt", OP_BETWEEN: "between", OP_EQ: "eq",
             OP_HASHMIX: "hashmix"}

# Arithmetic-mix constants for OP_HASHMIX (shared with kernel + oracle).
MIX_MUL = 1.0000019073486328  # exactly representable in f32
MIX_ADD = 0.31830987334251404
MIX_MOD = 1048576.0  # 2**20


@dataclasses.dataclass(frozen=True)
class Predicate:
    """One filter condition over ``column`` of the record batch.

    ``group`` extends the algebra from a flat conjunction to CNF: predicates
    sharing a group id are OR'ed together; distinct groups are AND'ed.
    ``group=None`` (default) puts the predicate in its own singleton group,
    so a chain of ungrouped predicates is exactly the paper's conjunction.
    Group labels are arbitrary hashables; ``pack`` normalizes them to dense
    ids in first-appearance order.
    """

    name: str
    column: int
    op: int
    t1: float
    t2: float = 0.0
    rounds: int = 0          # extra mix rounds (OP_HASHMIX only)
    static_cost: float = 1.0  # calibrated per-row work units (STATIC cost mode)
    group: object = None     # CNF OR-group label; None → singleton group

    def __post_init__(self) -> None:
        if self.op not in _OP_NAMES:
            raise ValueError(f"unknown op code {self.op}")
        if self.op == OP_HASHMIX and self.rounds < 1:
            raise ValueError("OP_HASHMIX requires rounds >= 1")
        if self.static_cost <= 0:
            raise ValueError("static_cost must be positive")

    def describe(self) -> str:
        grp = "" if self.group is None else f" group={self.group}"
        return f"{self.name}: col[{self.column}] {_OP_NAMES[self.op]} " \
               f"t1={self.t1} t2={self.t2} rounds={self.rounds} " \
               f"c={self.static_cost}{grp}"


@dataclasses.dataclass(frozen=True)
class PredicateSpecs:
    """Structure-of-arrays packing of a predicate chain (kernel ABI).

    ``group`` is the CNF structure: a *static* tuple of dense group ids, one
    per predicate (it rides in the pytree aux data, not as an array, so jit
    traces can unroll group-shaped control flow and kernels can specialize on
    the grouping). ``()`` means all-singleton groups (flat conjunction).
    """

    column: jnp.ndarray      # i32[P]
    op: jnp.ndarray          # i32[P]
    t1: jnp.ndarray          # f32[P]
    t2: jnp.ndarray          # f32[P]
    rounds: jnp.ndarray      # i32[P]
    static_cost: jnp.ndarray  # f32[P]
    group: tuple = ()        # static dense group id per predicate; () → flat

    @property
    def n(self) -> int:
        return int(self.column.shape[0])

    @property
    def groups(self) -> tuple:
        """Dense group id per predicate (singletons when unset)."""
        return self.group if self.group else tuple(range(self.n))

    @property
    def n_groups(self) -> int:
        return max(self.groups) + 1

    @property
    def is_flat(self) -> bool:
        """True when every group is a singleton (plain conjunction)."""
        g = self.groups
        return len(set(g)) == len(g)

    @property
    def group_members(self) -> tuple:
        """tuple[G] of tuple[int] — predicate indices per group (static)."""
        members: list[list[int]] = [[] for _ in range(self.n_groups)]
        for i, g in enumerate(self.groups):
            members[g].append(i)
        return tuple(tuple(m) for m in members)

    def tree_flatten(self):
        return ((self.column, self.op, self.t1, self.t2, self.rounds,
                 self.static_cost), self.group)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, group=aux)


jax.tree_util.register_pytree_node(
    PredicateSpecs, PredicateSpecs.tree_flatten, PredicateSpecs.tree_unflatten)


def normalize_groups(predicates: Sequence[Predicate]) -> tuple:
    """Dense group ids in first-appearance order; None → fresh singleton.

    Predicates sharing a group label must be ADJACENT in statement order:
    the statement order is the initial evaluation permutation, and every
    engine closes one OR accumulator at a time — the jit-traced engines
    (jnp, pallas) cannot detect an interleaved layout at runtime, so it is
    rejected here, at the one eager choke point.
    """
    ids: dict = {}
    out = []
    for i, p in enumerate(predicates):
        key = ("__singleton__", i) if p.group is None else ("user", p.group)
        gid = ids.setdefault(key, len(ids))
        if out and gid < len(ids) - 1 and out[-1] != gid:
            raise ValueError(
                f"predicates of group {p.group!r} are not contiguous in "
                f"statement order (predicate {i}: {p.name!r}); OR-group "
                f"members must be adjacent")
        out.append(gid)
    return tuple(out)


def pack(predicates: Sequence[Predicate]) -> PredicateSpecs:
    """Pack a python predicate chain into the SoA kernel ABI."""
    if not predicates:
        raise ValueError("empty predicate chain")
    return PredicateSpecs(
        column=jnp.asarray([p.column for p in predicates], jnp.int32),
        op=jnp.asarray([p.op for p in predicates], jnp.int32),
        t1=jnp.asarray([p.t1 for p in predicates], jnp.float32),
        t2=jnp.asarray([p.t2 for p in predicates], jnp.float32),
        rounds=jnp.asarray([p.rounds for p in predicates], jnp.int32),
        static_cost=jnp.asarray([p.static_cost for p in predicates], jnp.float32),
        group=normalize_groups(predicates),
    )


def hashmix(x: jnp.ndarray, rounds) -> jnp.ndarray:
    """Iterated arithmetic mix — the tunably-expensive predicate body.

    Deterministic, branch-free, identical in jnp / Pallas / numpy oracle.
    """
    def body(_, y):
        y = y * MIX_MUL + MIX_ADD
        return y - jnp.floor(y / MIX_MOD) * MIX_MOD

    return jax.lax.fori_loop(0, rounds, body, x.astype(jnp.float32))


def eval_one(specs: PredicateSpecs, i, x: jnp.ndarray) -> jnp.ndarray:
    """Evaluate predicate ``i`` (dynamic index) of ``specs`` on values ``x``.

    ``x`` is the *already-selected* column values, f32[R]. Returns bool[R].
    """
    op = specs.op[i]
    t1 = specs.t1[i]
    t2 = specs.t2[i]
    rounds = specs.rounds[i]

    # Branches are lazy: the expensive mix only runs when op == OP_HASHMIX,
    # preserving the cost heterogeneity the ordering exploits.
    return jax.lax.switch(op, [
        lambda: x > t1,
        lambda: x < t1,
        lambda: jnp.logical_and(x > t1, x < t2),
        lambda: jnp.round(x) == jnp.round(t1),
        lambda: hashmix(x, jnp.maximum(rounds, 1)) > t1,
    ])


def eval_all(specs: PredicateSpecs, columns: jnp.ndarray) -> jnp.ndarray:
    """Evaluate every predicate on every row: bool[P, R].

    ``columns`` is f32[C, R]. Used by the monitor lane (the paper evaluates
    *all* predicates on sampled rows to avoid correlation bias) and by tests.
    """
    def one(i):
        x = columns[specs.column[i]]
        return eval_one(specs, i, x)

    return jax.vmap(one)(jnp.arange(specs.n))


def chain_cost_row_model(specs: PredicateSpecs, pass_probs: jnp.ndarray,
                         perm: jnp.ndarray) -> jnp.ndarray:
    """Expected per-row cost of evaluating the chain in ``perm`` order.

    Implements the textbook objective the paper's rank ordering minimizes:
      E[cost] = sum_i c_{perm[i]} * prod_{j<i} s_{perm[j]}
    with s = per-predicate pass probability (selectivity). Used by property
    tests to verify rank-ascending order is optimal.
    """
    c = specs.static_cost[perm]
    s = pass_probs[perm]
    surv = jnp.concatenate([jnp.ones((1,), s.dtype), jnp.cumprod(s)[:-1]])
    return jnp.sum(c * surv)


def paper_filters_4(selectivity_target: str = "fig1") -> list[Predicate]:
    """The paper's experimental chain: 2 int predicates, 1 date, 1 string.

    Columns: 0=date (days, normal), 1=int (normal), 2=string-hash.
    Thresholds are chosen by the data layer's generator statistics so that
    overall selectivity ~= 4.51% ("fig1") or ~= 16.14% ("sens").
    """
    from repro.data.stream import threshold_for_quantile  # cycle-free at runtime

    # The two int predicates form a range (as in the paper's hour>7 && hour<16
    # example), so they are CORRELATED: joint int pass = a + b - 1. Overall
    # selectivity = (a+b-1) * d * s.
    if selectivity_target == "fig1":
        # (.62+.62-1) * .5 * .376 = 0.0451
        a, b, d, s = 0.62, 0.62, 0.50, 0.376
    elif selectivity_target == "sens":
        # (.75+.75-1) * .62 * .5208 = 0.1614
        a, b, d, s = 0.75, 0.75, 0.62, 0.5208
    else:
        raise ValueError(selectivity_target)

    return [
        Predicate("int_hi", column=1, op=OP_GT,
                  t1=threshold_for_quantile("int", 1.0 - a), static_cost=1.0),
        Predicate("int_lo", column=1, op=OP_LT,
                  t1=threshold_for_quantile("int", b), static_cost=1.0),
        Predicate("date_gt", column=0, op=OP_GT,
                  t1=threshold_for_quantile("date", 1.0 - d), static_cost=1.2),
        Predicate("str_match", column=2, op=OP_HASHMIX,
                  t1=(1.0 - s) * MIX_MOD, rounds=24, static_cost=6.0),
    ]


def paper_filters_cnf(selectivity_target: str = "fig1") -> list[Predicate]:
    """CNF (AND-of-OR) variant of the paper chain.

    Same columns and thresholds; the date and string predicates collapse
    into one OR-group ("recent OR matching") while the two int range
    predicates stay singleton groups:

        int_hi AND int_lo AND (date_gt OR str_match)

    This is the first filter shape the flat conjunction could not express.
    The OR-group pairs a cheap selective member with an expensive one, so
    both levels of the ordering matter: the group's rank against the int
    predicates, and evaluating ``date_gt`` before ``str_match`` inside the
    group (an OR short-circuits on the first PASS, so the cheap member
    spares most rows the hashmix).
    """
    int_hi, int_lo, date_gt, str_match = paper_filters_4(selectivity_target)
    return [
        int_hi, int_lo,
        dataclasses.replace(date_gt, group="recent_or_match"),
        dataclasses.replace(str_match, group="recent_or_match"),
    ]
