"""Spark-fidelity executor simulation: tasks, shared state, lock, deferral.

``core.ordering`` is the *functional* port of the paper's mechanism; this
module reproduces the paper's §2.2 concurrency semantics exactly, for the
fidelity benchmarks and tests:

  * one "executor" = a process-wide state object (permutation + adj ranks),
    the analogue of the static JVM fields;
  * N "task" threads each process partitions (numpy column batches) pulled
    from a shared queue, reading the current permutation WITHOUT a lock
    (like a JVM read of a static array reference);
  * each task accumulates its own (numCut, cost) metrics;
  * when a task observes the epoch boundary it tries the executor lock:
    the winner folds its metrics into the global ranks and re-sorts; losers
    DEFER — they keep their collected metrics and retry at the next epoch
    (verbatim the paper's "non-permitted updates are deferred to the next
    epoch keeping the collected metrics").
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Sequence

import numpy as np

from repro.core import np_exec
from repro.core import stats as stats_lib
from repro.core.ordering import OrderingConfig
from repro.core.predicates import Predicate


class _ExecutorState:
    """The 'static JVM fields' of one executor."""

    def __init__(self, n_preds: int, cfg: OrderingConfig):
        self.cfg = cfg
        self.perm = np.arange(n_preds)
        self.adj_rank = np.zeros(n_preds, np.float64)
        self.rows_seen = 0
        self.epoch = 0
        self.next_boundary = cfg.calculate_rate
        self.lock = threading.Lock()
        self.deferred_updates = 0
        self.perm_history: list[list[int]] = []

    def try_epoch_update(self, num_cut, cost_acc, n_monitored) -> bool:
        """Winner updates ranks; losers defer (returns False, keep metrics).

        The rank math itself is the shared ``core.stats`` implementation run
        on the numpy namespace — this class only reproduces the paper's
        lock/defer concurrency semantics around it.
        """
        if not self.lock.acquire(blocking=False):
            self.deferred_updates += 1
            return False
        try:
            if n_monitored <= 0:
                return True  # consumed, nothing learned
            st = stats_lib.FilterStats(
                num_cut=np.asarray(num_cut, np.float64),
                cost_acc=np.asarray(cost_acc, np.float64),
                n_monitored=float(n_monitored))
            rank = stats_lib.ranks(st, xp=np)
            self.adj_rank = stats_lib.momentum_update(
                self.adj_rank, rank, self.cfg.momentum,
                first_epoch=self.epoch == 0, xp=np)
            self.perm = stats_lib.order_from_ranks(self.adj_rank, xp=np)
            self.perm_history.append([int(i) for i in self.perm])
            self.epoch += 1
            return True
        finally:
            self.lock.release()


@dataclasses.dataclass
class SimResult:
    total_work_units: float
    wall_seconds: float
    rows_processed: int
    rows_passed: int
    epochs: int
    deferred_updates: int
    final_perm: list[int]
    perm_history: list[list[int]]


def run_executor(predicates: Sequence[Predicate],
                 partitions: Sequence[np.ndarray],
                 cfg: OrderingConfig = OrderingConfig(),
                 n_tasks: int = 4,
                 adaptive: bool = True,
                 cost_mode: str = "measured") -> SimResult:
    """Process ``partitions`` with ``n_tasks`` concurrent task threads."""
    n_preds = len(predicates)
    state = _ExecutorState(n_preds, cfg)
    work_q: queue.Queue = queue.Queue()
    for part in partitions:
        work_q.put(part)

    totals = {"work": 0.0, "rows": 0, "passed": 0}
    totals_lock = threading.Lock()

    def task_loop():
        # task-local metric accumulators (survive across partitions, as the
        # paper's tasks... are short-lived; here one thread runs many tasks,
        # each partition plays the role of one task's data slice)
        num_cut = np.zeros(n_preds, np.float64)
        cost_acc = np.zeros(n_preds, np.float64)
        n_mon = 0.0
        sample_phase = 0
        while True:
            try:
                part = work_q.get_nowait()
            except queue.Empty:
                return
            perm = state.perm if adaptive else np.arange(n_preds)
            mask, work, _ = np_exec.run_chain_np(part, predicates, perm)
            if adaptive:
                cut, _gcut, m, secs = np_exec.run_monitor_np(
                    part, predicates, cfg.collect_rate, sample_phase)
                num_cut += cut
                if cost_mode == "measured":
                    cost_acc += secs
                else:
                    cost_acc += np.array(
                        [p.static_cost for p in predicates]) * m
                n_mon += m
            sample_phase = (sample_phase + part.shape[1]) % cfg.collect_rate
            with totals_lock:
                totals["work"] += work
                totals["rows"] += part.shape[1]
                totals["passed"] += int(mask.sum())
                state.rows_seen += part.shape[1]
                crossed = state.rows_seen >= state.next_boundary
                if crossed:
                    state.next_boundary += cfg.calculate_rate
            if adaptive and crossed:
                if state.try_epoch_update(num_cut, cost_acc, n_mon):
                    num_cut[:] = 0.0
                    cost_acc[:] = 0.0
                    n_mon = 0.0
                # else: deferred — metrics kept, retried next boundary

    t0 = time.perf_counter()
    threads = [threading.Thread(target=task_loop) for _ in range(n_tasks)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    return SimResult(
        total_work_units=totals["work"],
        wall_seconds=wall,
        rows_processed=totals["rows"],
        rows_passed=totals["passed"],
        epochs=state.epoch,
        deferred_updates=state.deferred_updates,
        final_perm=[int(i) for i in state.perm],
        perm_history=state.perm_history,
    )
