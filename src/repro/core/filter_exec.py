"""Chain execution + monitor lane (pure-jnp reference path).

Three execution backends exist in the framework; this module is the jit-able
reference one. All three share semantics and are cross-checked by tests:

  * ``jnp`` (here)     — fully vectorized masked evaluation. Exact row-level
                         *work counters* (what Spark would have evaluated),
                         usable inside a jitted training pipeline.
  * ``numpy_compacted``— host path in ``executor_sim.py`` / benchmarks:
                         boolean-index compaction between predicates, so wall
                         time genuinely tracks the chosen order (row-exact
                         short-circuit, like Spark's processNext).
  * ``pallas``         — ``kernels/filter_chain``: fused single-HBM-pass tile
                         kernel with tile-level early exit (the TPU target).

Monitor lane (paper §2.1): rows with (global_row_index % collect_rate == 0)
are sampled; *all* predicates are evaluated on them (correlation-bias-free),
and numCut / cost accumulate only from those rows. Sampling is a
deterministic stride — no PRNG — carried across batches by ``sample_phase``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import predicates as pred_lib
from repro.core.predicates import PredicateSpecs


class ChainResult(NamedTuple):
    mask: jnp.ndarray           # bool[R] — rows passing every predicate
    work_units: jnp.ndarray     # f32[] — row-level cost-weighted work (Spark model)
    active_before: jnp.ndarray  # f32[P] — rows alive before each chain position
    cut_counts: jnp.ndarray     # f32[P] — monitor lane: rows failing each predicate
    n_monitored: jnp.ndarray    # f32[] — monitor lane: sampled row count
    monitor_cost: jnp.ndarray   # f32[P] — STATIC-mode cost contribution


def monitor_indices(n_rows: int, collect_rate: int, sample_phase):
    """Deterministic-stride sample positions for one batch.

    Returns (idx_i32[max_samples], valid_bool[max_samples]); static shapes so
    the whole thing jits. ``sample_phase`` = global row offset of this batch
    modulo collect_rate.
    """
    max_samples = n_rows // collect_rate + 1
    first = (-sample_phase) % collect_rate
    idx = first + jnp.arange(max_samples, dtype=jnp.int32) * collect_rate
    valid = idx < n_rows
    return jnp.clip(idx, 0, n_rows - 1), valid


def run_monitor(columns: jnp.ndarray, specs: PredicateSpecs,
                collect_rate: int, sample_phase):
    """Evaluate ALL predicates on the sampled rows only."""
    n_rows = columns.shape[1]
    idx, valid = monitor_indices(n_rows, collect_rate, sample_phase)
    sampled = columns[:, idx]                      # f32[C, max_samples]
    results = pred_lib.eval_all(specs, sampled)    # bool[P, max_samples]
    cut = jnp.sum(jnp.logical_and(~results, valid[None, :]), axis=1)
    n_monitored = jnp.sum(valid).astype(jnp.float32)
    # STATIC cost model: each sampled row pays every predicate's calibrated
    # per-row cost (the monitor lane evaluates all of them, as in the paper).
    monitor_cost = specs.static_cost * n_monitored
    return cut.astype(jnp.float32), n_monitored, monitor_cost


def run_chain(columns: jnp.ndarray, specs: PredicateSpecs, perm: jnp.ndarray,
              collect_rate: int, sample_phase) -> ChainResult:
    """Masked conjunctive chain in ``perm`` order + monitor lane.

    The boolean outcome is order-invariant (conjunction commutes); the work
    counters are not — they are the paper's objective function, measured
    exactly: predicate ``perm[k]`` is charged for every row still alive
    before position k (what a row-at-a-time engine would evaluate).
    """
    n_rows = columns.shape[1]
    n_preds = specs.n

    mask = jnp.ones((n_rows,), bool)
    work = jnp.zeros((), jnp.float32)
    active_before = []

    for k in range(n_preds):          # P is small & static → unrolled, lazy ops
        i = perm[k]
        alive = jnp.sum(mask).astype(jnp.float32)
        active_before.append(alive)
        work = work + alive * specs.static_cost[i]
        x = jnp.take(columns, specs.column[i], axis=0)
        res = pred_lib.eval_one(specs, i, x)
        mask = jnp.logical_and(mask, res)

    cut, n_mon, mon_cost = run_monitor(columns, specs, collect_rate, sample_phase)

    return ChainResult(
        mask=mask,
        work_units=work,
        active_before=jnp.stack(active_before),
        cut_counts=cut,
        n_monitored=n_mon,
        monitor_cost=mon_cost,
    )


def compact(columns: jnp.ndarray, mask: jnp.ndarray, fill: float = 0.0):
    """Stable stream compaction of surviving rows (cumsum + scatter).

    Returns (packed f32[C, R], n_survivors i32[]): survivors are moved to the
    front in order; the tail is ``fill``. Static output shape keeps it
    jit-able; downstream stages read only the first n_survivors rows.
    """
    n_rows = columns.shape[1]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1           # target slot per survivor
    dest = jnp.where(mask, pos, n_rows)                     # dump non-survivors
    out = jnp.full((columns.shape[0], n_rows + 1), fill, columns.dtype)
    out = out.at[:, dest].set(columns)
    return out[:, :n_rows], jnp.sum(mask.astype(jnp.int32))
