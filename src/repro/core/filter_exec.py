"""Chain execution + monitor lane (pure-jnp reference path).

This module implements the ``jnp`` engine's math (see ``core/engine/`` for
the registry; three engines share the ``ChainResult`` contract and are
cross-checked by tests):

  * ``jnp`` (here)     — fully vectorized masked evaluation. Exact row-level
                         *work counters* (what Spark would have evaluated),
                         usable inside a jitted training pipeline.
  * ``numpy``          — host path in ``engine/numpy_engine.py`` /
                         ``executor_sim.py``: boolean-index compaction
                         between predicates, so wall time genuinely tracks
                         the chosen order (row-exact short-circuit, like
                         Spark's processNext).
  * ``pallas``         — ``kernels/filter_chain``: fused single-HBM-pass tile
                         kernel with tile-level early exit (the TPU target).

CNF semantics (all engines): predicates sharing a group OR together; groups
AND together. Evaluation short-circuits at both levels — a row stops
evaluating an OR-group's members once one passes, and stops entirely once a
group rejects it. ``perm`` must keep each group's members contiguous
(``stats.cnf_order`` guarantees it); flat chains (all singleton groups) are
the degenerate case and reproduce the paper's conjunction bit-exactly.

Monitor lane (paper §2.1): rows with (global_row_index % collect_rate == 0)
are sampled; *all* predicates are evaluated on them (correlation-bias-free),
and numCut / cost accumulate only from those rows — plus, for CNF, the exact
per-group cut counts (no member passed). Sampling is a deterministic stride
— no PRNG — carried across batches by ``sample_phase``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import predicates as pred_lib
from repro.core.engine.base import ChainResult
from repro.core.predicates import PredicateSpecs

__all__ = ["ChainResult", "monitor_indices", "run_monitor", "run_chain",
           "run_chain_masks", "compact", "compact_fixed",
           "compact_fixed_argsort"]


def monitor_indices(n_rows: int, collect_rate: int, sample_phase):
    """Deterministic-stride sample positions for one batch.

    Returns (idx_i32[max_samples], valid_bool[max_samples]); static shapes so
    the whole thing jits. ``sample_phase`` = global row offset of this batch
    modulo collect_rate.
    """
    max_samples = n_rows // collect_rate + 1
    first = (-sample_phase) % collect_rate
    idx = first + jnp.arange(max_samples, dtype=jnp.int32) * collect_rate
    valid = idx < n_rows
    return jnp.clip(idx, 0, n_rows - 1), valid


def run_monitor(columns: jnp.ndarray, specs: PredicateSpecs,
                collect_rate: int, sample_phase):
    """Evaluate ALL predicates on the sampled rows only.

    Returns (cut f32[P], group_cut f32[G], n_monitored f32[],
    monitor_cost f32[P]).
    """
    n_rows = columns.shape[1]
    idx, valid = monitor_indices(n_rows, collect_rate, sample_phase)
    sampled = columns[:, idx]                      # f32[C, max_samples]
    results = pred_lib.eval_all(specs, sampled)    # bool[P, max_samples]
    cut = jnp.sum(jnp.logical_and(~results, valid[None, :]), axis=1)
    # group cut: a sampled row is cut by group g iff NO member passes —
    # exact (the monitor lane sees the full outcome matrix, so group
    # selectivities carry no independence assumption).
    group_fail = jnp.stack(
        [jnp.all(~results[jnp.asarray(m)], axis=0)
         for m in specs.group_members])            # bool[G, max_samples]
    group_cut = jnp.sum(jnp.logical_and(group_fail, valid[None, :]), axis=1)
    n_monitored = jnp.sum(valid).astype(jnp.float32)
    # STATIC cost model: each sampled row pays every predicate's calibrated
    # per-row cost (the monitor lane evaluates all of them, as in the paper).
    monitor_cost = specs.static_cost * n_monitored
    return (cut.astype(jnp.float32), group_cut.astype(jnp.float32),
            n_monitored, monitor_cost)


def run_chain_masks(columns: jnp.ndarray, specs: PredicateSpecs,
                    perm: jnp.ndarray, valid=None):
    """Chain lane only (no monitor): masked CNF evaluation in ``perm`` order.

    Returns (mask bool[R], work f32[], active_before f32[P]). ``valid``
    (bool[R], optional) pre-cuts rows before the first predicate: the skip
    tier's gathered ambiguous buffer uses it so padding and unused gather
    slots are neither kept nor charged to the work counters.
    """
    n_rows = columns.shape[1]
    n_preds = specs.n
    flat = specs.is_flat                  # static → branch folds at trace
    garr = jnp.asarray(specs.groups, jnp.int32)

    # survivors of all CLOSED groups
    mask = jnp.ones((n_rows,), bool) if valid is None else valid
    group_or = jnp.zeros((n_rows,), bool)  # passes within the OPEN group
    work = jnp.zeros((), jnp.float32)
    active_before = []

    for k in range(n_preds):          # P is small & static → unrolled, lazy ops
        i = perm[k]
        # is_first/closes are group-boundary flags; static True when flat,
        # traced scalars otherwise (perm is dynamic under jit).
        is_first = True if (flat or k == 0) else (garr[perm[k - 1]] != garr[i])
        closes = True if (flat or k == n_preds - 1) \
            else (garr[perm[k + 1]] != garr[i])
        pending = mask if is_first is True \
            else jnp.where(is_first, mask, jnp.logical_and(mask, ~group_or))
        alive = jnp.sum(pending).astype(jnp.float32)
        active_before.append(alive)
        work = work + alive * specs.static_cost[i]
        x = jnp.take(columns, specs.column[i], axis=0)
        res = pred_lib.eval_one(specs, i, x)
        group_or = res if is_first is True \
            else jnp.where(is_first, res, jnp.logical_or(group_or, res))
        new_mask = jnp.logical_and(mask, group_or)
        mask = new_mask if closes is True else jnp.where(closes, new_mask, mask)

    return mask, work, jnp.stack(active_before)


def run_chain(columns: jnp.ndarray, specs: PredicateSpecs, perm: jnp.ndarray,
              collect_rate: int, sample_phase) -> ChainResult:
    """Masked CNF chain in ``perm`` order + monitor lane.

    The boolean outcome is order-invariant (AND/OR commute); the work
    counters are not — they are the paper's objective function, measured
    exactly: predicate ``perm[k]`` is charged for every row still *pending*
    at position k — alive through all closed groups AND not yet passed by an
    earlier member of the current group (what a row-at-a-time engine with
    both short-circuits would evaluate).
    """
    mask, work, active_before = run_chain_masks(columns, specs, perm)

    cut, group_cut, n_mon, mon_cost = run_monitor(
        columns, specs, collect_rate, sample_phase)

    return ChainResult(
        mask=mask,
        work_units=work,
        active_before=active_before,
        cut_counts=cut,
        n_monitored=n_mon,
        monitor_cost=mon_cost,
        group_cut_counts=group_cut,
    )


def compact(columns: jnp.ndarray, mask: jnp.ndarray, fill: float = 0.0):
    """Stable stream compaction of surviving rows (cumsum + scatter).

    Returns (packed f32[C, R], n_survivors i32[]): survivors are moved to the
    front in order; the tail is ``fill``. Static output shape keeps it
    jit-able; downstream stages read only the first n_survivors rows.
    """
    n_rows = columns.shape[1]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1           # target slot per survivor
    dest = jnp.where(mask, pos, n_rows)                     # dump non-survivors
    out = jnp.full((columns.shape[0], n_rows + 1), fill, columns.dtype)
    out = out.at[:, dest].set(columns)
    return out[:, :n_rows], jnp.sum(mask.astype(jnp.int32))


def compact_fixed(columns: jnp.ndarray, mask: jnp.ndarray, capacity: int,
                  fill: float = 0.0):
    """Fixed-capacity device-side compaction: mask → cumsum positions → O(R)
    scatter.

    Returns (packed f32[C, capacity], n_kept i32[]). Survivors keep their
    stream order in the first ``n_kept`` slots; the tail is ``fill``. Unlike
    ``compact`` the output width is a static ``capacity`` independent of the
    batch width, so survivors flow to downstream device stages — or a single
    dense host copy — without ever round-tripping through a host boolean
    index. Shared by every traceable engine: the engines produce the mask,
    this gather consumes it (the fused compacting step). Survivors
    beyond ``capacity`` are dropped and ``n_kept`` saturates — size capacity
    from the stream's expected pass rate (capacity = batch width is always
    lossless; ``compact_capacity="auto"`` tracks the monitor lane's
    pass-rate).

    Each survivor's destination slot is its exclusive rank in the mask
    (cumsum − 1) — the same position math the fused Pallas kernel computes
    per tile — so there is no ``O(R log R)`` sort anywhere in the ingestion
    path. Non-survivors and overflow survivors scatter into a dump column
    that is sliced off, keeping the scatter index map free of duplicates on
    the live region.
    """
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1   # exclusive survivor rank
    dest = jnp.where(jnp.logical_and(mask, pos < capacity), pos, capacity)
    out = jnp.full((columns.shape[0], capacity + 1), fill, columns.dtype)
    out = out.at[:, dest].set(columns, mode="drop")
    n_pass = jnp.sum(mask.astype(jnp.int32))
    return out[:, :capacity], jnp.minimum(n_pass, capacity)


def compact_fixed_argsort(columns: jnp.ndarray, mask: jnp.ndarray,
                          capacity: int, fill: float = 0.0):
    """Legacy ``O(R log R)`` compaction (mask → stable argsort → gather).

    Kept only as the baseline for ``benchmarks/ingest.py`` and the parity
    tests — production paths use the ``O(R)`` cumsum scatter above. Output
    is bit-identical to ``compact_fixed``.
    """
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    keep = jnp.logical_not(mask)
    order = jnp.argsort(keep, stable=True)        # survivors first, in order
    slots = jnp.arange(capacity, dtype=jnp.int32)
    idx = jnp.take(order, slots, mode="fill", fill_value=0)
    n_pass = jnp.sum(mask.astype(jnp.int32))
    valid = slots < n_pass
    packed = jnp.where(valid[None, :], columns[:, idx], fill)
    return packed, jnp.minimum(n_pass, capacity)
