"""Execution-engine registry: the pluggable seam behind ``AdaptiveFilter``.

Three engines ship in-tree and register themselves on import:

  jnp     — masked vectorized evaluation (jit/shard_map reference path)
  pallas  — fused single-HBM-pass TPU tile kernel (interpret-mode on CPU)
  numpy   — row-exact compacted host path (wall-clock-true, measured costs)

Adding a backend is one module: implement ``FilterEngine.run_chain`` and
decorate the class with ``@register("name")`` — ``AdaptiveFilter`` and the
benchmarks discover it by name with no further wiring.
"""

from __future__ import annotations

from repro.core.engine.base import ChainResult, FilterEngine, MonitorSpec

_REGISTRY: dict = {}


def register(name: str):
    """Class decorator: instantiate and expose an engine under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_engine(name: str) -> FilterEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown filter engine {name!r}; available: "
            f"{available_engines()}") from None


def available_engines() -> tuple:
    return tuple(sorted(_REGISTRY))


# Self-registration of the in-tree engines (import for side effect).
from repro.core.engine import jnp_engine as _jnp_engine          # noqa: E402
from repro.core.engine import numpy_engine as _numpy_engine      # noqa: E402
from repro.core.engine import pallas_engine as _pallas_engine    # noqa: E402

__all__ = ["ChainResult", "FilterEngine", "MonitorSpec", "register",
           "get_engine", "available_engines"]
