"""``jnp`` engine: masked vectorized evaluation (the jit-able reference)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine as engine_lib
from repro.core import filter_exec, skip_tier
from repro.core.engine.base import ChainResult, MonitorSpec, SkipInfo


@engine_lib.register("jnp")
class JnpEngine:
    """Fully vectorized masked CNF chain; exact row-level work counters."""

    traceable = True
    supports_skip = True
    # the jnp skip path gathers ambiguous tiles into a static-width buffer,
    # so the session must sync the ambiguous count and size ``amb_cap``
    skip_gathers = True

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        return filter_exec.run_chain(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase)

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """Chain + O(R) cumsum compaction (no argsort); XLA fuses the two."""
        res = self.run_chain(columns, specs, perm, monitor)
        packed, n_kept = filter_exec.compact_fixed(columns, res.mask,
                                                   capacity, fill)
        return res, packed, n_kept

    # ------------------------------------------------------- skip tier
    def triage(self, columns, specs, *, bloom: bool) -> SkipInfo:
        """Zone-map (+ Bloom) summaries resolved against the chain."""
        return skip_tier.triage(columns, specs, bloom=bloom, xp=jnp)

    def run_chain_skip(self, columns, specs, perm, monitor: MonitorSpec,
                       skip: SkipInfo, *, amb_cap: int) -> ChainResult:
        """Gather ambiguous tiles → row-level chain → scatter the mask back.

        The expensive predicates genuinely run at the gathered width (the
        masked off-path evaluates them full-width), which is where the
        clustered-layout speedup comes from. The monitor lane runs on the
        full batch — ordering statistics are identical with the tier off.
        """
        return skip_tier.run_chain_skip_jnp(columns, specs, perm, monitor,
                                            skip, amb_cap=amb_cap)

    def run_chain_compact_skip(self, columns, specs, perm,
                               monitor: MonitorSpec, skip: SkipInfo, *,
                               amb_cap: int, capacity: int,
                               fill: float = 0.0):
        res = self.run_chain_skip(columns, specs, perm, monitor, skip,
                                  amb_cap=amb_cap)
        packed, n_kept = filter_exec.compact_fixed(columns, res.mask,
                                                   capacity, fill)
        return res, packed, n_kept
