"""``jnp`` engine: masked vectorized evaluation (the jit-able reference)."""

from __future__ import annotations

from repro.core import engine as engine_lib
from repro.core import filter_exec
from repro.core.engine.base import ChainResult, MonitorSpec


@engine_lib.register("jnp")
class JnpEngine:
    """Fully vectorized masked CNF chain; exact row-level work counters."""

    traceable = True

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        return filter_exec.run_chain(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase)

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """Chain + O(R) cumsum compaction (no argsort); XLA fuses the two."""
        res = self.run_chain(columns, specs, perm, monitor)
        packed, n_kept = filter_exec.compact_fixed(columns, res.mask,
                                                   capacity, fill)
        return res, packed, n_kept
