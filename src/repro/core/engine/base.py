"""FilterEngine contract: the one ABI all execution backends implement.

An engine turns (columns, packed predicate specs, permutation, monitor
config) into a ``ChainResult``. The semantics are fixed — CNF evaluation
(OR within a group, AND across groups, short-circuit at both levels, exact
row-level work accounting) plus the paper's §2.1 monitor lane — and are
pinned across engines by the conformance tests; only the execution strategy
(masked jnp, fused Pallas tiles, compacted numpy) differs.

Engines never touch ordering state: the epoch controller
(``core.ordering``) consumes the monitor counters an engine reports. That
seam is what makes backends pluggable — a new engine only has to produce a
correct ``ChainResult``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable


class ChainResult(NamedTuple):
    """Uniform output contract of every filter engine."""

    mask: Any             # bool[R] — rows passing the whole CNF chain
    work_units: Any       # f32[] — row-level cost-weighted work (Spark model)
    active_before: Any    # f32[P] — rows pending evaluation at each position
    cut_counts: Any       # f32[P] — monitor lane: rows failing each predicate
    n_monitored: Any      # f32[] — monitor lane: sampled row count
    monitor_cost: Any     # f32[P] — per-predicate monitor cost contribution
    group_cut_counts: Any  # f32[G] — monitor lane: rows cut by each OR-group


class MonitorSpec(NamedTuple):
    """Monitor-lane parameters threaded to an engine for one batch."""

    collect_rate: int      # static: sample 1 row in every collect_rate
    sample_phase: Any      # i32[] global row offset mod collect_rate
    cost_mode: str = "static"   # "static" | "measured" (host engines only)
    mode: str = "row"           # "row" | "block" (pallas tile sampling)


@runtime_checkable
class FilterEngine(Protocol):
    """The pluggable execution seam (register with ``engine.register``)."""

    name: str
    # True → run_chain is jit/shard_map traceable (device arrays in/out);
    # False → host engine (numpy in/out, may use wall clocks / python loops).
    traceable: bool

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        """Evaluate the CNF chain in ``perm`` order + run the monitor lane."""
        ...

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """``run_chain`` + fixed-capacity survivor compaction in one pass.

        Returns (ChainResult, packed f32[C, capacity], n_kept i32[]).
        Traceable engines must implement this so ``step_compact`` never
        needs a second full-width pass over the batch: the jnp engine
        chains the O(R) cumsum scatter onto its masked evaluation (XLA
        fuses them), the pallas engine packs survivors in-kernel while the
        tile is still in VMEM. Host engines may omit it — their
        boolean-index short-circuit already emits compacted rows.
        """
        ...
