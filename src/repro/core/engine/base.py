"""FilterEngine contract: the one ABI all execution backends implement.

An engine turns (columns, packed predicate specs, permutation, monitor
config) into a ``ChainResult``. The semantics are fixed — CNF evaluation
(OR within a group, AND across groups, short-circuit at both levels, exact
row-level work accounting) plus the paper's §2.1 monitor lane — and are
pinned across engines by the conformance tests; only the execution strategy
(masked jnp, fused Pallas tiles, compacted numpy) differs.

Engines never touch ordering state: the epoch controller
(``core.ordering``) consumes the monitor counters an engine reports. That
seam is what makes backends pluggable — a new engine only has to produce a
correct ``ChainResult``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable


class ChainResult(NamedTuple):
    """Uniform output contract of every filter engine."""

    mask: Any             # bool[R] — rows passing the whole CNF chain
    work_units: Any       # f32[] — row-level cost-weighted work (Spark model)
    active_before: Any    # f32[P] — rows pending evaluation at each position
    cut_counts: Any       # f32[P] — monitor lane: rows failing each predicate
    n_monitored: Any      # f32[] — monitor lane: sampled row count
    monitor_cost: Any     # f32[P] — per-predicate monitor cost contribution
    group_cut_counts: Any  # f32[G] — monitor lane: rows cut by each OR-group
    # skip-tier counters (core/skip_tier.py); zero whenever the tier is off.
    # Work/active counters above charge only ambiguous-tile rows when the
    # tier resolves tiles — the row-level work actually performed.
    n_tiles_pass: Any = 0       # i32[] — tiles provably passing every group
    n_tiles_fail: Any = 0       # i32[] — tiles provably failing some group
    n_tiles_ambiguous: Any = 0  # i32[] — tiles sent to the row-level chain


class SkipInfo(NamedTuple):
    """Tri-state tile resolution produced by an engine's ``triage``.

    ``pass_tiles``/``fail_tiles`` are bool[T] over the engine's padded
    128-row tiling of the batch (mutually exclusive; everything else is
    ambiguous). ``n_ambiguous`` is an i32 scalar the session syncs once per
    step to size the jnp gather width (``skip_tier.quantize_amb_cap``).
    """

    pass_tiles: Any
    fail_tiles: Any
    n_ambiguous: Any


class MonitorSpec(NamedTuple):
    """Monitor-lane parameters threaded to an engine for one batch."""

    collect_rate: int      # static: sample 1 row in every collect_rate
    sample_phase: Any      # i32[] global row offset mod collect_rate
    cost_mode: str = "static"   # "static" | "measured" (host engines only)
    mode: str = "row"           # "row" | "block" (pallas tile sampling)


@runtime_checkable
class FilterEngine(Protocol):
    """The pluggable execution seam (register with ``engine.register``)."""

    name: str
    # True → run_chain is jit/shard_map traceable (device arrays in/out);
    # False → host engine (numpy in/out, may use wall clocks / python loops).
    traceable: bool

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        """Evaluate the CNF chain in ``perm`` order + run the monitor lane."""
        ...

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """``run_chain`` + fixed-capacity survivor compaction in one pass.

        Returns (ChainResult, packed f32[C, capacity], n_kept i32[]).
        Traceable engines must implement this so the fused compacting step
        never needs a second full-width pass over the batch: the jnp
        engine chains the O(R) cumsum scatter onto its masked evaluation
        (XLA fuses them), the pallas engine packs survivors in-kernel
        while the tile is still in VMEM. Host engines may omit it — their
        boolean-index short-circuit already emits compacted rows.
        """
        ...

    # --- optional skip-tier surface (core/skip_tier.py) -----------------
    # Engines that support the tile-statistics skip tier additionally
    # implement:
    #
    #   triage(columns, specs, *, bloom: bool) -> SkipInfo
    #       Zone-map (+ optional Bloom) summaries resolved against the
    #       chain. Specs must be trace-time constants (closed over, not
    #       traced) — resolution branches on each predicate's op in
    #       python.
    #
    #   run_chain_skip(columns, specs, perm, monitor, skip, *, amb_cap)
    #   run_chain_compact_skip(..., capacity, fill)
    #       ``run_chain``/``run_chain_compact`` with provably-decided
    #       tiles bypassing the row-level chain. ``amb_cap`` is the static
    #       gathered width in tiles for engines that gather (jnp); the
    #       pallas engine predicates in-kernel and ignores it. The monitor
    #       lane always runs row-level on the full batch, so ordering
    #       statistics are identical with the tier on or off.
    #
    # ``supports_skip`` (class attribute, default False) advertises this.
