"""``pallas`` engine: fused single-HBM-pass tile kernel (TPU target).

Interpret-mode on non-TPU backends, so the same call validates on CPU and
runs compiled on TPU. See ``kernels/filter_chain`` for the kernel itself.
"""

from __future__ import annotations

from repro.core import engine as engine_lib
from repro.core.engine.base import ChainResult, MonitorSpec, SkipInfo


@engine_lib.register("pallas")
class PallasEngine:
    """Fused VMEM-tile CNF chain with tile-level short-circuit."""

    traceable = True
    supports_skip = True
    # decided tiles are predicated in-kernel from SMEM scalars — no gather,
    # so the session never needs to sync an ambiguous count for this engine
    skip_gathers = False

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            monitor_mode=monitor.mode)

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """Fused in-kernel compaction: survivors are packed per tile while
        the tile is still in VMEM; a second launch stitches tiles at their
        exclusive offsets (see ``kernels/filter_chain/filter_chain.py``)."""
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain_compact(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            capacity=capacity, fill=fill,
            monitor_mode=monitor.mode)

    # ------------------------------------------------------- skip tier
    def triage(self, columns, specs, *, bloom: bool) -> SkipInfo:
        """Pallas stats pre-pass + shared zone-map/Bloom resolution."""
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.skip_triage(columns, specs, bloom=bloom)

    def run_chain_skip(self, columns, specs, perm, monitor: MonitorSpec,
                       skip: SkipInfo, *, amb_cap: int = 0) -> ChainResult:
        """Two-phase launch: decided sub-tiles are predicated in-kernel
        (their rows start non-pending, so the existing ``alive > 0`` cond
        skips every predicate for fully decided grid tiles); ``amb_cap``
        is ignored — nothing is gathered."""
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain_skip(
            columns, specs, perm, skip,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            monitor_mode=monitor.mode)

    def run_chain_compact_skip(self, columns, specs, perm,
                               monitor: MonitorSpec, skip: SkipInfo, *,
                               amb_cap: int = 0, capacity: int,
                               fill: float = 0.0):
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain_compact_skip(
            columns, specs, perm, skip,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            capacity=capacity, fill=fill,
            monitor_mode=monitor.mode)
