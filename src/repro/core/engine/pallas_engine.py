"""``pallas`` engine: fused single-HBM-pass tile kernel (TPU target).

Interpret-mode on non-TPU backends, so the same call validates on CPU and
runs compiled on TPU. See ``kernels/filter_chain`` for the kernel itself.
"""

from __future__ import annotations

from repro.core import engine as engine_lib
from repro.core.engine.base import ChainResult, MonitorSpec


@engine_lib.register("pallas")
class PallasEngine:
    """Fused VMEM-tile CNF chain with tile-level short-circuit."""

    traceable = True

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            monitor_mode=monitor.mode)

    def run_chain_compact(self, columns, specs, perm, monitor: MonitorSpec,
                          *, capacity: int, fill: float = 0.0):
        """Fused in-kernel compaction: survivors are packed per tile while
        the tile is still in VMEM; a second launch stitches tiles at their
        exclusive offsets (see ``kernels/filter_chain/filter_chain.py``)."""
        from repro.kernels.filter_chain import ops as kernel_ops
        return kernel_ops.filter_chain_compact(
            columns, specs, perm,
            collect_rate=monitor.collect_rate,
            sample_phase=monitor.sample_phase,
            capacity=capacity, fill=fill,
            monitor_mode=monitor.mode)
