"""``numpy`` engine: row-exact compacted host path (wall-clock-true).

The one engine that is NOT jit-traceable: it runs python loops over numpy
arrays so that (a) wall time genuinely tracks the evaluation order and
(b) the monitor lane can measure real per-predicate seconds
(``cost_mode="measured"`` — the paper's System.nanoTime analogue).
"""

from __future__ import annotations

import numpy as np

from repro.core import engine as engine_lib
from repro.core import np_exec
from repro.core.engine.base import ChainResult, MonitorSpec
from repro.core.predicates import Predicate, PredicateSpecs, _OP_NAMES


def _preds_from_specs(specs: PredicateSpecs) -> list[Predicate]:
    """Host-side view of the packed ABI (cheap: P is small)."""
    col = np.asarray(specs.column)
    op = np.asarray(specs.op)
    t1 = np.asarray(specs.t1)
    t2 = np.asarray(specs.t2)
    rounds = np.asarray(specs.rounds)
    cost = np.asarray(specs.static_cost)
    return [Predicate(name=f"p{i}_{_OP_NAMES[int(op[i])]}",
                      column=int(col[i]), op=int(op[i]),
                      t1=float(t1[i]), t2=float(t2[i]),
                      rounds=int(rounds[i]), static_cost=float(cost[i]))
            for i in range(specs.n)]


@engine_lib.register("numpy")
class NumpyEngine:
    """Compacted short-circuit CNF chain on the host (Spark's processNext)."""

    traceable = False

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        columns = np.asarray(columns)
        preds = _preds_from_specs(specs)
        groups = specs.groups
        perm = np.asarray(perm)

        mask, work, active_before = np_exec.run_chain_np(
            columns, preds, perm, groups=groups)
        cut, group_cut, n_mon, secs = np_exec.run_monitor_np(
            columns, preds, monitor.collect_rate,
            int(monitor.sample_phase), groups=groups)
        if monitor.cost_mode == "measured":
            monitor_cost = secs
        else:
            monitor_cost = np.asarray(
                [p.static_cost for p in preds], np.float64) * n_mon
        return ChainResult(
            mask=mask,
            work_units=np.float32(work),
            active_before=active_before,
            cut_counts=cut.astype(np.float32),
            n_monitored=np.float32(n_mon),
            monitor_cost=monitor_cost.astype(np.float32),
            group_cut_counts=group_cut.astype(np.float32),
        )
