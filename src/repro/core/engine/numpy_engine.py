"""``numpy`` engine: row-exact compacted host path (wall-clock-true).

The one engine that is NOT jit-traceable: it runs python loops over numpy
arrays so that (a) wall time genuinely tracks the evaluation order and
(b) the monitor lane can measure real per-predicate seconds
(``cost_mode="measured"`` — the paper's System.nanoTime analogue).
"""

from __future__ import annotations

import numpy as np

from repro.core import engine as engine_lib
from repro.core import np_exec
from repro.core.engine.base import ChainResult, MonitorSpec
from repro.core.predicates import Predicate, PredicateSpecs, _OP_NAMES


def _preds_from_specs(specs: PredicateSpecs) -> list[Predicate]:
    """Host-side view of the packed ABI (cheap: P is small)."""
    col = np.asarray(specs.column)
    op = np.asarray(specs.op)
    t1 = np.asarray(specs.t1)
    t2 = np.asarray(specs.t2)
    rounds = np.asarray(specs.rounds)
    cost = np.asarray(specs.static_cost)
    return [Predicate(name=f"p{i}_{_OP_NAMES[int(op[i])]}",
                      column=int(col[i]), op=int(op[i]),
                      t1=float(t1[i]), t2=float(t2[i]),
                      rounds=int(rounds[i]), static_cost=float(cost[i]))
            for i in range(specs.n)]


@engine_lib.register("numpy")
class NumpyEngine:
    """Compacted short-circuit CNF chain on the host (Spark's processNext)."""

    traceable = False
    supports_skip = True
    skip_gathers = False     # host path indexes ambiguous rows directly

    def _monitor(self, columns, preds, monitor: MonitorSpec, groups):
        cut, group_cut, n_mon, secs = np_exec.run_monitor_np(
            columns, preds, monitor.collect_rate,
            int(monitor.sample_phase), groups=groups)
        if monitor.cost_mode == "measured":
            monitor_cost = secs
        else:
            monitor_cost = np.asarray(
                [p.static_cost for p in preds], np.float64) * n_mon
        return cut, group_cut, n_mon, monitor_cost

    def run_chain(self, columns, specs, perm,
                  monitor: MonitorSpec) -> ChainResult:
        columns = np.asarray(columns)
        preds = _preds_from_specs(specs)
        groups = specs.groups
        perm = np.asarray(perm)

        mask, work, active_before = np_exec.run_chain_np(
            columns, preds, perm, groups=groups)
        cut, group_cut, n_mon, monitor_cost = self._monitor(
            columns, preds, monitor, groups)
        return ChainResult(
            mask=mask,
            work_units=np.float32(work),
            active_before=active_before,
            cut_counts=cut.astype(np.float32),
            n_monitored=np.float32(n_mon),
            monitor_cost=monitor_cost.astype(np.float32),
            group_cut_counts=group_cut.astype(np.float32),
        )

    # ------------------------------------------------------- skip tier
    def triage(self, columns, specs, *, bloom: bool):
        """Reference zone-map/Bloom triage (shared math, xp=numpy)."""
        from repro.core import skip_tier
        return skip_tier.triage(np.asarray(columns), specs, bloom=bloom,
                                xp=np)

    def run_chain_skip(self, columns, specs, perm, monitor: MonitorSpec,
                       skip=None, *, bloom: bool = False,
                       amb_cap: int = 0) -> ChainResult:
        """Row-exact reference of the skip tier: decided 128-row tiles
        bypass ``run_chain_np``; only ambiguous tiles' rows are evaluated
        (and charged). ``skip=None`` computes the triage internally (host
        streaming path); ``amb_cap`` is ignored — the host indexes the
        ambiguous rows directly. Monitor lane: full batch, unchanged."""
        from repro.core import skip_tier

        columns = np.asarray(columns)
        if skip is None:
            skip = self.triage(columns, specs, bloom=bloom)
        preds = _preds_from_specs(specs)
        groups = specs.groups
        perm = np.asarray(perm)
        n_rows = columns.shape[1]
        tile = skip_tier.SKIP_TILE

        pass_t = np.asarray(skip.pass_tiles)
        fail_t = np.asarray(skip.fail_tiles)
        amb_tiles = np.nonzero(~(pass_t | fail_t))[0]
        rows = (amb_tiles[:, None] * tile +
                np.arange(tile)[None, :]).reshape(-1)
        rows = rows[rows < n_rows]

        sub_mask, work, active_before = np_exec.run_chain_np(
            columns[:, rows], preds, perm, groups=groups)
        mask = np.zeros(n_rows, bool)
        prows = (np.nonzero(pass_t)[0][:, None] * tile +
                 np.arange(tile)[None, :]).reshape(-1)
        mask[prows[prows < n_rows]] = True
        mask[rows] = sub_mask

        cut, group_cut, n_mon, monitor_cost = self._monitor(
            columns, preds, monitor, groups)
        n_pass_t, n_fail_t, n_amb_t = skip_tier.tile_counters(skip, np)
        return ChainResult(
            mask=mask,
            work_units=np.float32(work),
            active_before=active_before,
            cut_counts=cut.astype(np.float32),
            n_monitored=np.float32(n_mon),
            monitor_cost=monitor_cost.astype(np.float32),
            group_cut_counts=group_cut.astype(np.float32),
            n_tiles_pass=np.int32(n_pass_t),
            n_tiles_fail=np.int32(n_fail_t),
            n_tiles_ambiguous=np.int32(n_amb_t),
        )
