"""Adaptive filter ordering — the paper's contribution, as a JAX module.

Public API (one plan, one session):
  FilterPlan, TokenizeSpec         — THE declarative config surface
                                     (engine × scope × shards × compaction
                                     × exchange × tokenize, validated once)
  build_session → FilterSession    — compiled plan; one ``session.step``
                                     returning the uniform StepResult ABI,
                                     versioned elastic checkpoints
  Predicate, pack, OP_*            — predicate algebra (CNF via ``group``)
  OrderingConfig, OrderState       — Table-1 parameters + adaptive state
  Scope, EXCHANGE_MODES            — per_batch / per_shard / centralized +
                                     eager / deferred / deferred-async
  engine (get_engine/register)     — pluggable execution backends
  AdaptiveFilter, ShardedAdaptiveFilter, static_filter — the functional
                                     step math sessions compile (legacy
                                     step_compact surfaces are shims)
"""

from repro.core.adaptive_filter import (AdaptiveFilter, AdaptiveFilterConfig,
                                        StepMetrics, static_filter)
from repro.core.engine import (ChainResult, FilterEngine, MonitorSpec,
                               available_engines, get_engine)
from repro.core.ordering import OrderingConfig, OrderState, init_order_state
from repro.core.plan import FilterPlan, TokenizeSpec
from repro.core.predicates import (OP_BETWEEN, OP_EQ, OP_GT, OP_HASHMIX,
                                   OP_LT, Predicate, PredicateSpecs, pack,
                                   paper_filters_4, paper_filters_cnf)
from repro.core.scope import EXCHANGE_MODES, Scope
from repro.core.session import FilterSession, StepResult, build_session
from repro.core.sharded import (ShardedAdaptiveFilter, shard_slice,
                                stack_states)
from repro.core.stats import FilterStats

__all__ = [
    "FilterPlan", "TokenizeSpec", "FilterSession", "StepResult",
    "build_session",
    "AdaptiveFilter", "AdaptiveFilterConfig", "StepMetrics", "static_filter",
    "ShardedAdaptiveFilter", "shard_slice", "stack_states",
    "ChainResult", "FilterEngine", "MonitorSpec", "available_engines",
    "get_engine",
    "OrderingConfig", "OrderState", "init_order_state",
    "OP_BETWEEN", "OP_EQ", "OP_GT", "OP_HASHMIX", "OP_LT",
    "Predicate", "PredicateSpecs", "pack", "paper_filters_4",
    "paper_filters_cnf",
    "Scope", "EXCHANGE_MODES", "FilterStats",
]
