"""Row-exact numpy backend: compacted short-circuit evaluation.

This is the host-side execution path used by the benchmarks and by
``executor_sim.py``. It mirrors what Spark's generated ``processNext`` does —
a row is never evaluated against predicates later in the order once it fails
one — by *compacting* the active row set between predicates (boolean-index
gather). Wall time therefore genuinely depends on the evaluation order,
which is what Figures 1–4 of the paper measure.

Semantics are bit-identical to ``core.filter_exec`` / the Pallas kernel
(cross-checked in tests); only the execution strategy differs.
"""

from __future__ import annotations

import numpy as np

from repro.core import predicates as pred_lib


def eval_pred_np(op: int, t1: float, t2: float, rounds: int,
                 x: np.ndarray) -> np.ndarray:
    if op == pred_lib.OP_GT:
        return x > t1
    if op == pred_lib.OP_LT:
        return x < t1
    if op == pred_lib.OP_BETWEEN:
        return (x > t1) & (x < t2)
    if op == pred_lib.OP_EQ:
        return np.round(x) == np.round(t1)
    if op == pred_lib.OP_HASHMIX:
        y = x.astype(np.float32)
        for _ in range(max(rounds, 1)):
            y = y * np.float32(pred_lib.MIX_MUL) + np.float32(pred_lib.MIX_ADD)
            y = y - np.floor(y / np.float32(pred_lib.MIX_MOD)) * np.float32(pred_lib.MIX_MOD)
        return y > t1
    raise ValueError(f"unknown op {op}")


def run_chain_np(columns: np.ndarray, preds, perm) -> tuple[np.ndarray, float, np.ndarray]:
    """Short-circuit chain in ``perm`` order with inter-predicate compaction.

    Returns (mask bool[R], work_units, active_before f32[P]). ``preds`` is a
    sequence of ``Predicate``. Work accounting matches the jnp/Pallas paths:
    predicate perm[k] is charged static_cost × rows alive before it.
    """
    n_rows = columns.shape[1]
    alive_idx = np.arange(n_rows)
    mask = np.zeros(n_rows, dtype=bool)
    work = 0.0
    active_before = np.zeros(len(preds), np.float32)

    for k, pi in enumerate(perm):
        p = preds[int(pi)]
        active_before[k] = alive_idx.size
        work += alive_idx.size * p.static_cost
        if alive_idx.size == 0:
            continue
        x = columns[p.column, alive_idx]
        res = eval_pred_np(p.op, p.t1, p.t2, p.rounds, x)
        alive_idx = alive_idx[res]          # compaction == short-circuit

    mask[alive_idx] = True
    return mask, float(work), active_before


def run_monitor_np(columns: np.ndarray, preds, collect_rate: int,
                   sample_phase: int) -> tuple[np.ndarray, int, np.ndarray]:
    """Monitor lane: all predicates on stride-sampled rows (paper §2.1).

    Returns (cut_counts f64[P], n_monitored, per-predicate measured seconds).
    The measured clock here is the numpy analogue of the paper's
    ``System.nanoTime`` around each predicate evaluation.
    """
    import time

    n_rows = columns.shape[1]
    first = (-sample_phase) % collect_rate
    idx = np.arange(first, n_rows, collect_rate)
    cut = np.zeros(len(preds), np.float64)
    secs = np.zeros(len(preds), np.float64)
    if idx.size == 0:
        return cut, 0, secs
    for i, p in enumerate(preds):
        x = columns[p.column, idx]
        t0 = time.perf_counter()
        res = eval_pred_np(p.op, p.t1, p.t2, p.rounds, x)
        secs[i] = time.perf_counter() - t0
        cut[i] = np.sum(~res)
    return cut, int(idx.size), secs
