"""Row-exact numpy backend: compacted short-circuit evaluation.

This is the host-side execution path used by the ``numpy`` engine, the
benchmarks, and ``executor_sim.py``. It mirrors what Spark's generated
``processNext`` does — a row is never evaluated against predicates later in
the order once its fate is decided — by *compacting* the active row set
between predicates (boolean-index gather). Wall time therefore genuinely
depends on the evaluation order, which is what Figures 1–4 of the paper
measure.

CNF semantics match the jnp / Pallas engines exactly (cross-checked in
tests): within an OR-group a row stops evaluating members once one passes;
a row that fails every member of a group is dropped before the next group.

Semantics are bit-identical to ``core.filter_exec`` / the Pallas kernel;
only the execution strategy differs.
"""

from __future__ import annotations

import numpy as np

from repro.core import predicates as pred_lib


def eval_pred_np(op: int, t1: float, t2: float, rounds: int,
                 x: np.ndarray) -> np.ndarray:
    if op == pred_lib.OP_GT:
        return x > t1
    if op == pred_lib.OP_LT:
        return x < t1
    if op == pred_lib.OP_BETWEEN:
        return (x > t1) & (x < t2)
    if op == pred_lib.OP_EQ:
        return np.round(x) == np.round(t1)
    if op == pred_lib.OP_HASHMIX:
        y = x.astype(np.float32)
        for _ in range(max(rounds, 1)):
            y = y * np.float32(pred_lib.MIX_MUL) + np.float32(pred_lib.MIX_ADD)
            y = y - np.floor(y / np.float32(pred_lib.MIX_MOD)) * np.float32(pred_lib.MIX_MOD)
        return y > t1
    raise ValueError(f"unknown op {op}")


def _groups_for(preds, groups) -> np.ndarray:
    if groups is None:
        return np.arange(len(preds))
    g = np.asarray(groups, np.int64)
    if g.shape != (len(preds),):
        raise ValueError("groups must give one id per predicate")
    return g


def run_chain_np(columns: np.ndarray, preds, perm,
                 groups=None) -> tuple[np.ndarray, float, np.ndarray]:
    """Short-circuit CNF chain in ``perm`` order with compaction.

    Returns (mask bool[R], work_units, active_before f32[P]). ``preds`` is a
    sequence of ``Predicate``; ``groups`` the dense group-id-per-predicate
    tuple (None → singletons, the flat conjunction). Group members must be
    contiguous in ``perm``. Work accounting matches the jnp/Pallas paths:
    position k is charged static_cost × rows pending before it.
    """
    g = _groups_for(preds, groups)
    n_rows = columns.shape[1]
    mask = np.zeros(n_rows, dtype=bool)
    work = 0.0
    active_before = np.zeros(len(preds), np.float32)

    perm = [int(i) for i in perm]
    seq = [int(g[i]) for i in perm]
    runs = [x for j, x in enumerate(seq) if j == 0 or seq[j - 1] != x]
    if len(set(runs)) != len(runs):
        raise ValueError("group members must be contiguous in perm")

    alive_idx = np.arange(n_rows)        # survivors of all closed groups
    k = 0
    while k < len(perm):
        gid = g[perm[k]]
        # pending = alive rows not yet passed by this OR-group
        pending = alive_idx
        passed = np.zeros(0, np.int64)
        while k < len(perm) and g[perm[k]] == gid:
            p = preds[perm[k]]
            active_before[k] = pending.size
            work += pending.size * p.static_cost
            if pending.size:
                x = columns[p.column, pending]
                res = eval_pred_np(p.op, p.t1, p.t2, p.rounds, x)
                passed = np.concatenate([passed, pending[res]])
                pending = pending[~res]      # OR short-circuit on first pass
            k += 1
        # group closes: rows that passed no member are cut
        alive_idx = np.sort(passed)

    mask[alive_idx] = True
    return mask, float(work), active_before


def run_monitor_np(columns: np.ndarray, preds, collect_rate: int,
                   sample_phase: int,
                   groups=None) -> tuple[np.ndarray, np.ndarray, int,
                                         np.ndarray]:
    """Monitor lane: all predicates on stride-sampled rows (paper §2.1).

    Returns (cut_counts f64[P], group_cut f64[G], n_monitored,
    per-predicate measured seconds). The measured clock here is the numpy
    analogue of the paper's ``System.nanoTime`` around each predicate
    evaluation.
    """
    import time

    g = _groups_for(preds, groups)
    n_groups = int(g.max()) + 1
    n_rows = columns.shape[1]
    first = (-sample_phase) % collect_rate
    idx = np.arange(first, n_rows, collect_rate)
    cut = np.zeros(len(preds), np.float64)
    group_cut = np.zeros(n_groups, np.float64)
    secs = np.zeros(len(preds), np.float64)
    if idx.size == 0:
        return cut, group_cut, 0, secs
    group_fail = np.ones((n_groups, idx.size), bool)
    for i, p in enumerate(preds):
        x = columns[p.column, idx]
        t0 = time.perf_counter()
        res = eval_pred_np(p.op, p.t1, p.t2, p.rounds, x)
        secs[i] = time.perf_counter() - t0
        cut[i] = np.sum(~res)
        group_fail[g[i]] &= ~res
    group_cut[:] = group_fail.sum(axis=1)
    return cut, group_cut, int(idx.size), secs
