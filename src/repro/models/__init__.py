"""Model zoo: the 10 assigned architectures on one scan-over-layers spine."""

from repro.models.registry import build_model

__all__ = ["build_model"]
