"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

Dispatch is the capacity-buffer formulation (GShard-style, sort-free): each
(token, k) assignment gets a slot in a per-expert buffer [E, C, D]; expert
FFNs run as one grouped einsum over E; outputs gather back weighted. Under
GSPMD the E axis is sharded over the ``model`` mesh axis (expert
parallelism) and the scatter/gather lower to cross-shard collectives; the
shard_map all-to-all variant is evaluated in EXPERIMENTS §Perf.

Routing: softmax → top-k, renormalized (DeepSeek-V3 style), plus the
standard load-balance auxiliary loss. The dense path is dropless (buffer
capacity = token count, so outputs are batch-composition-independent — see
``moe_ffn_dense``); the EP path keeps bounded per-rank capacity, where
over-capacity assignments drop (their combine weight zeroes) to cap the
all_to_all buffer sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split
from repro.models.ffn import ffn, init_ffn


def init_moe(key, cfg):
    e, d = cfg.moe, cfg.d_model
    ks = split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e.n_experts, jnp.float32),
        "w_gate": dense_init(ks[1], e.n_experts * d, e.d_expert).reshape(
            e.n_experts, d, e.d_expert),
        "w_up": dense_init(ks[2], e.n_experts * d, e.d_expert).reshape(
            e.n_experts, d, e.d_expert),
        "w_down": dense_init(ks[3], e.n_experts * e.d_expert, d).reshape(
            e.n_experts, e.d_expert, d),
    }
    if e.n_shared:
        p["shared"] = init_ffn(ks[4], d, e.n_shared * e.d_expert)
    return p


def moe_ffn(params, cfg, x):
    """x: [B, S, D] → (y, aux_loss). Picks the EP all-to-all path when the
    launcher enabled sharding hints and the expert count divides the model
    axis (§Perf iteration 1); otherwise the GSPMD capacity-buffer path."""
    from repro.parallel import hints

    e = cfg.moe
    if hints.enabled():
        mesh = hints.mesh()
        if mesh is not None:
            tp = mesh.shape.get(hints.axes("tp"), 1)
            if tp > 1 and e.n_experts % tp == 0 and x.shape[1] % tp == 0:
                return moe_ffn_ep(params, cfg, x, mesh)
    return moe_ffn_dense(params, cfg, x)


def moe_ffn_dense(params, cfg, x):
    """Einsum/scatter dispatch (single-device & fallback path) — dropless.

    The buffer capacity is the token count itself, so no assignment ever
    drops and each token's output is a pure function of (token, weights).
    That invariant is what makes serving correct: the same token produces
    bit-identical results in a full-sequence train forward, a (T-1)-token
    prefill, and a 1-token decode step. A token-count-scaled capacity
    (``int(t·k/E·cf)+1``) breaks it two ways: the cap rounds differently per
    call so prefill drops assignments the full forward keeps (stale KV
    cache), and at decode t is so small the cap collapses to 1, dropping
    live assignments outright. Both modeled MoE families are dropless in
    production (DeepSeek-V3 drops no tokens; DBRX is dropless MegaBlocks).
    Bounded-capacity semantics live on in ``moe_ffn_ep``, where capacity
    bounds the all_to_all buffers — a real network constraint.
    """
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    top_w, top_i = jax.lax.top_k(probs, e.top_k)               # [T, k]
    top_w = top_w / jnp.maximum(
        jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(top_i[:, 0], e.n_experts, dtype=jnp.float32),
                 axis=0)
    aux = e.n_experts * jnp.sum(f * jnp.mean(probs, axis=0)) \
        * e.router_aux_weight

    # ---- dropless dispatch -------------------------------------------------
    # top-k indices are distinct per token, so per-expert load ≤ t: a t-slot
    # buffer can never overflow (costs k/E·cf× more slots than a capacity
    # buffer — the price of batch-composition-independent outputs).
    cap = t
    flat_e = top_i.reshape(-1)                                  # [T*k]
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), e.top_k)

    # slot within expert = how many earlier assignments chose the same expert
    onehot = jax.nn.one_hot(flat_e, e.n_experts, dtype=jnp.int32)  # [Tk, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    slot = jnp.sum(pos_in_e * onehot, axis=-1)                     # [Tk]

    buf = jnp.zeros((e.n_experts, cap, d), x.dtype)
    buf = buf.at[flat_e, slot].set(xf[flat_t])

    # ---- grouped expert FFN ------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))

    # ---- combine -----------------------------------------------------------
    gathered = y_e[flat_e, slot]                                   # [Tk, D]
    w = flat_w.astype(x.dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(t, e.top_k, d), axis=1)

    if e.n_shared:
        y = y + ffn(params["shared"], x).reshape(t, d)
    return y.reshape(b, s, d), aux


# ===================================================================== EP
def moe_ffn_ep(params, cfg, x, mesh):
    """Expert-parallel MoE via shard_map + all_to_all (§Perf iteration 1).

    The GSPMD capacity-buffer path scatters tokens into EXPERT-sharded
    buffers straight from TOKEN-sharded activations — on the 671B config the
    partitioner materializes a [T·k, E] cumsum and reduces dispatch tensors
    across the model axis: 16.5 TB/chip of all-reduce wire bytes (measured,
    EXPERIMENTS §Perf). Here the exchange is explicit and minimal:

      per device: local router → top-k → bucket by destination EP rank
      (exclusive-cumsum slotting, LOCAL [T_loc·k, M] only) → one all_to_all
      carrying each token once per chosen expert → local grouped FFN over
      E/M experts → reverse all_to_all → weighted combine.

    Drop semantics: fixed per-(source, dest) capacity, like production
    capacity-factor routing (slightly different drop set than the global-
    capacity dense path; equal in expectation).
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel import hints

    e = cfg.moe
    b, s, d = x.shape
    dp_axes = hints._STATE["dp"]
    tp_axis = hints.axes("tp")
    m = mesh.shape[tp_axis]
    e_loc = e.n_experts // m
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    # tokens are sharded over BOTH the data axes (batch) and the model axis
    # (sequence): every EP rank routes a DISTINCT token slice — with
    # model-replicated tokens the exchange and expert compute would be
    # tp-times redundant (measured: +171%% compute, first attempt, §Perf)
    t_loc = (b // dp_total) * (s // m)
    cap = int(t_loc * e.top_k / m * e.capacity_factor) + 1
    r_tot = m * (cap + 1)
    cap2 = int(r_tot / e_loc * e.capacity_factor) + 1

    def local(xb, router_w, w_gate, w_up, w_down):
        # xb: [B_loc, S_loc, D]; experts already sliced to [E_loc, D, F]
        xf = xb.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, e.top_k)
        top_w = top_w / jnp.maximum(
            jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

        f = jnp.mean(jax.nn.one_hot(top_i[:, 0], e.n_experts,
                                    dtype=jnp.float32), axis=0)
        aux = e.n_experts * jnp.sum(f * jnp.mean(probs, axis=0)) \
            * e.router_aux_weight
        aux = jax.lax.pmean(jax.lax.pmean(aux, tp_axis), dp_axes)

        # ---- bucket by destination EP rank (all indices LOCAL) ----------
        flat_e = top_i.reshape(-1)                        # [A]
        flat_w = top_w.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc), e.top_k)
        dest = flat_e // e_loc                            # [A] → rank
        oh = jax.nn.one_hot(dest, m, dtype=jnp.int32)
        pos = (jnp.cumsum(oh, axis=0) - oh)
        slot = jnp.take_along_axis(pos, dest[:, None], 1)[:, 0]
        keep = slot < cap
        slot = jnp.where(keep, slot, cap)

        send_x = jnp.zeros((m, cap + 1, d), xb.dtype)
        send_x = send_x.at[dest, slot].set(xf[flat_t])
        send_id = jnp.full((m, cap + 1), -1, jnp.int32)
        send_id = send_id.at[dest, slot].set(
            jnp.where(keep, flat_e % e_loc, -1))

        recv_x = jax.lax.all_to_all(send_x, tp_axis, 0, 0)
        recv_id = jax.lax.all_to_all(send_id, tp_axis, 0, 0)

        # ---- local grouped FFN over E_loc experts -----------------------
        rx = recv_x.reshape(r_tot, d)
        re = recv_id.reshape(r_tot)
        valid = re >= 0
        rec = jnp.clip(re, 0, e_loc - 1)
        oh2 = jax.nn.one_hot(rec, e_loc, dtype=jnp.int32) * valid[:, None]
        pos2 = (jnp.cumsum(oh2, axis=0) - oh2)
        slot2 = jnp.take_along_axis(pos2, rec[:, None], 1)[:, 0]
        keep2 = jnp.logical_and(slot2 < cap2, valid)
        slot2 = jnp.where(keep2, slot2, cap2)

        buf = jnp.zeros((e_loc, cap2 + 1, d), xb.dtype)
        buf = buf.at[rec, slot2].set(rx)
        gg = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xb.dtype))
        uu = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xb.dtype))
        hh = jax.nn.silu(gg) * uu
        y_e = jnp.einsum("ecf,efd->ecd", hh, w_down.astype(xb.dtype))

        y_recv = y_e[rec, slot2] * keep2[:, None].astype(xb.dtype)
        y_back = jax.lax.all_to_all(
            y_recv.reshape(m, cap + 1, d), tp_axis, 0, 0)

        gathered = y_back[dest, slot] * keep[:, None].astype(xb.dtype)
        y = jnp.sum((gathered * flat_w[:, None].astype(xb.dtype))
                    .reshape(t_loc, e.top_k, d), axis=1)
        return y.reshape(xb.shape), aux

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    # y is replicated over the model axis by construction (each rank gets
    # its own tokens back from the reverse all_to_all) — the static VMA
    # checker can't see through the round-trip, hence check_vma=False.
    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, tp_axis, None), P(None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None)),
        out_specs=(P(dp, tp_axis, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])

    if e.n_shared:
        y = y + ffn(params["shared"], x)
    return y, aux
