"""The decoder spine: every assigned arch on one scan-over-layers skeleton.

Families:
  dense / vlm      — (MLA|GQA) attention + SwiGLU FFN
  moe              — attention + MoE FFN (+ shared experts, + MTP head)
  ssm              — RWKV6 blocks (attention-free)
  hybrid           — Mamba2 blocks + ONE shared attention block applied every
                     ``hybrid_attn_every`` layers (Zamba2)
  audio            — whisper enc-dec (encoder over stub frame embeddings)

Per-layer params are stacked on a leading axis and consumed by ``lax.scan``:
HLO size and compile time are depth-independent (the 40-cell × 2-mesh
dry-run depends on this). The train path wraps the scan body in
``jax.checkpoint`` (full remat baseline; policy is a §Perf knob).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import common, ffn as ffn_lib, moe as moe_lib
from repro.models import rwkv as rwkv_lib, ssm as ssm_lib
from repro.models.common import (cross_entropy, dense_init, embed, rms_norm,
                                 softcap, split, unembed)

BIG_WINDOW = 1 << 30   # "no window" as a dynamic value


# ============================================================== param init
def init_attn_layer(key, cfg):
    """One (attention|MLA) + (FFN|MoE) layer."""
    ks = split(key, 4)
    p = {"norm1": jnp.zeros((cfg.d_model,), common.PARAM_DTYPE),
         "norm2": jnp.zeros((cfg.d_model,), common.PARAM_DTYPE)}
    if cfg.post_norm:
        p["post1"] = jnp.zeros((cfg.d_model,), common.PARAM_DTYPE)
        p["post2"] = jnp.zeros((cfg.d_model,), common.PARAM_DTYPE)
    if cfg.mla is not None:
        p["attn"] = attn_lib.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn_lib.init_attn(ks[0], cfg)
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["ffn"] = ffn_lib.init_ffn(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg):
    ks = split(key, 8)
    p: dict[str, Any] = {
        "embed": common.uniform_init(ks[0], (cfg.vocab, cfg.d_model), 0.02),
        "final_norm": jnp.zeros((cfg.d_model,), common.PARAM_DTYPE),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab)

    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    if cfg.family == "ssm":
        p["layers"] = jax.vmap(
            lambda k: rwkv_lib.init_rwkv_block(k, cfg))(layer_keys)
    elif cfg.family == "hybrid":
        p["layers"] = jax.vmap(
            lambda k: ssm_lib.init_mamba2(k, cfg))(layer_keys)
        p["shared_attn"] = init_attn_layer(ks[3], cfg)
    elif cfg.family == "audio":
        p["layers"] = jax.vmap(
            lambda k: _init_decdec_layer(k, cfg))(layer_keys)
        enc_keys = jax.random.split(ks[4], cfg.enc_layers)
        p["enc_layers"] = jax.vmap(
            lambda k: init_attn_layer(k, cfg))(enc_keys)
        p["enc_norm"] = jnp.zeros((cfg.d_model,), common.PARAM_DTYPE)
        p["enc_pos"] = common.uniform_init(ks[5], (cfg.enc_seq, cfg.d_model),
                                           0.02)
    else:
        p["layers"] = jax.vmap(lambda k: init_attn_layer(k, cfg))(layer_keys)

    if cfg.mtp_heads:
        p["mtp"] = {
            "norm_h": jnp.zeros((cfg.d_model,), common.PARAM_DTYPE),
            "norm_e": jnp.zeros((cfg.d_model,), common.PARAM_DTYPE),
            "proj": dense_init(ks[6], 2 * cfg.d_model, cfg.d_model),
            "layer": init_attn_layer(ks[7], cfg),
        }
    return p


def _init_decdec_layer(key, cfg):
    """Whisper decoder layer: self-attn + cross-attn + FFN."""
    ks = split(key, 3)
    p = init_attn_layer(ks[0], cfg)
    p["xnorm"] = jnp.zeros((cfg.d_model,), common.PARAM_DTYPE)
    p["xattn"] = attn_lib.init_attn(ks[1], cfg)
    return p


# ============================================================ layer bodies
def _window_for_layer(cfg, idx):
    """Dynamic window size: local layers get cfg.window, global layers get
    BIG_WINDOW (gemma2 alternation) — dynamic so it lives inside scan."""
    if cfg.window is None:
        return None
    if not cfg.local_global_every:
        return jnp.asarray(cfg.window, jnp.int32)
    is_global = ((idx + 1) % cfg.local_global_every) == 0
    return jnp.where(is_global, BIG_WINDOW, cfg.window).astype(jnp.int32)


def attn_layer_fwd(lp, cfg, x, positions, idx, aux):
    """Full-sequence (train/prefill) attention layer."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, c_kv, k_rope = attn_lib.mla_attention(lp["attn"], cfg, h,
                                                 positions, cfg.attn_chunk)
        cache_kv = (c_kv, k_rope)
    else:
        q, k, v = attn_lib.qkv(lp["attn"], cfg, h, positions)
        o = attn_lib.chunked_attention(
            q, k, v, causal=True, window=_window_for_layer(cfg, idx),
            cap=cfg.attn_softcap, chunk=cfg.attn_chunk)
        b, s = x.shape[:2]
        o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
        a = jnp.einsum("bsk,kd->bsd", o, lp["attn"]["wo"].astype(x.dtype))
        cache_kv = (k, v)
    if cfg.post_norm:
        a = rms_norm(a, lp["post1"], cfg.norm_eps)
    x = x + a

    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, moe_aux = moe_lib.moe_ffn(lp["moe"], cfg, h)
        aux = aux + moe_aux
    else:
        f = ffn_lib.ffn(lp["ffn"], h)
    if cfg.post_norm:
        f = rms_norm(f, lp["post2"], cfg.norm_eps)
    return x + f, aux, cache_kv


def attn_layer_decode(lp, cfg, x, pos, cache, idx):
    """One-token decode with cache update. cache: family-specific tuple."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        ckv, krope = cache
        a, ckv, krope = attn_lib.mla_decode(lp["attn"], cfg, h, pos, ckv,
                                            krope, pos + 1)
        cache = (ckv, krope)
    else:
        k_cache, v_cache = cache
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        if cfg.rope_style == "mrope":
            positions = jnp.broadcast_to(positions, (3, b, 1))
        q, k, v = attn_lib.qkv(lp["attn"], cfg, h, positions)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
        w = _window_for_layer(cfg, idx)
        o = attn_lib.decode_attention(
            q, k_cache, v_cache, pos + 1,
            window=None if w is None else w, cap=cfg.attn_softcap)
        o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
        a = jnp.einsum("bsk,kd->bsd", o, lp["attn"]["wo"].astype(x.dtype))
        cache = (k_cache, v_cache)
    if cfg.post_norm:
        a = rms_norm(a, lp["post1"], cfg.norm_eps)
    x = x + a

    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, _ = moe_lib.moe_ffn(lp["moe"], cfg, h)
    else:
        f = ffn_lib.ffn(lp["ffn"], h)
    if cfg.post_norm:
        f = rms_norm(f, lp["post2"], cfg.norm_eps)
    return x + f, cache


# ========================================================== forward (full)
def forward(params, cfg, batch, *, mode: str, remat: bool = True):
    """Full-sequence pass. mode: train | prefill.

    Returns (hidden [B,S,D], aux_loss, cache) — cache is the stacked
    per-layer KV/state pytree when mode == "prefill", else None.
    """
    want_cache = mode == "prefill"
    if cfg.embeds_input:
        x = batch["embeds"].astype(common.COMPUTE_DTYPE)
    else:
        x = embed(batch["tokens"], params["embed"])
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "ssm":
        return _forward_rwkv(params, cfg, x, want_cache, remat)
    if cfg.family == "hybrid":
        return _forward_hybrid(params, cfg, x, positions, want_cache, remat)
    if cfg.family == "audio":
        return _forward_whisper(params, cfg, x, batch, positions, want_cache,
                                remat)

    def body(carry, inp):
        xc, aux = carry
        lp, idx = inp
        xn, aux, kv = attn_layer_fwd(lp, cfg, xc, positions, idx, aux)
        return (xn, aux), kv if want_cache else None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, caches


def _forward_rwkv(params, cfg, x, want_cache, remat):
    b = x.shape[0]

    def body(xc, lp):
        carry0 = rwkv_lib.init_rwkv_carry(cfg, b)
        xn, carry = rwkv_lib.rwkv_block(lp, cfg, xc, carry0)
        return xn, carry if want_cache else None

    if remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), caches


def _forward_hybrid(params, cfg, x, positions, want_cache, remat):
    """Zamba2: groups of ``hybrid_attn_every`` mamba blocks; after each
    group the ONE shared attention block runs (fresh KV per application)."""
    every = cfg.hybrid_attn_every or cfg.n_layers
    n_groups = cfg.n_layers // every
    aux = jnp.zeros((), jnp.float32)

    def mamba_body(xc, lp):
        y, (h, conv) = ssm_lib.mamba2_forward(lp, cfg, xc)
        return xc + y, (h, conv) if want_cache else None

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["layers"])

    ssm_caches, attn_caches = [], []
    for gi in range(n_groups):
        gparams = jax.tree.map(lambda a: a[gi], grouped)
        x, gcache = jax.lax.scan(mamba_body, x, gparams)
        x, aux, kv = attn_layer_fwd(params["shared_attn"], cfg, x, positions,
                                    jnp.asarray(gi), aux)
        if want_cache:
            ssm_caches.append(gcache)
            attn_caches.append(kv)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if want_cache:
        ssm_stack = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                 *ssm_caches)
        attn_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *attn_caches)
        cache = (ssm_stack, attn_stack)
    return x, aux, cache


def _forward_whisper(params, cfg, x, batch, positions, want_cache, remat):
    """x here is the DECODER token embedding; encoder consumes the stub
    frame embeddings batch["enc_embeds"]."""
    enc = batch["enc_embeds"].astype(common.COMPUTE_DTYPE)
    enc = enc + params["enc_pos"].astype(enc.dtype)[None, :enc.shape[1]]
    eb, es = enc.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(es, dtype=jnp.int32), (eb, es))

    def enc_body(xc, lp):
        h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
        q, k, v = attn_lib.qkv(lp["attn"], cfg, h, enc_pos)
        o = attn_lib.chunked_attention(q, k, v, causal=False,
                                       chunk=cfg.attn_chunk)
        o = o.reshape(eb, es, cfg.n_heads * cfg.d_head)
        xc = xc + jnp.einsum("bsk,kd->bsd", o,
                             lp["attn"]["wo"].astype(xc.dtype))
        h = rms_norm(xc, lp["norm2"], cfg.norm_eps)
        return xc + ffn_lib.ffn(lp["ffn"], h), None

    if remat:
        enc_body = jax.checkpoint(enc_body)
    enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
    enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)

    def dec_body(carry, lp):
        xc, aux = carry
        xn, aux, kv = attn_layer_fwd(lp, cfg, xc, positions, jnp.zeros((), jnp.int32), aux)
        # cross-attention
        h = rms_norm(xn, lp["xnorm"], cfg.norm_eps)
        q, _, _ = attn_lib.qkv(lp["xattn"], cfg, h, positions)
        _, ek, ev = attn_lib.qkv(lp["xattn"], cfg, enc, enc_pos)
        o = attn_lib.chunked_attention(q, ek, ev, causal=False,
                                       chunk=cfg.attn_chunk)
        o = o.reshape(xn.shape[0], xn.shape[1], cfg.n_heads * cfg.d_head)
        xn = xn + jnp.einsum("bsk,kd->bsd", o,
                             lp["xattn"]["wo"].astype(xn.dtype))
        return (xn, aux), (kv, (ek, ev)) if want_cache else None

    if remat:
        dec_body = jax.checkpoint(dec_body)
    (x, aux), caches = jax.lax.scan(
        dec_body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, caches


# ================================================================= losses
def logits_from_hidden(params, cfg, x):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table, cfg.tie_embeddings)


def train_loss(params, cfg, batch, *, remat: bool = True):
    x, aux, _ = forward(params, cfg, batch, mode="train", remat=remat)
    # NOTE: chunked CE (common.cross_entropy_chunked) was hypothesized to cut
    # the [T, V] f32 logits round-trip, but with the vocab axis TP-sharded
    # the logits are already /16 per chip — measured no change on the 671B
    # cell (EXPERIMENTS §Perf iter 7, refuted) — so the plain head stays.
    logits = logits_from_hidden(params, cfg, x)
    loss = cross_entropy(logits, batch["labels"], cfg.final_softcap)

    if cfg.mtp_heads and "labels" in batch:
        # DeepSeek-V3 MTP: predict t+2 from (h_t, emb(token_{t+1}))
        mtp = params["mtp"]
        emb_next = embed(jnp.roll(batch["tokens"], -1, axis=1),
                         params["embed"])
        hcat = jnp.concatenate(
            [rms_norm(x, mtp["norm_h"], cfg.norm_eps),
             rms_norm(emb_next, mtp["norm_e"], cfg.norm_eps)], axis=-1)
        h2 = jnp.einsum("bsk,kd->bsd", hcat, mtp["proj"].astype(x.dtype))
        b, s = h2.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h2, _, _ = attn_layer_fwd(mtp["layer"], cfg, h2, pos,
                                  jnp.zeros((), jnp.int32),
                                  jnp.zeros((), jnp.float32))
        mtp_logits = logits_from_hidden(params, cfg, h2)
        mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
        loss = loss + 0.3 * cross_entropy(mtp_logits, mtp_labels,
                                          cfg.final_softcap)
    return loss + aux, {"aux": aux}
