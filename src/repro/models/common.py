"""Shared model building blocks: norms, RoPE variants, softcap, init.

Everything is functional — params are plain dict pytrees, layers are stacked
on a leading L axis and consumed by ``jax.lax.scan`` (keeps HLO size and
compile time independent of depth, which the 40-cell dry-run relies on).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------- init
def uniform_init(key, shape, scale, dtype=PARAM_DTYPE):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * scale


def dense_init(key, d_in, d_out, dtype=PARAM_DTYPE):
    return uniform_init(key, (d_in, d_out), d_in ** -0.5, dtype)


def split(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------- norms
def rms_norm(x, weight, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(x.dtype)


def softcap(x, cap):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap) (f32 for stability)."""
    if cap is None:
        return x
    x32 = x.astype(jnp.float32)
    return (cap * jnp.tanh(x32 / cap)).astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x, positions, theta: float, style: str = "half"):
    """Rotary embedding.

    x: [..., S, H, D]; positions: i32[..., S] (or [3, ..., S] for mrope).
    styles:
      half         — rotate-half pairing (x[..:D/2], x[D/2:]) (llama/qwen)
      interleaved  — adjacent-pair rotation on the FIRST HALF of dims only,
                     second half pass-through (chatglm/glm 2d-rope)
      mrope        — 3 position streams (t/h/w) over 3 dim sections (qwen2-vl)
      none         — identity
    """
    if style == "none":
        return x
    d = x.shape[-1]

    if style == "mrope":
        # sections: [2,1,1]/4 of the rotary dims for (t, h, w), qwen2-vl style
        sec = (d // 2, d // 4, d // 4)
        pos_t, pos_h, pos_w = positions[0], positions[1], positions[2]
        parts = []
        off = 0
        for p, width in zip((pos_t, pos_h, pos_w), sec):
            parts.append(_rope_half(x[..., off:off + width], p, theta, width))
            off += width
        return jnp.concatenate(parts, axis=-1)

    if style == "interleaved":
        half = d // 2
        rot = _rope_interleaved(x[..., :half], positions, theta, half)
        return jnp.concatenate([rot, x[..., half:]], axis=-1)

    return _rope_half(x, positions, theta, d)


def _angles(positions, theta, d):
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, jnp.float32) / d))
    return positions[..., None].astype(jnp.float32) * inv  # [..., S, d/2]


def _rope_half(x, positions, theta, d):
    ang = _angles(positions, theta, d)[..., None, :]       # [..., S, 1, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _rope_interleaved(x, positions, theta, d):
    ang = _angles(positions, theta, d)[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def einsum_f32acc(subs, a, b):
    """Einsum with f32 accumulation. On TPU this is the native MXU mode
    (bf16 inputs, f32 accumulate); the CPU interpreter cannot execute
    mixed-precision dots, so there we cast inputs up instead."""
    if jax.default_backend() == "cpu":
        return jnp.einsum(subs, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------- misc
def cross_entropy(logits, labels, final_cap=None):
    """Token-mean CE in f32; optional gemma-2 final softcap."""
    logits = softcap(logits, final_cap).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def cross_entropy_chunked(x, table, tied, labels, final_cap=None,
                          chunk: int = 512):
    """CE without materializing [B, S, V] logits (§Perf iteration 7).

    Scans over sequence chunks; each step computes a [B, chunk, V] logits
    block, reduces it to per-token (logz − gold), and the block is
    rematerialized in the backward pass (jax.checkpoint) instead of being
    stored — for a 129k vocab at 1M tokens that removes a multi-GB f32
    round-trip at the cost of one extra lm-head matmul in bwd.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        return cross_entropy(unembed(x, table, tied), labels, final_cap)
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, inp):
        xi, li = inp
        logits = softcap(unembed(xi, table, tied), final_cap) \
            .astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)


def unembed(x, table, tied: bool):
    w = table.T if tied else table
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
