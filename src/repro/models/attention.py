"""Attention: GQA + chunked (flash-style) softmax, sliding window, softcap,
MLA (DeepSeek latent attention) with compressed cache + absorbed decode.

Training/prefill attention is an online-softmax scan over KV chunks — the
[Sq, Sk] score matrix is never materialized beyond one [Sq, chunk] block, so
32k prefill fits. Decode (q_len=1) uses a single einsum against the cache;
with the cache's sequence axis sharded (SP), XLA partitions the softmax into
the partial-max/partial-sum + all-reduce merge pattern (verified in the
dry-run HLO; see EXPERIMENTS §Perf for the hand-tuned variant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import apply_rope, dense_init, rms_norm, softcap, split

NEG_INF = -1e30


# ----------------------------------------------------------------- GQA params
def init_attn(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kh * hd),
        "wv": dense_init(ks[2], d, kh * hd),
        "wo": dense_init(ks[3], h * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), common.PARAM_DTYPE)
        p["bk"] = jnp.zeros((kh * hd,), common.PARAM_DTYPE)
        p["bv"] = jnp.zeros((kh * hd,), common.PARAM_DTYPE)
    return p


def qkv(params, cfg, x, positions):
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)
    return q, k, v


# --------------------------------------------------- chunked flash attention
def chunked_attention(q, k, v, *, causal=True, window=None, cap=None,
                      chunk=1024, q_offset=0, scale=None):
    """Online-softmax attention over KV chunks.

    q: [B, Sq, H, Dq]; k: [B, Sk, Kh, Dq]; v: [B, Sk, Kh, Dv]; GQA via
    H = Kh * G grouping. Accumulation in f32. Returns [B, Sq, H, Dv].
    """
    b, sq, h, dq = q.shape
    sk, kh, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kh
    if scale is None:
        scale = dq ** -0.5
    chunk = min(chunk, sk)
    sk_actual = sk
    pad = (-sk) % chunk
    if pad:                      # ragged tail (e.g. whisper's 1500 frames)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk += pad
    n_chunks = sk // chunk

    # Attention sharding (§Perf iterations 2 & 5):
    #  * HEAD-sharded when kv heads divide the TP degree (deepseek MLA:
    #    128/128 heads): score/PV einsums AND their gradients are fully
    #    local per head shard — no K/V gathers, no dK all-reduce; only the
    #    standard wo all-reduce remains.
    #  * otherwise CONTEXT-parallel: shard the QUERY SEQUENCE over the
    #    model axis (head counts like 40q/8kv never divide 16, and GSPMD's
    #    fallback partial-shards the score contraction — 33 TB of g=2
    #    all-reduces for qwen2.5 prefill_32k). K/V chunks replicate (one
    #    all-gather per chunk) and their grads all-reduce — still ~160×
    #    less wire than the fallback.
    from repro.parallel import hints
    qg = q.reshape(b, sq, kh, g, dq)
    kc = k.reshape(b, n_chunks, chunk, kh, dq)
    vc = v.reshape(b, n_chunks, chunk, kh, dv)
    tp = 1
    if hints.enabled() and hints.mesh() is not None:
        tp = hints.mesh().shape.get(hints.axes("tp"), 1)
    if tp > 1 and kh % tp == 0:
        qg = hints.constrain(qg, "dp", None, "tp", None, None)
        kc = hints.constrain(kc, "dp", None, None, "tp", None)
        vc = hints.constrain(vc, "dp", None, None, "tp", None)
    elif sq > 1:
        qg = hints.constrain(qg, "dp", "tp", None, None, None)
        kc = hints.constrain(kc, "dp", None, None, None, None)
        vc = hints.constrain(vc, "dp", None, None, None, None)
    q_pos = q_offset + jnp.arange(sq, dtype=jnp.int32)

    def body(carry, inputs):
        m, l, acc = carry
        ci, kch, vch = inputs
        s = common.einsum_f32acc("bqkgd,bckd->bkgqc", qg, kch) * scale
        s = softcap(s, cap)
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = jnp.broadcast_to((k_pos < sk_actual)[None, :], (sq, chunk))
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = common.einsum_f32acc("bkgqc,bckd->bkgqd",
                                  p.astype(vch.dtype), vch)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    if tp > 1 and kh % tp == 0:
        out = hints.constrain(out, "dp", None, "tp", None)
    elif sq > 1:
        out = hints.constrain(out, "dp", "tp", None, None)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window=None, cap=None,
                     scale=None):
    """One-token attention against a [B, Smax, Kh, D] cache.

    Single einsum over the cache; under SP the cache's S axis is sharded and
    the softmax partials merge with small all-reduces instead of gathering
    the cache (DESIGN §6).
    """
    b, _, h, dq = q.shape
    smax, kh, dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[-1]
    g = h // kh
    if scale is None:
        scale = dq ** -0.5
    qg = q.reshape(b, kh, g, dq)
    s = common.einsum_f32acc("bkgd,bskd->bkgs", qg, k_cache) * scale
    s = softcap(s, cap)
    k_pos = jnp.arange(smax, dtype=jnp.int32)
    mask = k_pos[None] < cur_len            # [1?, S] broadcast over b
    if window is not None:
        mask &= k_pos[None] >= (cur_len - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = common.einsum_f32acc("bkgs,bskd->bkgd",
                               p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# --------------------------------------------------------------------- MLA
def init_mla(key, cfg):
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    ks = split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank),
        "q_norm": jnp.zeros((m.q_lora_rank,), common.PARAM_DTYPE),
        "wq_b": dense_init(ks[1], m.q_lora_rank,
                           h * (m.qk_nope_dim + m.qk_rope_dim)),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), common.PARAM_DTYPE),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_dim + m.v_head_dim)),
        "wo": dense_init(ks[4], h * m.v_head_dim, d),
    }


def mla_qcr(params, cfg, x, positions):
    """Queries + compressed KV (the cacheable latents)."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(x.dtype)),
                  params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rk->bsk", qa, params["wq_b"].astype(x.dtype))
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "half")

    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(x.dtype))
    c_kv = rms_norm(kv_a[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., None, m.kv_lora_rank:]          # [B,S,1,rope] shared
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta, "half")[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(params, cfg, x, positions, chunk):
    """Training/prefill MLA: expand latents to per-head K/V, chunked attn."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = mla_qcr(params, cfg, x, positions)

    kv = jnp.einsum("bsr,rk->bsk", c_kv, params["wkv_b"].astype(x.dtype))
    kv = kv.reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, h, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True, chunk=chunk)
    out = out.reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"].astype(x.dtype)), \
        c_kv, k_rope


def mla_decode(params, cfg, x, pos, ckv_cache, krope_cache, cur_len):
    """Absorbed-matrix decode: attention runs in the LATENT space — the cache
    stays compressed ([S, kv_rank+rope] per token, the MLA memory win) and
    W_uk / W_uv are folded into the query/output projections."""
    m, h = cfg.mla, cfg.n_heads
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = mla_qcr(params, cfg, x, positions)

    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv_new.astype(ckv_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope_new.astype(krope_cache.dtype), pos, axis=1)

    wkv_b = params["wkv_b"].reshape(
        m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_dim]                  # [r, H, nope]
    w_uv = wkv_b[..., m.qk_nope_dim:]                  # [r, H, v]

    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk.astype(x.dtype))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (common.einsum_f32acc("bshr,bSr->bhsS", q_lat, ckv_cache)
         + common.einsum_f32acc("bshr,bSr->bhsS", q_rope, krope_cache)) * scale
    k_pos = jnp.arange(ckv_cache.shape[1], dtype=jnp.int32)
    s = jnp.where((k_pos <= pos)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = common.einsum_f32acc(
        "bhsS,bSr->bshr", p.astype(ckv_cache.dtype),
        ckv_cache).astype(x.dtype)
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv.astype(x.dtype))
    out = out.reshape(b, 1, h * m.v_head_dim)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"].astype(x.dtype)), \
        ckv_cache, krope_cache
