"""Serving paths: cache construction, prefill, one-token decode.

``decode_step`` is what the decode_32k / long_500k dry-run cells lower: one
new token against a seq_len-capacity cache. Caches are stacked per layer so
the decode layer loop is a ``lax.scan`` over (layer_params, layer_cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import common, rwkv as rwkv_lib, ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.common import embed, rms_norm


# ============================================================== cache init
def init_cache(cfg, batch: int, capacity: int):
    """Zero cache with ``capacity`` sequence slots (family-specific pytree)."""
    L, kh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    bf = common.PARAM_DTYPE
    if cfg.family == "ssm":
        nh, rhd = cfg.n_heads, cfg.d_model // cfg.n_heads
        return (jnp.zeros((L, batch, cfg.d_model), bf),
                jnp.zeros((L, batch, cfg.d_model), bf),
                jnp.zeros((L, batch, nh, rhd, rhd), jnp.float32))
    if cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        nh = d_inner // cfg.ssm.head_dim
        conv_dim = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
        n_apps = cfg.n_layers // cfg.hybrid_attn_every
        ssm_c = (jnp.zeros((L, batch, nh, cfg.ssm.head_dim, cfg.ssm.d_state),
                           jnp.float32),
                 jnp.zeros((L, batch, cfg.ssm.d_conv - 1, conv_dim), bf))
        attn_c = (jnp.zeros((n_apps, batch, capacity, kh, hd), bf),
                  jnp.zeros((n_apps, batch, capacity, kh, hd), bf))
        return (ssm_c, attn_c)
    if cfg.mla is not None:
        m = cfg.mla
        return (jnp.zeros((L, batch, capacity, m.kv_lora_rank), bf),
                jnp.zeros((L, batch, capacity, m.qk_rope_dim), bf))
    if cfg.family == "audio":
        self_kv = (jnp.zeros((L, batch, capacity, kh, hd), bf),
                   jnp.zeros((L, batch, capacity, kh, hd), bf))
        cross_kv = (jnp.zeros((L, batch, cfg.enc_seq, kh, hd), bf),
                    jnp.zeros((L, batch, cfg.enc_seq, kh, hd), bf))
        return (self_kv, cross_kv)
    return (jnp.zeros((L, batch, capacity, kh, hd), bf),
            jnp.zeros((L, batch, capacity, kh, hd), bf))


# ================================================================= prefill
def prefill(params, cfg, batch):
    """Full-sequence pass building the cache; returns last-position logits
    (the [B, V] sampler input — the full [B, S, V] logits are never
    materialized, DESIGN §6) plus the cache at capacity == S."""
    x, _, cache = tfm.forward(params, cfg, batch, mode="prefill", remat=False)
    logits = tfm.logits_from_hidden(params, cfg, x[:, -1:])
    return logits[:, 0], cache


# ============================================================== decode step
def decode_step(params, cfg, tokens, cache, pos):
    """tokens: i32[B, 1] (or embeds [B,1,D] for embeds_input archs).
    Returns (logits [B, V], new cache)."""
    if cfg.embeds_input and tokens.ndim == 3:
        x = tokens.astype(common.COMPUTE_DTYPE)
    else:
        x = embed(tokens, params["embed"])

    if cfg.family == "ssm":
        x, cache = _decode_rwkv(params, cfg, x, cache)
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(params, cfg, x, cache, pos)
    elif cfg.family == "audio":
        x, cache = _decode_whisper(params, cfg, x, cache, pos)
    else:
        x, cache = _decode_attn(params, cfg, x, cache, pos)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_from_hidden(params, cfg, x)
    from repro.models.common import softcap
    return softcap(logits[:, 0], cfg.final_softcap), cache


def _decode_attn(params, cfg, x, cache, pos):
    def body(xc, inp):
        lp, lcache, idx = inp
        xn, new_cache = tfm.attn_layer_decode(lp, cfg, xc, pos, lcache, idx)
        return xn, new_cache
    x, cache = jax.lax.scan(
        body, x, (params["layers"], cache, jnp.arange(cfg.n_layers)))
    return x, cache


def _decode_rwkv(params, cfg, x, cache):
    def body(xc, inp):
        lp, carry = inp
        xn, carry = rwkv_lib.rwkv_block(lp, cfg, xc, carry)
        return xn, carry
    x, cache = jax.lax.scan(body, x, (params["layers"], cache))
    return x, cache


def _decode_hybrid(params, cfg, x, cache, pos):
    (ssm_h, ssm_conv), (ak, av) = cache
    every = cfg.hybrid_attn_every or cfg.n_layers
    n_groups = cfg.n_layers // every
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["layers"])
    gh = ssm_h.reshape(n_groups, every, *ssm_h.shape[1:])
    gc = ssm_conv.reshape(n_groups, every, *ssm_conv.shape[1:])

    def mamba_body(xc, inp):
        lp, h, conv = inp
        y, (h2, conv2) = ssm_lib.mamba2_forward(lp, cfg, xc, ssm_state=h,
                                                conv_state=conv)
        return xc + y, (h2, conv2)

    new_h, new_c, new_ak, new_av = [], [], [], []
    for gi in range(n_groups):
        gp = jax.tree.map(lambda a: a[gi], grouped)
        x, (h2, c2) = jax.lax.scan(mamba_body, x, (gp, gh[gi], gc[gi]))
        x, kv = tfm.attn_layer_decode(params["shared_attn"], cfg, x, pos,
                                      (ak[gi], av[gi]), jnp.asarray(gi))
        new_h.append(h2); new_c.append(c2)
        new_ak.append(kv[0]); new_av.append(kv[1])
    cache = ((jnp.concatenate(new_h), jnp.concatenate(new_c)),
             (jnp.stack(new_ak), jnp.stack(new_av)))
    return x, cache


def _decode_whisper(params, cfg, x, cache, pos):
    self_kv, cross_kv = cache
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(xc, inp):
        lp, (sk, sv), (ck, cv) = inp
        xn, (sk, sv) = tfm.attn_layer_decode(lp, cfg, xc, pos, (sk, sv),
                                             jnp.zeros((), jnp.int32))
        h = rms_norm(xn, lp["xnorm"], cfg.norm_eps)
        q, _, _ = attn_lib.qkv(lp["xattn"], cfg, h, positions)
        o = attn_lib.decode_attention(q, ck, cv, ck.shape[1])
        o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
        xn = xn + jnp.einsum("bsk,kd->bsd", o,
                             lp["xattn"]["wo"].astype(xn.dtype))
        return xn, ((sk, sv), (ck, cv))

    x, cache = jax.lax.scan(body, x, (params["layers"], self_kv, cross_kv))
    return x, cache
