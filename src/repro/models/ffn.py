"""Dense FFN (SwiGLU) — the default MLP for all non-MoE blocks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split


def init_ffn(key, d_model: int, d_ff: int):
    ks = split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff),
        "w_up": dense_init(ks[1], d_model, d_ff),
        "w_down": dense_init(ks[2], d_ff, d_model),
    }


def ffn(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
