"""RWKV-6 ("Finch") — attention-free, data-dependent decay.

Time-mix: token-shift ddlerp (low-rank data-dependent interpolation with the
previous token), per-channel decay w = exp(-exp(·)) produced by a LoRA from
the shifted input, and the WKV state recurrence

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    o_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)

carried per head by ``lax.scan`` over the sequence (sequential form — the
chunked-parallel form is a §Perf candidate). Decode is the O(1)-state single
step, which is why this arch runs the long_500k cell.

Channel-mix: shifted squared-ReLU MLP with receptance gate (RWKV standard).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split, layer_norm

LORA_RANK = 32


def _head_dims(cfg):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return nh, hd


def init_rwkv_block(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    nh, hd = _head_dims(cfg)
    ks = split(key, 12)
    zeros = lambda *sh: jnp.zeros(sh, jnp.bfloat16)
    return {
        # time-mix
        "ln1_w": zeros(d) + 1.0, "ln1_b": zeros(d),
        "mu_base": zeros(d),                  # base token-shift mix
        "mu_rkvgw": zeros(5, d),              # per-stream mixes
        "lora_a": dense_init(ks[0], d, 5 * LORA_RANK),
        "lora_b": dense_init(ks[1], 5 * LORA_RANK, 5 * d) * 0.0,
        "wr": dense_init(ks[2], d, d),
        "wk": dense_init(ks[3], d, d),
        "wv": dense_init(ks[4], d, d),
        "wg": dense_init(ks[5], d, d),
        "w0": zeros(d) - 4.0,                 # decay bias (w ≈ exp(-e^-4)≈1)
        "wa": dense_init(ks[6], d, LORA_RANK),
        "wb": dense_init(ks[7], LORA_RANK, d) * 0.0,
        "u": zeros(nh, hd),                   # bonus for current token
        "wo": dense_init(ks[8], d, d),
        "gn_w": zeros(d) + 1.0, "gn_b": zeros(d),
        # channel-mix
        "ln2_w": zeros(d) + 1.0, "ln2_b": zeros(d),
        "mu_ck": zeros(d), "mu_cr": zeros(d),
        "ck": dense_init(ks[9], d, f),
        "cv": dense_init(ks[10], f, d),
        "cr": dense_init(ks[11], d, d),
    }


def _shift(x, x_prev):
    """Token shift: x_{t-1} (first position takes carry-in x_prev [B,D])."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def time_mix(p, cfg, x, x_prev, state):
    """x: [B,S,D]; x_prev: [B,D] carry; state: [B,H,hd,hd] WKV state.
    Returns (out, new_x_prev, new_state)."""
    nh, hd = _head_dims(cfg)
    b, s, d = x.shape
    xs = _shift(x, x_prev)
    xx = xs - x
    xb = x + xx * p["mu_base"].astype(x.dtype)
    # data-dependent per-stream mixes (ddlerp)
    from repro.parallel import hints
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xb, p["lora_a"].astype(x.dtype)))
    dd = jnp.einsum("bsr,rk->bsk", lora, p["lora_b"].astype(x.dtype))
    # keep the ddlerp mix model-replicated: it multiplies the replicated
    # residual stream elementwise (sharded, it forced 1.7 TB f32 gathers)
    dd = hints.constrain(dd.reshape(b, s, 5, d), "dp", None, None, None)
    mix = p["mu_rkvgw"].astype(x.dtype)[None, None] + dd     # [B,S,5,D]
    xr, xk, xv, xg, xw = [x + xx * mix[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,dk->bsk", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", xg, p["wg"].astype(x.dtype)))
    g = hints.constrain(g, "dp", None, None)
    wlora = jnp.einsum("bsr,rk->bsk",
                       jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                                           p["wa"].astype(x.dtype))),
                       p["wb"].astype(x.dtype))
    wlora = hints.constrain(wlora, "dp", None, None)
    logw = p["w0"].astype(jnp.float32)[None, None] + wlora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                               # (0,1) decay

    # §Perf iteration 3: the WKV recurrence is cheap (O(S·D·hd)) next to the
    # projections (O(S·D²)) but its 40-head layout doesn't divide a 16-way
    # model axis — GSPMD was all-gathering 1.7 TB of ddlerp tensors per
    # layer. Pin the scan to model-REPLICATED (TP stays on the projections,
    # which carry the FLOPs); redundant scan compute is ~1% of layer FLOPs.
    rh = hints.constrain(r.reshape(b, s, nh, hd), "dp", None, None, None)
    kh = hints.constrain(k.reshape(b, s, nh, hd), "dp", None, None, None)
    vh = hints.constrain(v.reshape(b, s, nh, hd), "dp", None, None, None)
    wh = hints.constrain(w.reshape(b, s, nh, hd), "dp", None, None, None)
    u = p["u"].astype(jnp.float32)

    # §Perf iteration 6: scan xs streamed in bf16 (r/k/v) — halves the
    # dominant per-step HBM traffic; the STATE and decay stay f32 (the
    # recurrence is precision-sensitive through long products).
    def step(S, inp):
        rt, kt, vt, wt = inp                                  # [B,H,hd]
        rt, kt, vt = (a.astype(jnp.float32) for a in (rt, kt, vt))
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, out.astype(jnp.bfloat16)

    xs_seq = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))
    state, outs = jax.lax.scan(step, state, xs_seq)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)           # bf16

    out = layer_norm(out, p["gn_w"].astype(jnp.float32),
                     p["gn_b"].astype(jnp.float32), cfg.norm_eps)
    out = (out.astype(x.dtype) * g)
    out = jnp.einsum("bsd,dk->bsk", out, p["wo"].astype(x.dtype))
    return out, x[:, -1], state


def channel_mix(p, cfg, x, x_prev):
    xs = _shift(x, x_prev)
    xx = xs - x
    xk = x + xx * p["mu_ck"].astype(x.dtype)
    xr = x + xx * p["mu_cr"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr, p["cr"].astype(x.dtype)))
    return r * kv, x[:, -1]


def rwkv_block(p, cfg, x, carry):
    """carry = (x_prev_att [B,D], x_prev_ffn [B,D], wkv_state [B,H,hd,hd])."""
    xa_prev, xf_prev, state = carry
    h = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    att, xa_new, state = time_mix(p, cfg, h, xa_prev, state)
    x = x + att
    h = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    ff, xf_new = channel_mix(p, cfg, h, xf_prev)
    x = x + ff
    return x, (xa_new, xf_new, state)


def init_rwkv_carry(cfg, batch):
    nh, hd = _head_dims(cfg)
    return (jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
            jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
            jnp.zeros((batch, nh, hd, hd), jnp.float32))
