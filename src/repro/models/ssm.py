"""Mamba-2 (SSD) block — chunked state-space duality algorithm.

Training/prefill uses the chunked SSD form: within a chunk the recurrence is
expanded into a (masked, decay-weighted) quadratic form that feeds the MXU;
across chunks a ``lax.scan`` carries the [B, H, P, N] state. Decode is the
O(1)-state single-step recurrence — the reason SSM archs run the long_500k
cell that full attention cannot (DESIGN §5).

Shapes: d_inner = expand·d_model, H = d_inner / head_dim (P), N = d_state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split, rms_norm


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state, s.n_groups


def init_mamba2(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nh, p, n, g = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    ks = split(key, 4)
    return {
        # order: [z | x | B | C | dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * g * n + nh),
        "conv_w": dense_init(ks[1], s.d_conv, conv_dim),   # depthwise
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_inner,), jnp.bfloat16),
        "out_proj": dense_init(ks[2], d_inner, d),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over seq. x: [B,S,C]; w: [K,C]. If ``state``
    ([B, K-1, C]) is given, runs one decode step and returns (y, new_state)."""
    k = w.shape[0]
    if state is not None:                      # decode: x is [B,1,C]
        window = jnp.concatenate([state, x], axis=1)        # [B,K,C]
        y = jnp.einsum("bkc,kc->bc", window, w.astype(x.dtype)) + b
        return y[:, None], window[:, 1:]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    windows = jnp.stack([xp[:, i:i + x.shape[1]] for i in range(k)], axis=2)
    y = jnp.einsum("bskc,kc->bsc", windows, w.astype(x.dtype)) + b
    return y, None


def _split_proj(cfg, zxbcdt):
    d_inner, nh, p, n, g = _dims(cfg)
    z, x, bc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, x, bc, dt


def ssd_chunked(xh, dt, a_log, bmat, cmat, d_skip, chunk):
    """Chunked SSD. xh:[B,S,H,P] dt:[B,S,H] bmat/cmat:[B,S,H,N] (groups
    pre-broadcast). Returns (y:[B,S,H,P], final_state:[B,H,P,N])."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))               # [H]
    da = dt * a                                           # [B,S,H]

    def per_chunk(h_prev, inp):
        xc, dtc, dac, bc, cc = inp                        # [B,chunk,...]
        cum = jnp.cumsum(dac, axis=1)                     # [B,chunk,H]
        total = cum[:, -1]                                # [B,H]
        # intra-chunk quadratic (decay-masked attention-like form)
        rel = cum[:, :, None, :] - cum[:, None, :, :]     # [B,i,j,H]
        iota = jnp.arange(chunk)
        causal = iota[:, None] >= iota[None, :]
        lmat = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", cc, bc,
                            preferred_element_type=jnp.float32)
        w = scores * lmat * dtc[:, None, :, :]            # weight for j→i
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xh_f(xc))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bihn,bhpn->bihp",
                             cc * jnp.exp(cum)[..., None], h_prev)
        # state update
        decay_to_end = jnp.exp(total[:, None] - cum)      # [B,chunk,H]
        upd = jnp.einsum("bjhn,bjhp->bhpn",
                         bc * (dtc * decay_to_end)[..., None], xh_f(xc))
        h_new = h_prev * jnp.exp(total)[..., None, None] + upd
        y = y_intra + y_inter + d_skip[None, None, :, None] * xh_f(xc)
        return h_new, y

    def xh_f(x):
        return x.astype(jnp.float32)

    xs = (xh.reshape(b, nc, chunk, h, p).swapaxes(0, 1),
          dt.reshape(b, nc, chunk, h).swapaxes(0, 1),
          da.reshape(b, nc, chunk, h).swapaxes(0, 1),
          bmat.reshape(b, nc, chunk, h, n).swapaxes(0, 1),
          cmat.reshape(b, nc, chunk, h, n).swapaxes(0, 1))
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, ys = jax.lax.scan(per_chunk, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y.astype(xh.dtype), h_final


def mamba2_forward(params, cfg, x, ssm_state=None, conv_state=None):
    """Full block. Train/prefill: ssm_state=None → returns (y, (h, conv)).
    Decode: pass (ssm_state, conv_state), x is [B,1,D]."""
    s = cfg.ssm
    d_inner, nh, p, n, g = _dims(cfg)
    decode = ssm_state is not None

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"].astype(x.dtype))
    z, xc, bc, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xc, bc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"].astype(x.dtype),
                                      state=conv_state if decode else None)
    if not decode:  # keep the conv tail so prefill can hand off to decode
        new_conv = conv_in[:, -(s.d_conv - 1):]
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :d_inner]
    bmat, cmat = jnp.split(conv_out[..., d_inner:], 2, axis=-1)  # [B,S,G*N]

    bsz, seq = x.shape[0], x.shape[1]
    xh = xc.reshape(bsz, seq, nh, p)
    rep = nh // g
    bmat = jnp.repeat(bmat.reshape(bsz, seq, g, n), rep, axis=2)
    cmat = jnp.repeat(cmat.reshape(bsz, seq, g, n), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])        # [B,S,H]

    if decode:
        a = -jnp.exp(params["A_log"].astype(jnp.float32))
        dac = jnp.exp(dt[:, 0] * a)                              # [B,H]
        upd = jnp.einsum("bhn,bhp->bhpn", bmat[:, 0] * dt[:, 0, :, None],
                         xh[:, 0].astype(jnp.float32))
        h_new = ssm_state * dac[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", cmat[:, 0], h_new) \
            + params["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y[:, None]                                           # [B,1,H,P]
    else:
        y, h_new = ssd_chunked(xh, dt, params["A_log"], bmat, cmat,
                               params["D"], s.chunk)

    y = y.astype(x.dtype).reshape(bsz, seq, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(x.dtype))
    return out, (h_new, new_conv)
