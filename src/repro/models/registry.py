"""Uniform model API used by the launcher, tests and examples."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import serving, transformer as tfm


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: Any
    init: Callable          # key -> params
    train_loss: Callable    # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch) -> (last_logits, cache)
    decode_step: Callable   # (params, tokens, cache, pos) -> (logits, cache)
    init_cache: Callable    # (batch, capacity) -> cache


def build_model(cfg) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: tfm.init_params(key, cfg),
        train_loss=lambda params, batch, remat=True: tfm.train_loss(
            params, cfg, batch, remat=remat),
        prefill=lambda params, batch: serving.prefill(params, cfg, batch),
        decode_step=lambda params, tokens, cache, pos: serving.decode_step(
            params, cfg, tokens, cache, pos),
        init_cache=lambda batch, capacity: serving.init_cache(
            cfg, batch, capacity),
    )


def batch_for(cfg, batch_size: int, seq_len: int, *, kind: str = "train",
              key=None):
    """Concrete (smoke-test) batch for any family; mirrors
    ``launch.specs.input_specs`` which builds the ShapeDtypeStruct twins."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(
            ks[0], (batch_size, seq_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["enc_embeds"] = jax.random.normal(
                ks[1], (batch_size, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(
            ks[0], (batch_size, seq_len), 0, cfg.vocab, jnp.int32)
    if kind == "train":
        batch["labels"] = jax.random.randint(
            ks[2], (batch_size, seq_len), 0, cfg.vocab, jnp.int32)
        if cfg.embeds_input:   # loss still over vocab for backbone stubs
            batch.setdefault("tokens", jax.random.randint(
                ks[3], (batch_size, seq_len), 0, cfg.vocab, jnp.int32))
    if cfg.rope_style == "mrope":
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                               (batch_size, seq_len))
        batch["positions"] = jnp.stack([pos, pos, pos])  # t/h/w streams
    return batch
