"""Drifting-mix synthetic request traffic for the admission server.

~1M persistent user identities drive request features through the
guardrail chain's columns:

  0 prompt_len   — tokens in the prompt (len_ok: < 900)
  1 abuse_score  — heuristic abuse classifier output (abuse_ok: < 0.92)
  2 user_budget  — remaining token budget (budget_ok: > 10)
  3 allowlist    — 0/1 enterprise-allowlist membership (allow: > 0.5)

Three user COHORTS own disjoint, persistent id ranges; a user's
allowlist membership and budget tier are pure functions of a hash of
the user id, so cohort identity survives across batches, restarts, and
replay:

  organic     — moderate prompts, low abuse, mid budgets, ~15% allowlisted
  abusive     — long prompts, high abuse scores, drained budgets
  enterprise  — short prompts, clean, rich budgets, ~92% allowlisted

The PHASE of the stream reweights the cohort mix — organic-dominated →
abuse storm → enterprise/allowlist-heavy — so predicate selectivities
and effective costs drift exactly the way the adaptive gate exists for:
the cheap allowlist probe is nearly useless in phase 0 and nearly
decisive in phase 2, and the expensive abuse check goes from formality
to front line in phase 1.

Counter-based and pure in ``(seed, batch_index)`` (the ``LogStream``
discipline): restartable from any cursor, bit-exact under rollback
replay, and regenerable by the synchronous admission-parity reference.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import OP_GT, OP_LT, Predicate

COHORTS = ("organic", "abusive", "enterprise")

#: request-feature column indices (the guardrail chain's contract)
COL_PROMPT_LEN = 0
COL_ABUSE = 1
COL_BUDGET = 2
COL_ALLOW = 3
N_FEATURES = 4


def guardrail_chain() -> list[Predicate]:
    """Request-admission predicates over the traffic columns above (CNF):

        len_ok AND (allowlisted OR budget_ok) AND (allowlisted OR abuse_ok)

    i.e. ``allowlisted OR (budget_ok AND abuse_ok)`` distributed into
    AND-of-OR groups — allowlisted traffic skips the expensive
    budget/abuse checks via the OR short-circuit, and the adaptive
    ordering learns to probe the cheap allowlist bit first when
    allowlisted traffic dominates (phase 2 below).
    """
    allow = dict(column=COL_ALLOW, op=OP_GT, t1=0.5, static_cost=0.2)
    return [
        Predicate("len_ok", column=COL_PROMPT_LEN, op=OP_LT, t1=900.0,
                  static_cost=1.0),
        Predicate("allow_b", group="allow_or_budget", **allow),
        Predicate("budget_ok", column=COL_BUDGET, op=OP_GT, t1=10.0,
                  static_cost=1.5, group="allow_or_budget"),
        Predicate("allow_a", group="allow_or_abuse", **allow),
        Predicate("abuse_ok", column=COL_ABUSE, op=OP_LT, t1=0.92,
                  static_cost=4.0, group="allow_or_abuse"),
    ]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Mix schedule: ``mix[phase]`` are (organic, abusive, enterprise)
    cohort weights; the stream cycles through the phases every
    ``phase_requests`` rows."""

    seed: int = 0
    n_users: int = 1 << 20        # ~1.05M persistent identities
    phase_requests: int = 2048    # rows per phase before the mix shifts
    mix: tuple = (
        (0.85, 0.05, 0.10),       # phase 0: organic traffic
        (0.40, 0.50, 0.10),       # phase 1: abuse storm
        (0.25, 0.05, 0.70),       # phase 2: enterprise/allowlist-heavy
    )

    def __post_init__(self) -> None:
        if self.phase_requests <= 0:
            raise ValueError("phase_requests must be positive")
        for row in self.mix:
            if len(row) != len(COHORTS) or abs(sum(row) - 1.0) > 1e-6:
                raise ValueError(f"mix rows must be {len(COHORTS)} weights "
                                 f"summing to 1, got {row}")

    @property
    def n_phases(self) -> int:
        return len(self.mix)


def phase_of(cfg: TrafficConfig, row_mid: float) -> int:
    """Phase owning a row position (batches use their midpoint row)."""
    return int(row_mid // cfg.phase_requests) % cfg.n_phases


def _user_hash(uid: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: per-user u64 the persistent attributes hang
    off (same mix the device tokenizer reproduces in u32 limbs)."""
    x = uid.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


#: per-cohort generative parameters:
#: (prompt mean/std, abuse Beta a/b, budget base/span, P(allowlisted))
_COHORT_PARAMS = {
    "organic": ((550.0, 220.0), (2.0, 12.0), (15.0, 85.0), 0.15),
    "abusive": ((950.0, 280.0), (16.0, 2.0), (-5.0, 30.0), 0.02),
    "enterprise": ((420.0, 160.0), (1.0, 16.0), (150.0, 120.0), 0.92),
}

# disjoint user-id ranges per cohort (fractions of n_users): identity —
# and therefore allowlist membership and budget tier — persists across
# every batch that samples the cohort
_COHORT_ID_RANGES = {
    "organic": (0.0, 0.70),
    "abusive": (0.70, 0.80),
    "enterprise": (0.80, 1.0),
}


def gen_requests_with_users(
        cfg: TrafficConfig, batch_index: int, row_start: int,
        n_rows: int) -> tuple[np.ndarray, np.ndarray]:
    """Rows [row_start, row_start+n_rows) as (f32[4, n], user_ids i64[n]).

    Counter-based: depends only on ``(cfg, batch_index, row_start,
    n_rows)``, never on generator history. All cohorts draw for every
    row and a mask selects — a fixed draw schedule keeps the stream
    bit-reproducible regardless of the realized mix.
    """
    rng = np.random.Generator(np.random.Philox(
        key=[cfg.seed, batch_index]))
    phase = phase_of(cfg, row_start + n_rows / 2)
    cohort = rng.choice(len(COHORTS), size=n_rows, p=cfg.mix[phase])

    feats = np.zeros((N_FEATURES, n_rows), np.float64)
    users = np.zeros(n_rows, np.int64)
    for ci, name in enumerate(COHORTS):
        (pm, ps), (ba, bb), (b0, bspan), p_allow = _COHORT_PARAMS[name]
        lo, hi = _COHORT_ID_RANGES[name]
        uid = rng.integers(int(lo * cfg.n_users),
                           max(int(hi * cfg.n_users), int(lo * cfg.n_users) + 1),
                           n_rows)
        h = _user_hash(uid)
        u1 = (h & np.uint64(0xFFFF)).astype(np.float64) / 65536.0
        u2 = ((h >> np.uint64(16)) & np.uint64(0xFFFF)).astype(np.float64) \
            / 65536.0
        prompt = rng.normal(pm, ps, n_rows).clip(1.0, 4096.0)
        abuse = rng.beta(ba, bb, n_rows)
        budget = b0 + bspan * u1 + rng.normal(0.0, 5.0, n_rows)
        allow = (u2 < p_allow).astype(np.float64)
        sel = cohort == ci
        feats[COL_PROMPT_LEN, sel] = prompt[sel]
        feats[COL_ABUSE, sel] = abuse[sel]
        feats[COL_BUDGET, sel] = budget[sel]
        feats[COL_ALLOW, sel] = allow[sel]
        users[sel] = uid[sel]
    return feats.astype(np.float32), users


def gen_requests(cfg: TrafficConfig, batch_index: int, row_start: int,
                 n_rows: int) -> np.ndarray:
    """Feature columns only — the ``RequestStream`` generator signature."""
    return gen_requests_with_users(cfg, batch_index, row_start, n_rows)[0]


class TrafficGenerator:
    """A ``TrafficConfig`` bound into the per-batch generator callable the
    serving stream adapter (``data.stream.RequestStream``) consumes."""

    def __init__(self, cfg: TrafficConfig = TrafficConfig()):
        self.cfg = cfg

    def gen(self, batch_index: int, row_start: int,
            n_rows: int) -> np.ndarray:
        return gen_requests(self.cfg, batch_index, row_start, n_rows)

    def stream(self, total_requests: int, batch_rows: int,
               start_batch: int = 0):
        from repro.data.stream import RequestStream

        return RequestStream(self.gen, total_rows=total_requests,
                             batch_rows=batch_rows, start_batch=start_batch)
