"""Continuous-batching admission serving (see ``serving.server``)."""

from repro.serving.metrics import ServerMetrics, weighted_quantile
from repro.serving.server import (REASON_ADMITTED, REASON_QUARANTINED,
                                  REASON_REJECTED, AdmissionServer, GateItem,
                                  RequestResult, ServerConfig, ServerReport,
                                  SimExecutor, Ticket, synchronous_reference)
from repro.serving.traffic import (TrafficConfig, TrafficGenerator,
                                   gen_requests, gen_requests_with_users,
                                   guardrail_chain, phase_of)

__all__ = [
    "AdmissionServer", "GateItem", "RequestResult", "ServerConfig",
    "ServerMetrics", "ServerReport", "SimExecutor", "Ticket",
    "TrafficConfig", "TrafficGenerator", "REASON_ADMITTED",
    "REASON_QUARANTINED", "REASON_REJECTED", "gen_requests",
    "gen_requests_with_users", "guardrail_chain", "phase_of",
    "synchronous_reference", "weighted_quantile",
]
