"""Continuous-batching admission server: queued ingest → adaptive
guardrail gate → packed prefill/decode slots.

The shape of an offline-inference driver (MaxText/JetStream style), with
the paper's adaptive filter as the admission gate:

    ingest thread ──► request queue (bounded) ──► GATE (FilterSession /
        GuardedSession.step, FIFO per micro-batch)
            ├─ rejected / quarantined → result queue (answered
            │     immediately with a reason code)
            └─ admitted → backlog (bounded) → free slot → prefill →
                  one decode tick per server loop → result queue

    collector thread ◄── result queue (bounded)

No global barrier anywhere: a freed slot is refilled from the backlog on
the same loop iteration, and the gate keeps deciding new micro-batches
while slots decode.

ADMISSION DETERMINISM — the property everything else leans on: the gate
consumes micro-batches in FIFO arrival order from ONE queue, and the
adaptive state advances only through ``session.step``. Queue depth, slot
timing, thread scheduling, and executor speed therefore change admission
LATENCY but never admission DECISIONS: the admit/reject sequence and the
final ``OrderState`` are bit-identical to ``synchronous_reference`` over
the same seeded traffic. ``tests/test_serving.py`` pins this.

ACCOUNTING — every request the ingest thread enqueues gets exactly one
``RequestResult``: rejects/quarantines at decision time, admits at
decode completion. Bounded queues block (backpressure), never drop.

Graceful drain: a ``stop`` object with a truthy ``requested`` attribute
(``runtime.fault_tolerance.GracefulShutdown`` fits) stops the ingest
thread, finishes gating everything already queued, lets in-flight slots
decode to completion, and flushes a final checkpoint blob + health line
into the ``ServerReport``.

Hot-path discipline: ``AdmissionServer._gate_batch`` is a
``hotpath_lint`` root — the jitted admission step must stay free of
host syncs; the ONE sanctioned device→host sync of the serving loop is
``AdmissionServer._decide`` (allowlisted with its reason): answering
rejects immediately requires concretizing the gate mask.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import numpy as np

from repro.serving.metrics import ServerMetrics

REASON_ADMITTED = "admitted"
REASON_REJECTED = "rejected"
REASON_QUARANTINED = "quarantined"

_SENTINEL = object()


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Queue/slot geometry + drain knobs."""

    num_slots: int = 8            # fixed prefill/decode slots
    queue_depth: int = 8          # request & result queue bound (batches)
    max_backlog: int | None = None  # admitted awaiting a slot (None→4·slots)
    gate_poll_s: float = 0.001    # dequeue timeout while slots decode

    def backlog_bound(self) -> int:
        return self.max_backlog if self.max_backlog is not None \
            else 4 * self.num_slots


@dataclasses.dataclass
class GateItem:
    """One ingested micro-batch awaiting its admission decision."""

    batch_index: int
    cols: np.ndarray              # f32[C, B]
    row_start: int
    t_enqueue: float


@dataclasses.dataclass
class Ticket:
    """One ADMITTED request heading for a slot."""

    request_id: int               # global row id (row_start + offset)
    batch_index: int
    features: np.ndarray          # f32[C]


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """The answer every ingested request gets exactly once."""

    request_id: int
    batch_index: int
    reason: str                   # admitted | rejected | quarantined
    latency_s: float              # enqueue → admission decision
    decode_steps: int = 0         # admitted only: slot ticks consumed


@dataclasses.dataclass
class ServerReport:
    """Everything a run produced (drives BENCH_serve.json + the tests)."""

    results: list                 # RequestResult, completion order
    masks: dict                   # batch_index → admission mask (np.bool_)
    state: Any                    # final OrderState
    state_blob: dict              # final versioned checkpoint (always)
    metrics: dict                 # ServerMetrics.snapshot(...)
    drained: bool                 # True when a stop request ended the run
    health_line: str | None      # guarded runs: GuardHealth.summary()

    def results_by_id(self) -> dict:
        return {r.request_id: r for r in self.results}


class SimExecutor:
    """Deterministic stand-in slot executor: decode length is a pure
    function of the request features, so run output is reproducible and
    tests can meter slot pressure with ``tick_s``."""

    def __init__(self, max_decode_steps: int = 8, tick_s: float = 0.0):
        self.max_decode_steps = max_decode_steps
        self.tick_s = tick_s

    def prefill(self, ticket: Ticket):
        return 1 + int(abs(float(ticket.features[0]))) % self.max_decode_steps

    def advance(self, remaining):
        if self.tick_s:
            time.sleep(self.tick_s)
        remaining -= 1
        return remaining, remaining <= 0


class _IngestThread(threading.Thread):
    """Background producer: generates the stream, applies the (pure)
    batch hook, stamps enqueue time, and blocks on the bounded queue —
    backpressure, never drops. Always terminates the queue with the
    sentinel, even on error or early stop."""

    def __init__(self, stream, out_q: queue.Queue, stop_event: threading.Event,
                 hook: Callable | None, metrics: ServerMetrics):
        super().__init__(name="serve-ingest", daemon=True)
        self.stream = stream
        self.out_q = out_q
        self.stop_event = stop_event
        self.hook = hook
        self.metrics = metrics

    def run(self) -> None:
        try:
            for rb in self.stream:
                if self.stop_event.is_set():
                    break
                b = rb.row_offset // self.stream.batch_rows
                cols = rb.columns if self.hook is None \
                    else self.hook(b, rb.columns)
                self.metrics.note_ingest(int(cols.shape[1]))
                self.out_q.put(GateItem(b, cols, rb.row_offset,
                                        time.perf_counter()))
        finally:
            self.out_q.put(_SENTINEL)


class _CollectorThread(threading.Thread):
    """Drains the bounded result queue into the report's result list."""

    def __init__(self, in_q: queue.Queue, sink: list):
        super().__init__(name="serve-collect", daemon=True)
        self.in_q = in_q
        self.sink = sink

    def run(self) -> None:
        while True:
            item = self.in_q.get()
            if item is _SENTINEL:
                return
            self.sink.append(item)


class AdmissionServer:
    """The driver (module docstring). ``session`` is a ``FilterSession``
    or ``GuardedSession``; ``stream`` follows the ``LogStream`` contract
    (``data.stream.RequestStream`` adapts any counter-based generator);
    ``executor`` provides ``prefill(ticket) -> ctx`` and
    ``advance(ctx) -> (ctx, done)`` (``SimExecutor`` by default, the
    model-backed one lives in ``launch.serve``); ``batch_hook(b, cols)
    -> cols`` is the pure data-plane fault-injection seam shared with
    ``GuardedSession.run_log_stream``; ``warmup_batch`` compiles the
    gate on a throwaway state before the clock starts so the first
    request's latency is not a compile."""

    def __init__(self, session, stream, config: ServerConfig = ServerConfig(),
                 *, executor=None, batch_hook: Callable | None = None,
                 warmup_batch: np.ndarray | None = None):
        self.session = session
        self.stream = stream
        self.config = config
        self.executor = executor if executor is not None else SimExecutor()
        self.batch_hook = batch_hook
        self.warmup_batch = warmup_batch
        self.metrics = ServerMetrics()
        self.request_q: queue.Queue = queue.Queue(maxsize=config.queue_depth)
        self.result_q: queue.Queue = queue.Queue(maxsize=config.queue_depth)
        self._backlog: list[Ticket] = []
        self._lat_by_id: dict[int, float] = {}
        self.masks: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------ gate
    def _gate_batch(self, state, item: GateItem):
        """The serving admission step (a ``hotpath_lint`` root): drive
        the compiled gate, then hand the device outputs to the one
        sanctioned decision sync. Nothing else may touch the device."""
        state, res = self.session.step(state, item.cols)
        self._decide(res, item)
        return state

    def _decide(self, res, item: GateItem) -> None:
        """THE sanctioned dequeue→decision sync of the serving loop
        (allowlisted in ``hotpath_lint`` with this reason): rejects and
        quarantined batches are answered immediately with a reason code,
        which requires concretizing the gate mask on the host — one
        readback per micro-batch, by design."""
        mask = np.asarray(res.mask_np)
        now = time.perf_counter()
        latency = now - item.t_enqueue
        self.masks[item.batch_index] = mask
        quarantined = bool(getattr(res, "quarantined", False))
        n = int(mask.shape[0])
        if quarantined:
            self.metrics.note_decision(0, 0, n, latency,
                                       float(res.gate_s or 0.0))
            for off in range(n):
                self.result_q.put(RequestResult(
                    item.row_start + off, item.batch_index,
                    REASON_QUARANTINED, latency))
            return
        n_admit = int(mask.sum())
        self.metrics.note_decision(n_admit, n - n_admit, 0, latency,
                                   float(res.gate_s or 0.0))
        for off in np.flatnonzero(~mask):
            self.result_q.put(RequestResult(
                item.row_start + int(off), item.batch_index,
                REASON_REJECTED, latency))
        for off in np.flatnonzero(mask):
            self._backlog.append(Ticket(
                request_id=item.row_start + int(off),
                batch_index=item.batch_index,
                features=np.array(item.cols[:, int(off)])))
            self._lat_by_id[item.row_start + int(off)] = latency

    # ----------------------------------------------------------------- slots
    def _fill_slots(self, slots: list, free: list) -> None:
        while free and self._backlog:
            s = free.pop()
            tk = self._backlog.pop(0)
            slots[s] = (tk, self.executor.prefill(tk), 0)

    def _tick_slots(self, slots: list, free: list) -> None:
        occupied = [s for s in range(len(slots)) if slots[s] is not None]
        if not occupied:
            return
        for s in occupied:
            tk, ctx, ticks = slots[s]
            ctx, done = self.executor.advance(ctx)
            if done:
                self.result_q.put(RequestResult(
                    tk.request_id, tk.batch_index, REASON_ADMITTED,
                    self._lat_by_id.pop(tk.request_id, 0.0),
                    decode_steps=ticks + 1))
                self.metrics.note_completion()
                slots[s] = None
                free.append(s)
            else:
                slots[s] = (tk, ctx, ticks + 1)
        self.metrics.note_tick(len(occupied), len(slots))

    # ------------------------------------------------------------------- run
    def _warmup(self) -> None:
        """Compile the gate outside the measured window: one step on a
        throwaway state through the UNDERLYING session, so guarded
        health counters and the ring stay untouched."""
        if self.warmup_batch is None:
            return
        inner = getattr(self.session, "session", self.session)
        wstate = inner.init_state()
        inner.step(wstate, self.warmup_batch)

    def run(self, state=None, stop=None) -> ServerReport:
        cfg = self.config
        self._warmup()
        session = self.session
        if state is None:
            state = session.init_state()

        results: list[RequestResult] = []
        stop_ingest = threading.Event()
        ingest = _IngestThread(self.stream, self.request_q, stop_ingest,
                               self.batch_hook, self.metrics)
        collector = _CollectorThread(self.result_q, results)
        t0 = time.perf_counter()
        collector.start()
        ingest.start()

        slots: list = [None] * cfg.num_slots
        free: list[int] = list(range(cfg.num_slots))
        ingest_done = False
        drained = False
        backlog_bound = cfg.backlog_bound()
        while True:
            if stop is not None and getattr(stop, "requested", False) \
                    and not drained:
                drained = True
                stop_ingest.set()
            # 1) gate the next queued micro-batch (FIFO — determinism),
            #    unless the admitted backlog is at its bound
            if not ingest_done and len(self._backlog) < backlog_bound:
                try:
                    item = self.request_q.get(
                        timeout=cfg.gate_poll_s if any(
                            s is not None for s in slots) else 0.05)
                except queue.Empty:
                    item = None
                if item is _SENTINEL:
                    ingest_done = True
                elif item is not None:
                    state = self._gate_batch(state, item)
            # 2) freed slot → next admitted request prefills (no barrier)
            self._fill_slots(slots, free)
            # 3) one decode tick across every occupied slot
            self._tick_slots(slots, free)
            self._fill_slots(slots, free)
            if ingest_done and self.request_q.empty() \
                    and not self._backlog \
                    and all(s is None for s in slots):
                break
        wall_s = time.perf_counter() - t0
        ingest.join()
        self.result_q.put(_SENTINEL)
        collector.join()

        # final checkpoint + health line flushed on every exit, drained or
        # not — the drain contract of the SIGTERM test
        blob = session.save_state(state)
        guarded = getattr(session, "is_guarded_session", False)
        guard = session.health_snapshot() if guarded else None
        health_line = session.health.summary() if guarded else None
        return ServerReport(
            results=results, masks=self.masks, state=state, state_blob=blob,
            metrics=self.metrics.snapshot(wall_s, guard=guard),
            drained=drained, health_line=health_line)


def synchronous_reference(session, stream, batch_hook: Callable | None = None):
    """The admission ORACLE: the same plan over the same seeded traffic
    with no queues, threads, or slots. ``AdmissionServer`` must produce a
    bit-identical admit/reject sequence and final ``OrderState`` —
    queuing changes latency, never admission decisions.

    Returns ``(final_state, masks)`` with ``masks[batch_index]`` the
    boolean admission mask.
    """
    state = session.init_state()
    masks: dict[int, np.ndarray] = {}
    for rb in stream:
        b = rb.row_offset // stream.batch_rows
        cols = rb.columns if batch_hook is None else batch_hook(b, rb.columns)
        state, res = session.step(state, cols)
        masks[b] = np.asarray(res.mask_np)
    return state, masks
