"""Serving metrics: admission counters, latency quantiles, occupancy.

One ``ServerMetrics`` instance accounts every request exactly once
(ingested → decided → [completed]); ``snapshot()`` is THE
``BENCH_serve.json`` schema — the CI smoke gate and the README metrics
table both read these field names.

Thread discipline: ``note_ingest`` is called from the ingest thread,
everything else from the driver thread; counters are partitioned by
writer so no lock is needed (CPython int/append atomicity covers the
cross-thread reads at snapshot time, which happens after join anyway).

NOTE ``note_decision`` is reachable from the jitted-admission hot path
(``AdmissionServer._gate_batch`` is a ``hotpath_lint`` root): it must
stay pure host arithmetic — no device-array accessors, no ``.item()``,
no numpy materialization. Callers hand it plain Python numbers.
"""

from __future__ import annotations

import dataclasses


def weighted_quantile(pairs: list, q: float) -> float:
    """Quantile over (value, count) pairs (counts = batch sizes).

    The admission decision is per micro-batch, so every request in a
    batch shares its latency; weighting by count makes the p99 a true
    per-REQUEST quantile, not a per-batch one.
    """
    if not pairs:
        return 0.0
    ordered = sorted(pairs)
    total = sum(c for _, c in ordered)
    target = q * total
    seen = 0
    for value, count in ordered:
        seen += count
        if seen >= target:
            return value
    return ordered[-1][0]


@dataclasses.dataclass
class ServerMetrics:
    """Counters + reservoirs for one server run (module docstring)."""

    # ingest thread
    requests_in: int = 0          # rows handed to the request queue
    batches_in: int = 0
    # driver thread: admission
    admitted: int = 0
    rejected: int = 0
    quarantined: int = 0
    gate_batches: int = 0
    gate_s_total: float = 0.0     # host time inside FilterSession.step
    # driver thread: slots
    completed: int = 0            # admitted requests whose decode finished
    decode_ticks: int = 0
    _occ_sum: float = 0.0
    _occ_samples: int = 0
    # (latency_s, n_requests) per decided micro-batch
    _lat: list = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------ ingest side
    def note_ingest(self, n_rows: int) -> None:
        self.requests_in += n_rows
        self.batches_in += 1

    # ------------------------------------------------------------ driver side
    def note_decision(self, n_admit: int, n_reject: int, n_quar: int,
                      latency_s: float, gate_s: float) -> None:
        """One gated micro-batch: enqueue→decision latency covers queue
        wait + gate compute for every request in the batch."""
        self.admitted += n_admit
        self.rejected += n_reject
        self.quarantined += n_quar
        self.gate_batches += 1
        self.gate_s_total += gate_s
        self._lat.append((latency_s, n_admit + n_reject + n_quar))

    def note_tick(self, occupied: int, slots: int) -> None:
        self.decode_ticks += 1
        self._occ_sum += occupied / slots
        self._occ_samples += 1

    def note_completion(self, n: int = 1) -> None:
        self.completed += n

    # -------------------------------------------------------------- summaries
    @property
    def decided(self) -> int:
        return self.admitted + self.rejected + self.quarantined

    def admission_latency_s(self, q: float) -> float:
        return weighted_quantile(self._lat, q)

    def snapshot(self, wall_s: float, guard: dict | None = None) -> dict:
        """The BENCH_serve.json metrics block. ``guard`` is
        ``GuardedSession.health_snapshot()`` when the gate is guarded,
        None otherwise (the key is always present — schema stability)."""
        decided = self.decided
        denom = max(decided, 1)
        return {
            "requests": self.requests_in,
            "batches": self.batches_in,
            "decided": decided,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "quarantined": self.quarantined,
            "completed": self.completed,
            "admit_rate": self.admitted / denom,
            "reject_rate": self.rejected / denom,
            "quarantine_rate": self.quarantined / denom,
            "wall_s": wall_s,
            "requests_per_sec": decided / wall_s if wall_s > 0 else 0.0,
            "admission_latency_ms": {
                "p50": 1e3 * self.admission_latency_s(0.50),
                "p99": 1e3 * self.admission_latency_s(0.99),
                "max": 1e3 * max((v for v, _ in self._lat), default=0.0),
            },
            "gate_us_per_request": 1e6 * self.gate_s_total / denom,
            "slot_occupancy": (self._occ_sum / self._occ_samples
                               if self._occ_samples else 0.0),
            "decode_ticks": self.decode_ticks,
            "guard": guard,
        }
