"""GuardedSession: self-healing wrapper around ``FilterSession.step``.

The paper's operator only pays off on *long-running* streams — which means
the runtime around it must survive what long-running streams actually
serve: NaN/Inf-poisoned batches, adversarial traffic storms that overflow
the compaction capacity, transient step failures, bit-rotted checkpoints,
and corrupted device state. Strider (arXiv 1705.05688) frames continuous
queries as processes that must outlive their faults; Cuttlefish (arXiv
1802.09180) shows switching physical operators online is cheap — the same
primitive, driven by failure counters instead of reward, is a *degrade
ladder*. This module is both ideas applied to the compiled session:

  detection
    * data-plane admission: a poisoned (non-finite) batch never reaches
      the jitted step — it is QUARANTINED (all-False mask, zero metrics,
      state unchanged, ``StepResult.quarantined=True``);
    * capacity overflow: ``n_dropped > 0`` under a bounded compaction
      width (a column storm) triggers a lossless re-run of the SAME batch
      from the pre-step state — no survivor is ever lost, no statistic is
      folded twice;
    * state integrity: ``FilterSession.validate_state`` — every
      structural invariant fused into ONE jitted boolean, ONE host sync —
      runs once per validation boundary, never per step;
    * checkpoint integrity: every ring entry is the session's versioned
      blob with its crc32; a bit-flipped entry is rejected at restore and
      the ring falls back to the next-newest valid blob.

  recovery
    * bounded retry with exponential backoff + deterministic jitter for
      transient step failures (injected node kills recover here);
    * rollback to a ring of the last-K integrity-checked checkpoints when
      the state itself is corrupt, with stream-cursor replay through the
      counter-based ``LogStream`` (``run_log_stream``) — replayed batch
      indices simply overwrite their earlier, suspect results;
    * a graceful-degradation ladder driven by consecutive failures:
      pallas → jnp engine, skip_tier → off, fused → mask compaction
      (bounded capacity → lossless). Plan fingerprints exclude exactly
      these execution fields, so the live ``OrderState`` and every ring
      checkpoint stay valid across all rungs. The ladder also climbs
      back UP: after ``GuardPolicy.promote_after`` consecutive validated
      boundaries with no fault, the newest degrade is reverted
      (``GuardHealth.promotes`` records each climb) — transient faults
      cost throughput only while they last.

Survivor bit-parity: masks depend on the predicate SET, not the evaluation
order, so quarantine-induced statistic divergence, rollback replay, and
ladder rungs never change which rows survive — the chaos soak in
``tests/test_guard.py`` pins a faulted run bit-equal to a fault-free one
on every non-quarantined batch.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import random
import time
from typing import Any, Callable

import numpy as np

from repro.core.adaptive_filter import StepMetrics
from repro.core.session import FilterSession, StepResult

log = logging.getLogger(__name__)


class GuardStateError(RuntimeError):
    """Unrecoverable: state invalid and no ring checkpoint restores."""


class GuardRollback(Exception):
    """Internal control flow: a ring rollback needs the STREAM rewound.

    Raised by ``step`` only under ``run_log_stream`` (which owns the
    cursor); carries the restored state and the replay cursor.
    """

    def __init__(self, state, cursor: int, entry_step: int):
        super().__init__(f"rollback to ring checkpoint @step {entry_step}")
        self.state = state
        self.cursor = cursor
        self.entry_step = entry_step


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Recovery policy knobs (all counters in steps, delays in seconds)."""

    max_retries: int = 3          # bounded retry per step before degrading
    backoff_base_s: float = 0.05  # first retry delay; doubles per attempt
    backoff_max_s: float = 2.0
    jitter: float = 0.25          # ± fraction of the delay, seeded
    ring_size: int = 4            # last-K integrity-checked checkpoints
    checkpoint_every: int = 16    # steps between ring snapshots
    validate_every: int = 4       # steps between validator syncs
    # re-promotion: after this many CONSECUTIVE validated boundaries with
    # no fault of any kind (quarantine/retry/overflow/validator), climb
    # the degradation ladder back UP one rung (0 disables — degrades are
    # then permanent for the session's lifetime, the pre-PR-10 behavior)
    promote_after: int = 0
    seed: int = 0                 # backoff-jitter determinism
    # injectable clock for tests (never sleep real seconds in CI)
    sleep: Callable[[float], None] = time.sleep


@dataclasses.dataclass
class GuardHealth:
    """Counters every recovery path accounts into (serve/train metrics)."""

    steps: int = 0                # batches that produced a live result
    quarantined: int = 0          # poisoned batches refused at admission
    retries: int = 0              # step failures absorbed by retry
    rollbacks: int = 0            # ring restores (state corruption)
    validator_failures: int = 0   # boundary validations that came back False
    crc_rejects: int = 0          # ring blobs refused (corrupt/invalid)
    overflow_events: int = 0      # capacity storms degraded to lossless
    degrades: list = dataclasses.field(default_factory=list)
    promotes: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["n_degrades"] = len(self.degrades)
        d["n_promotes"] = len(self.promotes)
        return d

    def summary(self) -> str:
        return (f"steps={self.steps} quarantined={self.quarantined} "
                f"retries={self.retries} rollbacks={self.rollbacks} "
                f"crc_rejects={self.crc_rejects} "
                f"overflows={self.overflow_events} "
                f"degrades={len(self.degrades)} "
                f"promotes={len(self.promotes)}")


_RingEntry = collections.namedtuple("_RingEntry", "step cursor blob")


class GuardedSession:
    """Wrap a ``FilterSession`` with detection + recovery (module docstring).

    Drop-in: exposes the full session surface (``plan``, ``init_state``,
    ``save_state``/``restore_state``, ``num_shards``, ...) by delegation,
    so the pipelines and launchers drive it exactly like the bare session.

    ``step_injector``/``state_injector`` are chaos hooks: the first is
    called with the step index inside the retry scope (raise to simulate a
    node failure — ``FailureInjector.maybe_fail`` fits directly), the
    second maps ``(step_index, state) -> state`` before the step runs
    (return a corrupted tree to simulate device-state rot; the boundary
    validator must catch it).
    """

    is_guarded_session = True

    def __init__(self, session: FilterSession,
                 policy: GuardPolicy = GuardPolicy(), *,
                 health: GuardHealth | None = None,
                 step_injector: Callable[[int], None] | None = None,
                 state_injector: Callable[[int, Any], Any] | None = None):
        self.session = session
        self.policy = policy
        self.health = health if health is not None else GuardHealth()
        self.step_injector = step_injector
        self.state_injector = state_injector
        self._ring: collections.deque = collections.deque(
            maxlen=policy.ring_size)
        self._rng = random.Random(policy.seed)
        self._step_idx = 0
        self._stream_cursor = 0       # set by run_log_stream before steps
        self._raise_rollback = False  # True only under run_log_stream
        # re-promotion bookkeeping: each degrade pushes the INVERSE plan
        # changes; ``promote_after`` consecutive fault-free validated
        # boundaries pop one rung back (see _note_boundary)
        self._degrade_stack: list[dict] = []
        self._healthy_boundaries = 0
        self._fault_since = False     # any fault since the last boundary

    # ------------------------------------------------------------ delegation
    def __getattr__(self, name):
        if name == "session":       # not set yet: don't recurse during init
            raise AttributeError(name)
        return getattr(self.session, name)

    def init_state(self):
        state = self.session.init_state()
        self._ring.clear()
        self._snapshot(state)
        return state

    def restore_state(self, blob: dict):
        state = self.session.restore_state(blob)
        self._ring.clear()
        self._snapshot(state)
        return state

    def with_tokenize(self, spec) -> "GuardedSession":
        return GuardedSession(self.session.with_tokenize(spec), self.policy,
                              health=self.health,
                              step_injector=self.step_injector,
                              state_injector=self.state_injector)

    # ---------------------------------------------------------------- step
    def step(self, state, batch):
        """One guarded micro-batch; same signature/ABI as the session's."""
        i = self._step_idx
        self._step_idx += 1

        cols = np.asarray(batch, np.float32) if isinstance(
            batch, (np.ndarray, list)) else batch

        # ---- data-plane admission: quarantine poisoned batches
        if not self._batch_finite(cols):
            self.health.quarantined += 1
            self._fault_since = True
            log.warning("guard: quarantined poisoned batch at step %d "
                        "(non-finite values); state unchanged", i)
            return state, self._quarantined_result(state, cols)

        if self.state_injector is not None:
            state = self.state_injector(i, state)

        # ---- bounded retry + degrade ladder for step failures
        new_state, res = self._step_with_retry(state, cols, i)

        # ---- column storm: overflow under a bounded capacity
        if res.capacity is not None \
                and int(np.asarray(res.metrics.n_dropped).sum()) > 0:
            self.health.overflow_events += 1
            self._fault_since = True
            if self._degrade_lossless(
                    f"capacity overflow at step {i}"):
                # SAME batch, PRE-step state: survivors recovered losslessly
                # and the epoch statistics fold exactly once
                new_state, res = self.session.step(state, cols)

        # ---- boundary validation + ring snapshot
        p = self.policy
        snapshot_due = self._step_idx % p.checkpoint_every == 0
        if snapshot_due or self._step_idx % p.validate_every == 0:
            if not self.session.validate_state(new_state):
                self.health.validator_failures += 1
                self._fault_since = True
                new_state, res = self._recover(state, cols, i)
                snapshot_due = False      # never snapshot a suspect epoch
            self._note_boundary(i)
        if snapshot_due:
            self._snapshot(new_state)
        self.health.steps += 1
        return new_state, res

    def _note_boundary(self, i: int) -> None:
        """Validated-boundary bookkeeping for re-promotion: a boundary
        with no fault since the previous one extends the healthy window;
        any fault (quarantine/retry/overflow/validator) resets it. After
        ``policy.promote_after`` consecutive clean boundaries the
        degradation ladder climbs back UP one rung — a recurring fault
        simply degrades again, so a flapping rung oscillates with period
        ``promote_after`` instead of pinning the session at the bottom."""
        if self._fault_since:
            self._fault_since = False
            self._healthy_boundaries = 0
            return
        self._healthy_boundaries += 1
        p = self.policy
        if p.promote_after > 0 and self._degrade_stack \
                and self._healthy_boundaries >= p.promote_after:
            self._promote_once(
                f"{self._healthy_boundaries} clean validated boundaries "
                f"ending at step {i}")
            self._healthy_boundaries = 0

    # -------------------------------------------------------------- recovery
    def _step_with_retry(self, state, cols, i: int):
        attempt = 0
        while True:
            try:
                if self.step_injector is not None:
                    self.step_injector(i)
                return self.session.step(state, cols)
            except GuardStateError:
                raise
            except Exception as e:           # noqa: BLE001 — retry scope
                attempt += 1
                self._fault_since = True
                if attempt <= self.policy.max_retries:
                    self.health.retries += 1
                    self._backoff(attempt, i, e)
                    continue
                if self._degrade_once(
                        f"{self.policy.max_retries} consecutive step "
                        f"failures at step {i}: {e}"):
                    attempt = 0
                    continue
                raise

    def _backoff(self, attempt: int, i: int, exc: Exception) -> None:
        p = self.policy
        delay = min(p.backoff_base_s * (2.0 ** (attempt - 1)), p.backoff_max_s)
        delay *= 1.0 + p.jitter * (2.0 * self._rng.random() - 1.0)
        log.warning("guard: step %d failed (%s); retry %d/%d in %.3fs",
                    i, exc, attempt, p.max_retries, delay)
        p.sleep(delay)

    def _recover(self, pre_state, cols, i: int):
        """Post-step state failed validation: replay, then roll back.

        1. If the PRE-step state still validates, the corruption happened
           in flight — re-run the batch from it.
        2. Otherwise the state itself rotted: restore the newest ring
           checkpoint that passes crc + validation and re-run the batch
           from there (under ``run_log_stream`` this raises
           ``GuardRollback`` instead, so the stream cursor replays every
           suspect batch since that snapshot).
        3. If even the replay result fails validation, the BATCH drives
           the state invalid: quarantine it and keep the healthy state.
        """
        if self.session.validate_state(pre_state):
            new_state, res = self.session.step(pre_state, cols)
            if self.session.validate_state(new_state):
                return new_state, res
            self.health.quarantined += 1
            log.warning("guard: batch at step %d corrupts any state it "
                        "touches; quarantined", i)
            return pre_state, self._quarantined_result(pre_state, cols)

        entry, restored = self._restore_newest_valid()
        self.health.rollbacks += 1
        log.warning("guard: state corrupt at step %d; rolled back to ring "
                    "checkpoint from step %d", i, entry.step)
        if self._raise_rollback:
            raise GuardRollback(restored, entry.cursor, entry.step)
        new_state, res = self.session.step(restored, cols)
        if self.session.validate_state(new_state):
            return new_state, res
        self.health.quarantined += 1
        return restored, self._quarantined_result(restored, cols)

    def _restore_newest_valid(self):
        """Newest ring entry whose blob passes crc AND whose state passes
        the validator; corrupt entries are skipped (accounted) — the
        integrity-checked-ring contract."""
        for entry in reversed(self._ring):
            try:
                st = self.session.restore_state(entry.blob)
            except ValueError as e:
                self.health.crc_rejects += 1
                log.warning("guard: ring checkpoint @step %d rejected: %s",
                            entry.step, e)
                continue
            if self.session.validate_state(st):
                return entry, st
            self.health.crc_rejects += 1
        raise GuardStateError(
            "state validation failed and no ring checkpoint restores "
            "cleanly — the session cannot self-heal; restart from durable "
            "storage")

    # --------------------------------------------------------- degrade ladder
    def _degrade_once(self, reason: str) -> bool:
        """One rung down: pallas→jnp, then skip_tier→off, then fused→mask
        compaction. Returns False when already at the bottom."""
        plan = self.session.plan
        if plan.engine not in ("jnp", "numpy"):
            changes: dict = {"engine": "jnp"}
        elif plan.skip_tier != "off":
            changes = {"skip_tier": "off"}
        elif plan.compact and plan.tokenize is None:
            changes = {"compact": False, "capacity": None}
        elif plan.compact and plan.capacity is not None:
            changes = {"capacity": None}     # tokenize needs compact: go
        else:                                # lossless instead of mask
            return False
        self._swap_plan(changes, reason)
        return True

    def _degrade_lossless(self, reason: str) -> bool:
        """Storm response: drop the bounded capacity, keep everything else."""
        plan = self.session.plan
        if not plan.compact or plan.capacity is None:
            return False
        self._swap_plan({"capacity": None}, reason)
        return True

    def _swap_plan(self, changes: dict, reason: str) -> None:
        """One rung DOWN: apply ``changes`` and push their inverse so a
        healthy window can climb back (see ``_note_boundary``)."""
        inverse = {k: getattr(self.session.plan, k) for k in changes}
        event = self._apply_plan(changes, reason)
        self._degrade_stack.append(inverse)
        self._healthy_boundaries = 0
        self._fault_since = True
        self.health.degrades.append(event)
        log.warning("guard: degraded %s (%s)", event["changes"], reason)

    def _promote_once(self, reason: str) -> None:
        """One rung UP: pop the newest degrade's inverse and re-apply it.
        If the fault recurs, the regular ladder degrades again."""
        changes = self._degrade_stack.pop()
        event = self._apply_plan(changes, reason)
        self.health.promotes.append(event)
        log.info("guard: re-promoted %s (%s)", event["changes"], reason)

    def _apply_plan(self, changes: dict, reason: str) -> dict:
        old = self.session
        new_plan = dataclasses.replace(old.plan, **changes)
        mesh = old.filter.mesh if old.sharded else None
        new = FilterSession(new_plan, mesh=mesh)
        # the host-side deferred-boundary row counter survives the swap
        # (plan fingerprints exclude every changed field, so the live
        # OrderState and all ring blobs remain loadable as-is)
        new._rows_local = old._rows_local
        self.session = new
        return {"step": self._step_idx, "reason": reason,
                "changes": {k: str(v) for k, v in changes.items()}}

    def health_snapshot(self) -> dict:
        """Health counters plus the ladder's CURRENT rungs — what the
        admission server exports into ``BENCH_serve.json``."""
        d = self.health.to_dict()
        p = self.session.plan
        d["rungs"] = {"engine": p.engine, "skip_tier": p.skip_tier,
                      "compact": p.compact, "capacity": str(p.capacity),
                      "degrade_depth": len(self._degrade_stack)}
        return d

    # ------------------------------------------------------------------ ring
    def _snapshot(self, state) -> None:
        self._ring.append(_RingEntry(
            step=self._step_idx, cursor=self._stream_cursor,
            blob=self.session.save_state(state)))

    # ------------------------------------------------------------- admission
    def _batch_finite(self, cols) -> bool:
        if isinstance(cols, np.ndarray):
            return bool(np.isfinite(cols).all())
        import jax.numpy as jnp
        return bool(np.asarray(jnp.all(jnp.isfinite(cols))))

    def _quarantined_result(self, state, cols) -> StepResult:
        n_rows = int(cols.shape[1])
        z32 = np.zeros((), np.int32)
        metrics = StepMetrics(
            work_units=np.zeros((), np.float32), n_pass=z32,
            perm=np.asarray(state.perm), epoch=np.asarray(state.epoch),
            adj_rank=np.asarray(state.adj_rank), n_dropped=z32,
            n_tiles_pass=z32, n_tiles_fail=z32, n_tiles_ambiguous=z32)
        return StepResult(np.zeros((n_rows,), bool), None, None, None, None,
                          metrics, None, warn_cell=None, quarantined=True,
                          gate_s=0.0)

    # ------------------------------------------------------------ stream run
    def run_log_stream(self, stream, state=None, *,
                       batch_hook: Callable | None = None) -> tuple:
        """Drive a whole counter-based ``LogStream`` under guard.

        The full recovery story, including CURSOR REPLAY: ring snapshots
        record the stream cursor, and a rollback rewinds the stream to the
        snapshot's cursor (counter-based generation makes this exact), so
        every batch stepped on a suspect state is re-run — its replayed
        result simply overwrites the earlier one.

        ``batch_hook(batch_index, cols) -> cols`` is the data-plane fault
        injection point; it MUST be a pure function of its arguments
        (``DataFaultInjector`` is) so replay re-applies identical faults.

        Returns ``(final_state, results)`` where ``results`` maps the
        global batch index to its final ``StepResult``.
        """
        if state is None:
            state = self.session.init_state()
        self._ring.clear()
        self._stream_cursor = stream.cursor
        self._snapshot(state)       # there is always a rollback target
        results: dict[int, StepResult] = {}
        self._raise_rollback = True
        try:
            for rb in stream:       # the generator reads `cursor` live —
                b = rb.row_offset // stream.batch_rows   # rewind-safe
                cols = rb.columns if batch_hook is None \
                    else batch_hook(b, rb.columns)
                self._stream_cursor = stream.cursor
                try:
                    state, res = self.step(state, cols)
                except GuardRollback as g:
                    state = g.state
                    stream.cursor = g.cursor
                    continue
                results[b] = res
        finally:
            self._raise_rollback = False
        return state, results
