"""Fault tolerance: checkpoint/restart driver, failure injection, stragglers.

At 1000+ nodes the mean time between node failures is shorter than a long
run, so the driver treats failure as the normal case:

  * every state element needed to resume — params, optimizer, data-pipeline
    cursor AND the adaptive filter's OrderState (the paper's ranks) — lives
    in one atomic checkpoint; restart resumes BIT-IDENTICALLY (asserted by
    tests/test_fault_tolerance.py);
  * ``FailureInjector`` kills steps deterministically for tests/chaos runs;
  * ``StragglerMonitor`` implements the data-plane mitigation the paper's
    per-executor scope enables: each shard's filter keeps local ranks, so a
    slow/failed shard's *unprocessed batches* can be reassigned to healthy
    shards without transferring any adaptive state (round-robin reassignment
    over the counter-based stream — any shard can generate any batch);
  * elastic rescale: checkpoints are host-local numpy + a manifest, so a
    restore can target a different device count (re-shard on load).
The data-plane extensions (the guarded-runtime counterpart of the
step-kill injector):

  * ``DataFaultInjector`` — seeded NaN/Inf batch poisoning + all-pass
    column storms, pure in ``(seed, batch_index)`` so rollback REPLAY
    re-applies identical faults;
  * ``corrupt_state`` / ``corrupt_blob`` — one-defect OrderState and
    bit-flipped checkpoint factories for validator/integrity tests;
  * ``GracefulShutdown`` — SIGINT/SIGTERM → polled flag, so drivers flush
    a final checkpoint and print the resume command instead of dying
    mid-epoch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class FailureInjector:
    """Deterministically raises at the given step numbers (chaos testing)."""

    def __init__(self, fail_at_steps: Iterable[int] = ()):
        self.fail_at = set(fail_at_steps)
        self.failures = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected node failure at step {step}")


# ========================================================= data-plane faults
class DataFaultInjector:
    """Seeded, REPLAY-DETERMINISTIC data-plane fault schedule.

    Transforms batch contents as a pure function of ``(seed, batch_index,
    cols)`` — never of call count — so the guarded runtime's rollback
    replay (``GuardedSession.run_log_stream``) re-applies identical faults
    to re-generated batches. Fault kinds:

      * ``poison_at``: NaN/Inf-poison a seeded fraction of the batch's
        cells (half NaN, half +Inf) — the admission check must quarantine;
      * ``storm_at``: replace the batch with ``storm_row`` tiled across
        every row — an adversarial column storm in which (by the caller's
        construction of ``storm_row``) every row passes the chain,
        overflowing any bounded ``compact_capacity``.

    Use directly as the ``batch_hook`` of ``run_log_stream``.
    """

    def __init__(self, *, poison_at: Iterable[int] = (),
                 storm_at: Iterable[int] = (), storm_row=None,
                 poison_frac: float = 0.01, seed: int = 0):
        self.poison_at = frozenset(poison_at)
        self.storm_at = frozenset(storm_at)
        if self.storm_at and storm_row is None:
            raise ValueError("storm_at needs storm_row (a [C] feature "
                             "vector every predicate passes)")
        self.storm_row = None if storm_row is None \
            else np.asarray(storm_row, np.float32)
        self.poison_frac = poison_frac
        self.seed = seed

    def __call__(self, batch_index: int, cols: np.ndarray) -> np.ndarray:
        if batch_index in self.storm_at:
            return np.tile(self.storm_row[:, None],
                           (1, cols.shape[1])).astype(np.float32)
        if batch_index in self.poison_at:
            rng = np.random.Generator(
                np.random.Philox(key=[self.seed, batch_index]))
            out = np.array(cols, np.float32)
            flat = out.reshape(-1)
            n = max(1, int(flat.size * self.poison_frac))
            idx = rng.choice(flat.size, size=n, replace=False)
            flat[idx[: n // 2 + 1]] = np.nan
            flat[idx[n // 2 + 1:]] = np.inf
            return out
        return cols


#: defect classes ``corrupt_state`` injects — each one is a distinct
#: violated invariant the fused validator must detect
STATE_CORRUPTIONS = ("nan_stat", "inf_stat", "bad_perm", "bad_group_perm",
                     "count_overflow", "negative_rows")


def corrupt_state(state, kind: str, seed: int = 0):
    """Return a copy of an ``OrderState`` with ONE injected defect.

    Simulates in-memory/device state rot for validator property tests and
    the chaos soak. ``kind`` is one of ``STATE_CORRUPTIONS``.
    """
    from repro.data.pipeline import fstate_from_arrays, fstate_to_arrays

    a = {k: np.array(v) for k, v in fstate_to_arrays(state).items()}
    rng = np.random.Generator(np.random.Philox(key=[seed, 0]))
    if kind == "nan_stat":
        flat = a["stats.num_cut"].reshape(-1)
        flat[rng.integers(flat.size)] = np.nan
    elif kind == "inf_stat":
        flat = a["stats.cost_acc"].reshape(-1)
        flat[rng.integers(flat.size)] = np.inf
    elif kind == "bad_perm":
        a["perm"][..., 0] = a["perm"][..., 1]     # duplicate entry
    elif kind == "bad_group_perm":
        a["group_perm"][..., 0] = a["group_perm"].shape[-1] + 3
    elif kind == "count_overflow":
        a["stats.num_cut"][..., 0] = a["stats.n_monitored"] + 1000.0
    elif kind == "negative_rows":
        a["rows_into_epoch"][...] = -5
    else:
        raise ValueError(f"unknown corruption kind {kind!r}; pick from "
                         f"{STATE_CORRUPTIONS}")
    return fstate_from_arrays(a)


def corrupt_blob(blob: dict, *, seed: int = 0, n_flips: int = 1) -> dict:
    """Bit-flip a checkpoint blob (deep copy; the original is untouched).

    Flips ``n_flips`` seeded bits in one of the envelope's state arrays —
    the storage-rot model the crc32 integrity field exists to catch.
    """
    import copy

    out = copy.deepcopy(blob)
    arrays = out["arrays"] if isinstance(out, dict) and "arrays" in out \
        else out
    rng = np.random.Generator(np.random.Philox(key=[seed, 1]))
    key = sorted(arrays)[int(rng.integers(len(arrays)))]
    v = np.array(np.asarray(arrays[key]))
    raw = v.reshape(-1).view(np.uint8)
    for _ in range(n_flips):
        raw[int(rng.integers(raw.size))] ^= np.uint8(
            1 << int(rng.integers(8)))
    arrays[key] = v
    return out


# ========================================================= graceful shutdown
class GracefulShutdown:
    """Context manager turning SIGINT/SIGTERM into a polled flag.

    First signal: set ``requested`` — the driver finishes the current
    step, flushes a final checkpoint, and prints the resume command.
    Second signal: raise ``KeyboardInterrupt`` (the operator insists).
    Handlers are restored on exit; must be entered from the main thread.
    """

    def __init__(self, signals: Iterable[int] | None = None):
        import signal as _signal

        self._signals = tuple(signals) if signals is not None \
            else (_signal.SIGINT, _signal.SIGTERM)
        self.requested = False
        self._old: dict = {}

    def _handler(self, signum, frame):
        if self.requested:
            raise KeyboardInterrupt
        self.requested = True

    def __enter__(self) -> "GracefulShutdown":
        import signal as _signal

        for s in self._signals:
            self._old[s] = _signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> bool:
        import signal as _signal

        for s, h in self._old.items():
            _signal.signal(s, h)
        self._old.clear()
        return False


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks per-shard step latencies; flags shards slower than
    ``threshold`` × median and proposes batch reassignment."""

    n_shards: int
    threshold: float = 2.0
    window: int = 16

    def __post_init__(self):
        self._lat = [list() for _ in range(self.n_shards)]

    def record(self, shard: int, seconds: float):
        buf = self._lat[shard]
        buf.append(seconds)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> list[int]:
        med = np.median([np.mean(l) for l in self._lat if l] or [0.0])
        if med <= 0:
            return []
        return [i for i, l in enumerate(self._lat)
                if l and np.mean(l) > self.threshold * med]

    def reassign(self, shard_batches: dict[int, list[int]]) -> dict[int, list[int]]:
        """Move the tail of each straggler's queue to the fastest shards.
        Batches are counter-based (stream.gen_batch) so any shard can
        produce any batch — no data movement, just index reassignment."""
        slow = set(self.stragglers())
        if not slow:
            return shard_batches
        fast = [i for i in shard_batches if i not in slow]
        if not fast:
            return shard_batches
        out = {k: list(v) for k, v in shard_batches.items()}
        for s in slow:
            tail = out[s][len(out[s]) // 2:]
            out[s] = out[s][:len(out[s]) // 2]
            for j, b in enumerate(tail):
                out[fast[j % len(fast)]].append(b)
        return out


class TrainDriver:
    """Restartable training loop: run() can be killed at any step (or by the
    injector) and called again; it resumes from the newest checkpoint."""

    def __init__(self, *, step_fn: Callable, pipeline, params, opt_state,
                 ckpt_dir: str, ckpt_every: int = 50,
                 injector: FailureInjector | None = None,
                 async_ckpt: bool = False):
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.params = params
        self.opt_state = opt_state
        self.manager = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector()
        self.async_ckpt = async_ckpt
        self.step = 0
        self.history: list[float] = []

    # ---------------------------------------------------------------- state
    def _tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        self.manager.save(
            self.step, self._tree(),
            extra={"step": self.step,
                   "pipeline": _pipeline_state_to_json(self.pipeline)},
            blocking=not self.async_ckpt)

    def try_restore(self) -> bool:
        from repro.checkpoint.ckpt import latest_step
        if latest_step(self.manager.directory) is None:
            return False
        tree, extra, step = self.manager.restore(self._tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = extra["step"]
        _pipeline_state_from_json(self.pipeline, extra["pipeline"])
        return True

    # ----------------------------------------------------------------- run
    def run(self, n_steps: int, stop: "GracefulShutdown | None" = None
            ) -> bool:
        """Returns True if target reached, False if a failure interrupted.

        ``stop``: optional ``GracefulShutdown`` (or anything with a
        ``requested`` flag) polled between steps — a pending shutdown
        flushes a final checkpoint and returns False instead of dying
        mid-epoch (the caller prints the resume command).
        """
        it = iter(self.pipeline)
        try:
            while self.step < n_steps:
                if stop is not None and getattr(stop, "requested", False):
                    self.manager.wait()
                    self.save()
                    return False
                batch = next(it, None)
                if batch is None:
                    return True  # stream exhausted
                t0 = time.perf_counter()
                self.injector.maybe_fail(self.step)
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                self.history.append(float(metrics["loss"]))
                self.step += 1
                if self.step % self.ckpt_every == 0:
                    self.save()
                _ = time.perf_counter() - t0
        except RuntimeError:
            self.manager.wait()
            return False
        self.manager.wait()
        self.save()
        return True


def _pipeline_state_to_json(pipeline) -> dict:
    """Handles both ``Pipeline`` (one cursor) and ``ShardedPipeline`` (one
    cursor per shard). The filter state is the session's versioned blob:
    scalar metadata (version, fingerprint, shard + accumulator layout)
    rides under ``filter_meta`` so restores are guarded and elastic."""
    st = pipeline.state()
    arrays = st.filter_state["arrays"]
    out = {
        "filter_meta": {k: v for k, v in st.filter_state.items()
                        if k != "arrays"},
        "filter_state": {k: v.tolist() for k, v in arrays.items()},
        "filter_dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "buffer": st.buffer.tolist(),
        "batches_emitted": st.batches_emitted,
        "rows_in": st.rows_in,
        "rows_pass": st.rows_pass,
    }
    if hasattr(st, "stream_cursors"):
        out["stream_cursors"] = [int(c) for c in st.stream_cursors]
    else:
        out["stream_cursor"] = st.stream_cursor
    return out


def _pipeline_state_from_json(pipeline, d: dict):
    from repro.data.pipeline import PipelineState, ShardedPipelineState
    arrays = {k: np.asarray(v, dtype=d["filter_dtypes"][k])
              for k, v in d["filter_state"].items()}
    # pre-session checkpoints have no envelope — their raw arrays load as
    # v1 blobs; versioned ones reassemble the v2 envelope (fingerprint
    # checked, elastic reshard applied on layout change)
    fs = dict(d["filter_meta"], arrays=arrays) if "filter_meta" in d \
        else arrays
    common = dict(filter_state=fs,
                  buffer=np.asarray(d["buffer"], np.int32),
                  batches_emitted=d["batches_emitted"], rows_in=d["rows_in"],
                  rows_pass=d["rows_pass"])
    if "stream_cursors" in d:
        pipeline.restore(ShardedPipelineState(
            stream_cursors=list(d["stream_cursors"]), **common))
    else:
        pipeline.restore(PipelineState(
            stream_cursor=d["stream_cursor"], **common))
