"""Fault tolerance: checkpoint/restart driver, failure injection, stragglers.

At 1000+ nodes the mean time between node failures is shorter than a long
run, so the driver treats failure as the normal case:

  * every state element needed to resume — params, optimizer, data-pipeline
    cursor AND the adaptive filter's OrderState (the paper's ranks) — lives
    in one atomic checkpoint; restart resumes BIT-IDENTICALLY (asserted by
    tests/test_fault_tolerance.py);
  * ``FailureInjector`` kills steps deterministically for tests/chaos runs;
  * ``StragglerMonitor`` implements the data-plane mitigation the paper's
    per-executor scope enables: each shard's filter keeps local ranks, so a
    slow/failed shard's *unprocessed batches* can be reassigned to healthy
    shards without transferring any adaptive state (round-robin reassignment
    over the counter-based stream — any shard can generate any batch);
  * elastic rescale: checkpoints are host-local numpy + a manifest, so a
    restore can target a different device count (re-shard on load).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class FailureInjector:
    """Deterministically raises at the given step numbers (chaos testing)."""

    def __init__(self, fail_at_steps: Iterable[int] = ()):
        self.fail_at = set(fail_at_steps)
        self.failures = 0

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks per-shard step latencies; flags shards slower than
    ``threshold`` × median and proposes batch reassignment."""

    n_shards: int
    threshold: float = 2.0
    window: int = 16

    def __post_init__(self):
        self._lat = [list() for _ in range(self.n_shards)]

    def record(self, shard: int, seconds: float):
        buf = self._lat[shard]
        buf.append(seconds)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> list[int]:
        med = np.median([np.mean(l) for l in self._lat if l] or [0.0])
        if med <= 0:
            return []
        return [i for i, l in enumerate(self._lat)
                if l and np.mean(l) > self.threshold * med]

    def reassign(self, shard_batches: dict[int, list[int]]) -> dict[int, list[int]]:
        """Move the tail of each straggler's queue to the fastest shards.
        Batches are counter-based (stream.gen_batch) so any shard can
        produce any batch — no data movement, just index reassignment."""
        slow = set(self.stragglers())
        if not slow:
            return shard_batches
        fast = [i for i in shard_batches if i not in slow]
        if not fast:
            return shard_batches
        out = {k: list(v) for k, v in shard_batches.items()}
        for s in slow:
            tail = out[s][len(out[s]) // 2:]
            out[s] = out[s][:len(out[s]) // 2]
            for j, b in enumerate(tail):
                out[fast[j % len(fast)]].append(b)
        return out


class TrainDriver:
    """Restartable training loop: run() can be killed at any step (or by the
    injector) and called again; it resumes from the newest checkpoint."""

    def __init__(self, *, step_fn: Callable, pipeline, params, opt_state,
                 ckpt_dir: str, ckpt_every: int = 50,
                 injector: FailureInjector | None = None,
                 async_ckpt: bool = False):
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.params = params
        self.opt_state = opt_state
        self.manager = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector()
        self.async_ckpt = async_ckpt
        self.step = 0
        self.history: list[float] = []

    # ---------------------------------------------------------------- state
    def _tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        self.manager.save(
            self.step, self._tree(),
            extra={"step": self.step,
                   "pipeline": _pipeline_state_to_json(self.pipeline)},
            blocking=not self.async_ckpt)

    def try_restore(self) -> bool:
        from repro.checkpoint.ckpt import latest_step
        if latest_step(self.manager.directory) is None:
            return False
        tree, extra, step = self.manager.restore(self._tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = extra["step"]
        _pipeline_state_from_json(self.pipeline, extra["pipeline"])
        return True

    # ----------------------------------------------------------------- run
    def run(self, n_steps: int) -> bool:
        """Returns True if target reached, False if a failure interrupted."""
        it = iter(self.pipeline)
        try:
            while self.step < n_steps:
                batch = next(it, None)
                if batch is None:
                    return True  # stream exhausted
                t0 = time.perf_counter()
                self.injector.maybe_fail(self.step)
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                self.history.append(float(metrics["loss"]))
                self.step += 1
                if self.step % self.ckpt_every == 0:
                    self.save()
                _ = time.perf_counter() - t0
        except RuntimeError:
            self.manager.wait()
            return False
        self.manager.wait()
        self.save()
        return True


def _pipeline_state_to_json(pipeline) -> dict:
    """Handles both ``Pipeline`` (one cursor) and ``ShardedPipeline`` (one
    cursor per shard). The filter state is the session's versioned blob:
    scalar metadata (version, fingerprint, shard + accumulator layout)
    rides under ``filter_meta`` so restores are guarded and elastic."""
    st = pipeline.state()
    arrays = st.filter_state["arrays"]
    out = {
        "filter_meta": {k: v for k, v in st.filter_state.items()
                        if k != "arrays"},
        "filter_state": {k: v.tolist() for k, v in arrays.items()},
        "filter_dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "buffer": st.buffer.tolist(),
        "batches_emitted": st.batches_emitted,
        "rows_in": st.rows_in,
        "rows_pass": st.rows_pass,
    }
    if hasattr(st, "stream_cursors"):
        out["stream_cursors"] = [int(c) for c in st.stream_cursors]
    else:
        out["stream_cursor"] = st.stream_cursor
    return out


def _pipeline_state_from_json(pipeline, d: dict):
    from repro.data.pipeline import PipelineState, ShardedPipelineState
    arrays = {k: np.asarray(v, dtype=d["filter_dtypes"][k])
              for k, v in d["filter_state"].items()}
    # pre-session checkpoints have no envelope — their raw arrays load as
    # v1 blobs; versioned ones reassemble the v2 envelope (fingerprint
    # checked, elastic reshard applied on layout change)
    fs = dict(d["filter_meta"], arrays=arrays) if "filter_meta" in d \
        else arrays
    common = dict(filter_state=fs,
                  buffer=np.asarray(d["buffer"], np.int32),
                  batches_emitted=d["batches_emitted"], rows_in=d["rows_in"],
                  rows_pass=d["rows_pass"])
    if "stream_cursors" in d:
        pipeline.restore(ShardedPipelineState(
            stream_cursors=list(d["stream_cursors"]), **common))
    else:
        pipeline.restore(PipelineState(
            stream_cursor=d["stream_cursor"], **common))
