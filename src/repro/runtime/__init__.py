"""Runtime substrate: fault-tolerant training driver, failure injection,
straggler mitigation, elastic rescale, and the guarded (self-healing)
session runtime."""

from repro.runtime.fault_tolerance import (STATE_CORRUPTIONS,
                                           DataFaultInjector, FailureInjector,
                                           GracefulShutdown, StragglerMonitor,
                                           TrainDriver, corrupt_blob,
                                           corrupt_state)
from repro.runtime.guard import (GuardedSession, GuardHealth, GuardPolicy,
                                 GuardStateError)

__all__ = [
    "FailureInjector", "TrainDriver", "StragglerMonitor",
    "DataFaultInjector", "GracefulShutdown", "corrupt_state", "corrupt_blob",
    "STATE_CORRUPTIONS",
    "GuardedSession", "GuardPolicy", "GuardHealth", "GuardStateError",
]
