"""Runtime substrate: fault-tolerant training driver, failure injection,
straggler mitigation, elastic rescale."""

from repro.runtime.fault_tolerance import (FailureInjector, TrainDriver,
                                           StragglerMonitor)

__all__ = ["FailureInjector", "TrainDriver", "StragglerMonitor"]
