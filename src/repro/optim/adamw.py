"""Functional AdamW with global-norm clipping and configurable state dtype.

State dtype matters at scale: fp32 m/v for a 671B model is 5.4TB; bf16
state (with fp32 compute at the update) halves it — the dry-run memory
analysis for the MoE giants uses bf16 state (recorded in EXPERIMENTS).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig, lr):
    """One AdamW step (f32 math, params/state cast back to stored dtypes)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), gnorm
