"""Optimizer substrate: AdamW, schedules, clipping, accumulation."""

from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               global_norm, clip_by_global_norm)
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "global_norm",
           "clip_by_global_norm", "cosine_schedule"]
