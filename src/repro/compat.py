"""Version portability shims for the jax API surface we depend on.

``shard_map`` graduated from ``jax.experimental.shard_map`` (jax <= 0.4.x,
replication checker flag ``check_rep``) to ``jax.shard_map`` (flag renamed
``check_vma``). Every shard_map call site in this repo goes through
:func:`shard_map` below so the codebase runs on both; pass ``check_vma``
with the new-API meaning and it is translated for the old API.
"""

from __future__ import annotations

import jax

try:
    _shard_map_new = jax.shard_map          # jax >= 0.5
except AttributeError:
    _shard_map_new = None
    from jax.experimental.shard_map import shard_map as _shard_map_old


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check_vma=None`` means "the API's default" on new jax but disables the
    old ``check_rep`` checker: it predates varying-axis marking (``pcast``)
    and rejects valid programs whose replication only becomes provable
    through collectives (scan carries, all_to_all round-trips).
    """
    if _shard_map_new is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          check_rep=bool(check_vma) if check_vma is not None
                          else False)
