"""qwen2-vl-2b [vlm] — M-RoPE backbone; vision frontend is a STUB:
``input_specs()`` supplies precomputed patch embeddings + 3d positions.
[arXiv:2409.12191]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, rope_style="mrope", qkv_bias=True,
    embeds_input=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, rope_style="mrope", qkv_bias=True,
        embeds_input=True,
    )
