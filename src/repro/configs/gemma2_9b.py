"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab=256000,
    attn_softcap=50.0, final_softcap=30.0,
    window=4096, local_global_every=2, post_norm=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, attn_softcap=50.0, final_softcap=30.0,
        window=32, local_global_every=2, post_norm=True,
    )
