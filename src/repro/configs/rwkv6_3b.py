"""rwkv6-3b [ssm] — Finch: token shift + data-dependent decay, attention-free.
[arXiv:2404.05892; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # head_dim 64
    d_ff=8960, vocab=65536, rope_style="none",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, rope_style="none",
    )
