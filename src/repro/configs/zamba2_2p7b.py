"""zamba2-2.7b [hybrid] — Mamba2 blocks + shared attention block.
[arXiv:2411.15242; hf]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    # chunk=128: SSD intra-chunk traffic ∝ B·S·chunk·H — halving chunk
    # halved the quadratic-part HBM bytes (EXPERIMENTS §Perf, zamba2 cell)
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    hybrid_attn_every=6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        hybrid_attn_every=2,
    )
