"""Config dataclasses for the model zoo + input-shape cells.

Every assigned architecture is one ``ModelConfig`` instance in its own
``configs/<id>.py`` (exact numbers from the assignment) plus a
``smoke_config()`` — a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # always-on shared experts (DeepSeek-V3)
    d_expert: int = 0            # expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64           # mamba2 SSD head dim
    chunk: int = 256             # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 → d_model // n_heads
    rope_style: str = "half"     # half | interleaved | mrope | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None          # sliding-window size (local layers)
    local_global_every: int = 0           # >0: every Nth layer is global
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0            # zamba2: shared attn every k blocks
    enc_layers: int = 0                   # whisper: encoder depth
    enc_seq: int = 1500                   # whisper: encoder frames (stub)
    embeds_input: bool = False            # vlm/audio: takes embeddings, not ids
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    post_norm: bool = False               # gemma2 sandwich norms
    mtp_heads: int = 0                    # deepseek multi-token prediction
    attn_chunk: int = 4096                # flash-chunk length (perf knob;
                                          # baseline table used 1024)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")

    # ------------------------------------------------------------ accounting
    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in §Roofline)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention / mixer
        if self.family == "ssm":            # rwkv6
            per_layer += 5 * d * d + 3 * d * self.d_ff  # time-mix + channel-mix
        elif self.mla is not None:
            m = self.mla
            h = self.n_heads
            per_layer += d * m.q_lora_rank + m.q_lora_rank * h * (m.qk_nope_dim + m.qk_rope_dim)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_dim)
            per_layer += m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
            per_layer += h * m.v_head_dim * d
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            per_layer += 2 * d * d_in + d_in * d  # in/out proj (approx, BC small)
        else:
            hd = self.d_head
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        # ffn / moe
        if self.moe is not None:
            e = self.moe
            per_layer += (e.n_experts + e.n_shared) * 3 * d * e.d_expert
            per_layer += d * e.n_experts  # router
        elif self.family not in ("ssm", "hybrid"):
            per_layer += 3 * d * self.d_ff
        total = emb + L * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += 4 * d * d + 3 * d * self.d_ff  # one shared attn+ffn block
        if self.enc_layers:
            hd = self.d_head
            enc = self.enc_layers * (4 * d * self.n_heads * hd + 3 * d * self.d_ff)
            total += enc + self.n_layers * (2 * d * d)  # + cross-attn kv/q
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.n_layers
        inactive = L * (e.n_experts - e.top_k) * 3 * d * e.d_expert
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assignment table."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing only)
LONG_CONTEXT_OK = ("zamba2-2.7b", "rwkv6-3b")
