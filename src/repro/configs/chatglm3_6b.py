"""chatglm3-6b [dense] — 2d (interleaved, half-dims) RoPE, GQA kv=2.
[arXiv:2406.12793]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, rope_style="interleaved", qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, rope_style="interleaved", qkv_bias=True,
    )
