"""whisper-base [audio] — enc-dec; conv frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings for the encoder. [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, rope_style="none",
    enc_layers=6, enc_seq=1500, embeds_input=True, tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, rope_style="none",
        enc_layers=2, enc_seq=64, embeds_input=True, tie_embeddings=False,
    )
