"""The paper's own workload config: Table 1 defaults + the 4-predicate chain
over the 75M-row synthetic date/int/string stream, plus CNF (AND-of-OR)
variants of the chain for the group-ordering benchmarks."""

import dataclasses

from repro.core.ordering import OrderingConfig
from repro.data.stream import DriftConfig


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    total_rows: int = 75_000_000          # the paper's dataset size
    bench_rows: int = 3_000_000           # CPU-budget default for benchmarks
    batch_rows: int = 65536
    ordering: OrderingConfig = OrderingConfig(
        collect_rate=1000, calculate_rate=1_000_000, momentum=0.3)
    drift: DriftConfig = DriftConfig(kind="regime", period_rows=750_000,
                                     amplitude=1.5)


DEFAULT = PaperWorkload()


def filter_chain(shape: str = "flat"):
    """Paper chain in one of the benchmark group shapes.

    flat — the paper's 4-predicate conjunction (all singleton groups)
    cnf  — int_hi AND int_lo AND (date_gt OR str_match): one OR pair
    wide — int_hi AND (int_lo OR date_gt OR str_match): one 3-wide OR group
    """
    from repro.core.predicates import paper_filters_4, paper_filters_cnf

    if shape == "flat":
        return paper_filters_4("fig1")
    if shape == "cnf":
        return paper_filters_cnf("fig1")
    if shape == "wide":
        int_hi, int_lo, date_gt, str_match = paper_filters_4("fig1")
        grouped = [dataclasses.replace(p, group="wide_or")
                   for p in (int_lo, date_gt, str_match)]
        return [int_hi, *grouped]
    raise ValueError(f"unknown chain shape {shape!r}")


CNF_SHAPES = ("flat", "cnf", "wide")

#: Declared per-column value domains of the paper stream, for the chain
#: linter's always-true analysis (``repro.analysis.chain_lint.lint_chain``).
#: Columns 0 (date) and 1 (int) are normally distributed — unbounded, so
#: they declare nothing; column 2 is the string-hash lane, folded into
#: [0, MIX_MOD) by the hashmix modulo (``core.predicates.MIX_MOD``).
def paper_domains() -> dict[int, tuple[float, float]]:
    from repro.core.predicates import MIX_MOD

    return {2: (0.0, MIX_MOD)}
