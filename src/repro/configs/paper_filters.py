"""The paper's own workload config: Table 1 defaults + the 4-predicate chain
over the 75M-row synthetic date/int/string stream."""

import dataclasses

from repro.core.ordering import OrderingConfig
from repro.data.stream import DriftConfig


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    total_rows: int = 75_000_000          # the paper's dataset size
    bench_rows: int = 3_000_000           # CPU-budget default for benchmarks
    batch_rows: int = 65536
    ordering: OrderingConfig = OrderingConfig(
        collect_rate=1000, calculate_rate=1_000_000, momentum=0.3)
    drift: DriftConfig = DriftConfig(kind="regime", period_rows=750_000,
                                     amplitude=1.5)


DEFAULT = PaperWorkload()
