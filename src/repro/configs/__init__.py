"""Config registry: ``get_config(arch)`` / ``get_smoke_config(arch)`` /
``ARCHS`` (the 10 assigned architectures) / ``SHAPES`` (the 4 cells)."""

import importlib

from repro.configs.base import (LONG_CONTEXT_OK, SHAPES, MLAConfig,
                                ModelConfig, MoEConfig, ShapeCell, SSMConfig)

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "dbrx-132b": "dbrx_132b",
    "zamba2-2.7b": "zamba2_2p7b",
    "rwkv6-3b": "rwkv6_3b",
    "gemma2-9b": "gemma2_9b",
    "qwen2.5-14b": "qwen2p5_14b",
    "chatglm3-6b": "chatglm3_6b",
    "glm4-9b": "glm4_9b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "whisper-base": "whisper_base",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """Skip policy from DESIGN §5 (long_500k needs sub-quadratic mixing)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full attention is O(L^2) at 524k (DESIGN §5 skip)"
    return True, ""


__all__ = ["ARCHS", "SHAPES", "LONG_CONTEXT_OK", "ModelConfig", "MoEConfig",
           "MLAConfig", "SSMConfig", "ShapeCell", "get_config",
           "get_smoke_config", "cell_is_runnable"]
