"""Pipeline parallelism: GPipe-style microbatch schedule over a ``stage``
mesh axis using ``shard_map`` + ``collective_permute``.

The production configs use DP×TP×EP(×SP) — the TPU-idiomatic choice — but
the framework supports PP as a first-class module for topologies where a
stage axis is preferable (e.g. spanning slow inter-pod links). The schedule
is the classic fill-drain: with S stages and M microbatches, bubble fraction
= (S-1)/(M+S-1); each tick every stage runs its block on its current
microbatch, then activations shift stage i → i+1 with one
``collective_permute`` (point-to-point, overlappable).

``pipeline_apply`` is deliberately model-agnostic: it takes a per-stage
``block_fn(stage_params, x) -> x`` and handles scheduling/communication, so
any of the 10 archs' layer stacks can be cut into stages. Correctness is
asserted against the unpipelined reference in tests/test_pipeline_pp.py (4
CPU devices, 2 stages).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(block_fn, stage_params, x_microbatches, *, mesh,
                   stage_axis: str = "stage"):
    """Run a stage-sharded stack over microbatches.

    stage_params: pytree whose leaves have a leading ``n_stages`` axis,
      sharded over ``stage_axis``.
    x_microbatches: [M, mb, ...] activations (replicated over stages).
    Returns [M, mb, ...] outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[stage_axis]

    def per_stage(params, xs):
        # params: this stage's block params (leading axis stripped by shard_map)
        params = jax.tree.map(lambda a: a[0], params)
        m = xs.shape[0]
        ticks = m + n_stages - 1
        stage_id = jax.lax.axis_index(stage_axis)

        def tick(carry, t):
            buf, outputs = carry
            # stage s works on microbatch (t - s) when 0 <= t-s < m
            mb_idx = t - stage_id
            active = jnp.logical_and(mb_idx >= 0, mb_idx < m)
            # stage 0 ingests from xs; others use the shifted-in buffer
            x_in = jnp.where(stage_id == 0,
                             xs[jnp.clip(mb_idx, 0, m - 1)], buf)
            y = block_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # shift activations one stage forward (ring permute)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            shifted = jax.lax.ppermute(y, stage_axis, perm)
            # last stage commits its finished microbatch
            out_idx = t - (n_stages - 1)
            commit = jnp.logical_and(stage_id == n_stages - 1,
                                     jnp.logical_and(out_idx >= 0,
                                                     out_idx < m))
            outputs = jnp.where(
                commit,
                outputs.at[jnp.clip(out_idx, 0, m - 1)].set(y),
                outputs)
            return (shifted, outputs), None

        # initial carries must be marked stage-varying (they become so after
        # one tick: stage_id enters the dataflow); old jax has no pcast and
        # no varying-manifest axes — there the unmarked zeros are fine
        # because the shard_map below disables the replication checker
        pcast = getattr(jax.lax, "pcast", None)
        if pcast is not None:
            buf0 = pcast(jnp.zeros_like(xs[0]), ("stage",), to="varying")
            out0 = pcast(jnp.zeros_like(xs), ("stage",), to="varying")
        else:
            buf0 = jnp.zeros_like(xs[0])
            out0 = jnp.zeros_like(xs)
        (_, outputs), _ = jax.lax.scan(tick, (buf0, out0),
                                       jnp.arange(ticks))
        # replicate final-stage outputs to every stage
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, 0.0), stage_axis)
        return outputs

    spec_params = jax.tree.map(lambda _: P(stage_axis), stage_params)
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_params, P()), out_specs=P(),
    )(stage_params, x_microbatches)
