"""Partition rules: param/batch/cache PartitionSpecs for every arch.

Layout (DESIGN §6):
  * TP over ``model``: attention head projections, FFN inner dim, MoE expert
    axis (EP), vocab axis of the embedding/lm-head.
  * DP over ``data`` (× ``pod`` in the multi-pod mesh): the batch axis.
  * FSDP/ZeRO-3 over the DP axes: every ≥2-D weight additionally shards its
    largest not-yet-sharded axis (param + grad + optimizer state) — this is
    what lets the 671B config fit per-chip HBM.
  * SP for serving caches: the sequence axis shards over ``model`` (and over
    the DP axes too when global_batch == 1, the long_500k cell), so decode
    attention merges softmax partials with small all-reduces instead of
    gathering a multi-GB cache.

Rules are name-based on the param-tree path; divisibility is checked and
falls back to replication (e.g. whisper's vocab 51865 is not 16-divisible).
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weights whose LAST axis is the "parallel" (output/TP) axis
_SHARD_LAST = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b",
               "w_gate", "w_up", "ck", "lora_a", "wa", "wr", "wg",
               "in_proj", "conv_w", "proj", "bq", "bk", "bv"}
# weights whose FIRST (non-stack) axis is the parallel (input) axis
_SHARD_FIRST = {"wo", "w_down", "cv", "out_proj", "wb", "lora_b"}
_REPLICATED = {"router", "mu_rkvgw", "u"}
_STACKED = {"layers", "enc_layers"}


def _axis_size(mesh_shape: dict, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape[axes]
    n = 1
    for a in axes:
        n *= mesh_shape[a]
    return n


def param_pspec(path: Sequence[str], shape, *, mesh_shape: dict,
                dp_axes=("data",), fsdp: bool = True) -> P:
    names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
    leaf = names[-1]
    stacked = 1 if (names and names[0] in _STACKED) else 0
    ndim = len(shape)
    spec: list = [None] * ndim

    def fits(dim_idx, axes) -> bool:
        return spec[dim_idx] is None and \
            shape[dim_idx] % _axis_size(mesh_shape, axes) == 0

    is_moe_expert = "moe" in names and leaf in ("w_gate", "w_up", "w_down")
    if is_moe_expert:
        if fits(stacked, "model"):
            spec[stacked] = "model"                 # expert axis → EP
    elif leaf == "embed":
        if fits(0, "model"):
            spec[0] = "model"                       # vocab-parallel
    elif leaf == "lm_head":
        if fits(ndim - 1, "model"):
            spec[ndim - 1] = "model"
    elif leaf in _REPLICATED or ndim - stacked <= 1 and leaf not in _SHARD_LAST:
        pass
    elif leaf in _SHARD_LAST:
        if fits(ndim - 1, "model"):
            spec[ndim - 1] = "model"
    elif leaf in _SHARD_FIRST:
        if fits(stacked, "model"):
            spec[stacked] = "model"

    if fsdp and ndim - stacked >= 2:
        # ZeRO-3: shard the biggest remaining axis over the DP axes
        cands = [i for i in range(stacked, ndim) if spec[i] is None]
        cands.sort(key=lambda i: -shape[i])
        for i in cands:
            if fits(i, dp_axes):
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
    return P(*spec)


def params_shardings(params_shape, mesh: Mesh, *, fsdp: bool = True):
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def rule(path, leaf):
        spec = param_pspec(path, leaf.shape, mesh_shape=mesh_shape,
                           dp_axes=dp_axes, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_pspec(mesh: Mesh, global_batch: int):
    """Batch axis over the DP axes (dropping axes that don't divide)."""
    dp_axes = [a for a in mesh.axis_names if a != "model"]
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    use = []
    n = 1
    for a in dp_axes:
        if global_batch % (n * mesh_shape[a]) == 0:
            use.append(a)
            n *= mesh_shape[a]
    return tuple(use) if use else None


def batch_shardings(batch_shape, mesh: Mesh, global_batch: int):
    dp = batch_pspec(mesh, global_batch)

    def rule(path, leaf):
        names = [str(getattr(p, "key", p)) for p in path]
        if names and names[-1] == "positions" and len(leaf.shape) == 3:
            return NamedSharding(mesh, P(None, dp, None))
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_shardings(cache_shape, mesh: Mesh, global_batch: int, capacity: int):
    """SP rules for serving caches: shard the (large) sequence axis."""
    dp = batch_pspec(mesh, global_batch)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    seq_axes = ("model",) if dp else \
        tuple(a for a in mesh.axis_names if a != "model") + ("model",)

    def rule(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1:
            spec[0] = None                               # stacked L
        if len(shape) >= 2 and dp and shape[1] == -1:
            pass
        # find the capacity axis (== capacity) → SP; batch axis (== B) → DP
        for i, s in enumerate(shape):
            if i == 0:
                continue
            if s == capacity and s % _axis_size(mesh_shape, seq_axes) == 0:
                spec[i] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
                break
        for i, s in enumerate(shape):
            if i == 0 or spec[i] is not None:
                continue
            if dp and s == global_batch:
                spec[i] = dp
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
