"""Gradient compression for cross-pod all-reduce (DESIGN §6 tricks).

Two standard schemes, both as pure functional transforms that wrap the
gradient tree before the optimizer:

  * top-k sparsification with ERROR FEEDBACK (Stich et al.): each step sends
    only the k largest-|g| entries per tensor; the residual is carried and
    added back next step, so the compression error is compensated rather
    than lost. Compression ratio k/n, typically 1–10%.
  * int8 quantization (per-tensor scale): 4× volume reduction for f32
    gradients with stochastic-rounding-free symmetric quantization.

On a real multi-pod fabric these run *before* the cross-pod reduction (the
``pod`` axis all-reduce is the slow hop); compiled-HLO wire bytes with and
without compression are compared in EXPERIMENTS §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ top-k + EF
def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_compress(grads, residual, *, fraction: float = 0.01):
    """Returns (sparse_grads, new_residual). ``sparse_grads`` keeps only the
    top-``fraction`` entries of (grad + residual) per tensor; the rest moves
    into the residual (error feedback)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        flat = g.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g) >= thresh
        sent = jnp.where(mask, g, 0.0)
        return sent, g - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


# ------------------------------------------------------------------ int8
def int8_compress(grads):
    """(quantized int8 tree, scales tree) — symmetric per-tensor."""

    def q(g):
        g = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8), scale

    flat, tdef = jax.tree.flatten(grads)
    qs = [q(g) for g in flat]
    return (jax.tree.unflatten(tdef, [x[0] for x in qs]),
            jax.tree.unflatten(tdef, [x[1] for x in qs]))


def int8_decompress(qgrads, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qgrads, scales)


def compressed_psum(grads, axis_name: str, *, scheme: str = "none",
                    residual=None, fraction: float = 0.01):
    """All-reduce ``grads`` over ``axis_name`` with optional compression.
    Must run inside shard_map/pmap. Returns (reduced, new_residual)."""
    if scheme == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads), residual
    if scheme == "int8":
        q, s = int8_compress(grads)
        q = jax.tree.map(lambda x: jax.lax.psum(x.astype(jnp.int32),
                                                axis_name), q)
        # scales reduced with max → conservative dequant
        s = jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), s)
        return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, s), \
            residual
    if scheme == "topk":
        sent, residual = topk_compress(grads, residual, fraction=fraction)
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), sent), residual
    raise ValueError(f"unknown compression scheme {scheme}")
