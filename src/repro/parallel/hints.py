"""Activation-sharding hints (the §Perf levers).

Model code is mesh-agnostic; the launcher enables hints with the mesh's
axis names before lowering, and performance-critical spots call
``constrain(x, "dp", "tp", None, ...)`` to pin activation layouts where
GSPMD's default propagation picks pathological reshards (EXPERIMENTS §Perf
documents each site with before/after numbers). With hints disabled (unit
tests, single device) every call is a no-op.

Axis tokens: "dp" → the data axes (("pod","data") on the multi-pod mesh),
"tp" → the model axis, None → unsharded.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"enabled": False, "dp": ("data",), "tp": "model",
          "mesh": None}


def enable(dp=("data",), tp="model", mesh=None):
    _STATE.update(enabled=True, dp=tuple(dp), tp=tp, mesh=mesh)


def disable():
    _STATE["enabled"] = False


def enabled() -> bool:
    return _STATE["enabled"]


def mesh():
    return _STATE["mesh"]


def axes(token):
    if token == "dp":
        dp = _STATE["dp"]
        return dp if len(dp) > 1 else dp[0]
    if token == "tp":
        return _STATE["tp"]
    return token


def spec(*tokens) -> P:
    return P(*[axes(t) for t in tokens])


def constrain(x, *tokens):
    if not _STATE["enabled"]:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*tokens))
