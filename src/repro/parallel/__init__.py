"""Distribution substrate: sharding rules (DP/TP/EP/SP + ZeRO/FSDP),
gradient compression, pipeline parallelism."""
