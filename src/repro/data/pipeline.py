"""Staged ingestion pipeline: stream → adaptive filter → tokenize → pack.

This is where the paper's operator becomes a first-class framework feature:
the filter stage is an ``AdaptiveFilter`` (or a static one — drop-in), its
``OrderState`` is part of the pipeline checkpoint (adaptive ranks survive
restarts, per DESIGN §6), and every host/shard runs its own instance — the
paper's per-executor scope by construction.

Two deployment shapes, both thin iterators over ONE ``FilterSession``
(``make_pipeline(build_session(plan), ...)`` picks the right one):

  ``Pipeline``        — one stream, one session (one host process = one
                        executor; run N processes for N executors).
  ``ShardedPipeline`` — one process drives a whole data mesh: S per-shard
                        ``LogStream``s feed ONE sharded session step per
                        iteration (shard_map over the mesh's data axis,
                        per-shard OrderState, scope-controlled stat
                        exchange — see ``core.sharded``).

All per-step driving — capacity resolution, deferred exchange, auto
retune, overflow warnings, metrics — lives in ``FilterSession.step``; the
pipelines only assemble batches and emit fixed-shape LM examples.

Both emit fixed-shape LM batches {"tokens": i32[B, S], "labels": i32[B, S]}
ready for ``train_step``, checkpoint/restore bit-identically (the
fault-tolerance tests restart mid-stream and compare batch sequences), and
honour ``compact_output``: survivors then arrive as padded on-device
buffers + counts and the host never boolean-indexes a batch. With
``device_tokenize=True`` (needs ``compact_output``) the tokenize/pack stage
consumes those padded buffers ON DEVICE too (``tokenizer.tokens_from_padded``
— valid-count-masked hash + O(N) cumsum pack), so one ingestion iteration
moves exactly one dense token buffer to the host: stream → filter →
compact → tokenize is a single device-resident pass. Deferred-exchange
epoch boundaries (``AdaptiveFilterConfig.exchange``) and auto capacity
re-tuning (``compact_capacity="auto"``) are driven from here, after each
step.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Iterator, Sequence

import numpy as np

log = logging.getLogger(__name__)

from repro.core.plan import TokenizeSpec
from repro.data import tokenizer
from repro.data.stream import LogStream


def _as_session(filt, device_tokenize: bool, vocab_size: int,
                tokens_per_row: int):
    """Normalize a pipeline's filter argument to ONE ``FilterSession``.

    Accepts a ``FilterSession`` (the plan-first path), a ``GuardedSession``
    (the self-healing wrapper — it proxies the full session surface, so the
    pipeline drives it identically and gains quarantine/retry/rollback for
    free), or a legacy ``AdaptiveFilter`` / ``ShardedAdaptiveFilter``
    instance (adopted under a synthesized plan). Returns
    (session, device_tokenize) with the tokenize stage attached to the
    session when requested — all combination validation happens in
    ``FilterPlan``, not here.
    """
    from repro.core.session import FilterSession

    if isinstance(filt, FilterSession) \
            or getattr(filt, "is_guarded_session", False):
        session = filt
    else:
        session = FilterSession.from_filter(filt)
    spec = session.plan.tokenize
    if spec is None and device_tokenize:
        spec = TokenizeSpec(vocab_size, tokens_per_row)
        session = session.with_tokenize(spec)
    if spec is not None and (spec.vocab_size != vocab_size
                             or spec.tokens_per_row != tokens_per_row):
        raise ValueError(
            f"pipeline tokenize params (vocab={vocab_size}, "
            f"tokens_per_row={tokens_per_row}) disagree with the plan's "
            f"TokenizeSpec {spec}")
    return session, spec is not None


def fstate_to_arrays(fstate) -> dict:
    """OrderState → flat dict of numpy arrays (checkpoint encoding).

    Works for single [P]-shaped states and stacked [S, P] sharded states —
    leaves are stored verbatim, stats fields under a ``stats.`` prefix.
    """
    return {k: np.asarray(v) for k, v in fstate._asdict().items()
            if k != "stats"} \
        | {f"stats.{k}": np.asarray(v) for k, v in
           fstate.stats._asdict().items()}


def fstate_from_arrays(fs: dict):
    """Inverse of ``fstate_to_arrays`` (jnp leaves).

    Pre-CNF checkpoints lack the group fields; for flat chains group_cut ≡
    num_cut accumulators start at zero and group_perm is the identity, so
    the defaults restore them losslessly (shape-generic: the identity is
    broadcast over any leading shard axis).
    """
    import jax.numpy as jnp

    from repro.core.ordering import OrderState
    from repro.core.stats import FilterStats

    adj = np.asarray(fs["adj_rank"])
    n_groups = int(adj.shape[-1])
    stats = FilterStats(jnp.asarray(fs["stats.num_cut"]),
                        jnp.asarray(fs["stats.cost_acc"]),
                        jnp.asarray(fs["stats.n_monitored"]),
                        jnp.asarray(fs.get("stats.group_cut",
                                           fs["stats.num_cut"])))
    default_gperm = np.broadcast_to(
        np.arange(n_groups, dtype=np.int32), adj.shape)
    return OrderState(
        perm=jnp.asarray(fs["perm"]), adj_rank=jnp.asarray(fs["adj_rank"]),
        stats=stats, rows_into_epoch=jnp.asarray(fs["rows_into_epoch"]),
        sample_phase=jnp.asarray(fs["sample_phase"]),
        epoch=jnp.asarray(fs["epoch"]),
        group_perm=jnp.asarray(fs.get("group_perm", default_gperm)))


@dataclasses.dataclass
class PipelineState:
    stream_cursor: int
    filter_state: dict          # versioned session blob (schema v2);
                                # pre-session raw-array (v1) dicts restore too
    buffer: np.ndarray          # leftover tokens not yet emitted
    batches_emitted: int
    rows_in: int
    rows_pass: int


class _LMBatchEmitter:
    """Shared session-step + tokenize-buffer-emit tail of both pipelines.

    Expects ``batch_size``, ``seq_len``, ``vocab_size``, ``tokens_per_row``,
    ``_session``, ``_fstate``, ``_buffer``, and ``batches_emitted`` on self.
    """

    def _emit_tokens(self, toks: np.ndarray) -> Iterator[dict]:
        self._buffer = np.concatenate([self._buffer, toks])
        need = self.batch_size * (self.seq_len + 1)
        while self._buffer.size >= need:
            chunk, self._buffer = self._buffer[:need], self._buffer[need:]
            seq = chunk.reshape(self.batch_size, self.seq_len + 1)
            self.batches_emitted += 1
            yield {"tokens": seq[:, :-1].astype(np.int32),
                   "labels": seq[:, 1:].astype(np.int32)}

    def _emit(self, survivors: np.ndarray) -> Iterator[dict]:
        yield from self._emit_tokens(tokenizer.rows_to_tokens(
            survivors, self.vocab_size, self.tokens_per_row))

    def _filter_step(self, columns: np.ndarray):
        """ONE session step; returns (payload, n_pass).

        ``payload`` is the dense token stream under device tokenization
        (the rows never come back to the host), otherwise the surviving
        rows (sliced from the packed device buffer under compaction, a host
        boolean index otherwise). All driving — capacity resolution,
        deferred exchange, auto retune, overflow warning, metrics — is the
        session's; ``last_metrics`` is its uniform JSON encoding, with
        per-shard ``n_dropped`` alongside the sum for sharded sessions.
        """
        self._fstate, res = self._session.step(self._fstate, columns)
        self.last_metrics = res.metrics_dict()
        if self._device_tokenize:
            return res.host_tokens(), res.n_pass
        return res.survivors(columns), res.n_pass


class Pipeline(_LMBatchEmitter):
    def __init__(self, stream: LogStream, filt,
                 batch_size: int, seq_len: int, vocab_size: int,
                 tokens_per_row: int = 8, device_tokenize: bool = False):
        self.stream = stream
        self._session, self._device_tokenize = _as_session(
            filt, device_tokenize, vocab_size, tokens_per_row)
        self.filt = self._session.filter
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.tokens_per_row = tokens_per_row
        self._fstate = self._session.init_state()
        self._buffer = np.zeros((0,), np.int32)
        self.batches_emitted = 0
        self.rows_in = 0
        self.rows_pass = 0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------- checkpoint
    def state(self) -> PipelineState:
        return PipelineState(
            stream_cursor=self.stream.cursor,
            filter_state=self._session.save_state(self._fstate),
            buffer=self._buffer.copy(),
            batches_emitted=self.batches_emitted,
            rows_in=self.rows_in,
            rows_pass=self.rows_pass,
        )

    def restore(self, st: PipelineState) -> None:
        self.stream.cursor = st.stream_cursor
        self._fstate = self._session.restore_state(st.filter_state)
        self._buffer = st.buffer.copy()
        self.batches_emitted = st.batches_emitted
        self.rows_in = st.rows_in
        self.rows_pass = st.rows_pass

    # -------------------------------------------------------------- iteration
    def __iter__(self) -> Iterator[dict]:
        for rb in self.stream:
            payload, n_pass = self._filter_step(rb.columns)
            self.rows_in += rb.n_rows
            self.rows_pass += n_pass
            if self._device_tokenize:
                yield from self._emit_tokens(payload)
            else:
                yield from self._emit(payload)


# =============================================================== sharded
@dataclasses.dataclass
class ShardedPipelineState:
    stream_cursors: list        # one LogStream cursor per shard
    filter_state: dict          # versioned session blob (v2; stacked
                                # [S, ...] arrays inside), v1 loads too
    buffer: np.ndarray
    batches_emitted: int
    rows_in: int
    rows_pass: int


class ShardedPipeline(_LMBatchEmitter):
    """Multi-shard ingestion: S per-shard streams → one shard_map step.

    ``streams[i]`` must be the i-th round-robin partition of one logical
    stream (``LogStream(shard_id=i, num_shards=S)``) — like Spark partitions
    spread over executors. Each iteration pulls one batch per shard,
    block-concatenates them into the [C, S·R] layout ``ShardedAdaptiveFilter``
    expects (shard i owns rows [i·R, (i+1)·R)), runs ONE jitted sharded
    step, and packs survivors shard-major into LM batches. The stacked
    per-shard ``OrderState`` checkpoints/restores as a whole, so every
    shard's adaptive ranks survive a restart.
    """

    def __init__(self, streams: Sequence[LogStream], filt,
                 batch_size: int, seq_len: int,
                 vocab_size: int, tokens_per_row: int = 8,
                 device_tokenize: bool = False):
        self._session, self._device_tokenize = _as_session(
            filt, device_tokenize, vocab_size, tokens_per_row)
        self.filt = self._session.filter
        if len(streams) != self._session.num_shards:
            raise ValueError(f"{len(streams)} streams for "
                             f"{self._session.num_shards} shards")
        self.streams = list(streams)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.tokens_per_row = tokens_per_row
        self._fstate = self._session.init_state()
        self._buffer = np.zeros((0,), np.int32)
        self.batches_emitted = 0
        self.rows_in = 0
        self.rows_pass = 0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------- checkpoint
    def state(self) -> ShardedPipelineState:
        return ShardedPipelineState(
            stream_cursors=[s.cursor for s in self.streams],
            filter_state=self._session.save_state(self._fstate),
            buffer=self._buffer.copy(),
            batches_emitted=self.batches_emitted,
            rows_in=self.rows_in,
            rows_pass=self.rows_pass,
        )

    def restore(self, st: ShardedPipelineState) -> None:
        if len(st.stream_cursors) != len(self.streams):
            # elastic S→S′ rescale: the filter state reshards through the
            # session (accumulators split/merged — sums, so exact; see
            # core.session); every new round-robin stream partition resumes
            # at the next unconsumed GLOBAL batch index (the max cursor —
            # all source shards have walked the indices below it).
            cursor = max(int(c) for c in st.stream_cursors)
            for stream in self.streams:
                stream.cursor = cursor
        else:
            for stream, cur in zip(self.streams, st.stream_cursors):
                stream.cursor = int(cur)
        self._fstate = self._session.restore_state(st.filter_state)
        self._buffer = st.buffer.copy()
        self.batches_emitted = st.batches_emitted
        self.rows_in = st.rows_in
        self.rows_pass = st.rows_pass

    # -------------------------------------------------------------- iteration
    def __iter__(self) -> Iterator[dict]:
        iters = [iter(s) for s in self.streams]
        while True:
            rbs = []
            for it in iters:
                rb = next(it, None)
                if rb is None:          # a shard ran dry → stream over
                    return
                rbs.append(rb)
            cols = np.concatenate([rb.columns for rb in rbs], axis=1)
            payload, n_pass = self._filter_step(cols)
            self.rows_in += cols.shape[1]
            self.rows_pass += n_pass
            if self._device_tokenize:
                yield from self._emit_tokens(payload)
            else:
                yield from self._emit(payload)


def make_pipeline(session, *, total_rows: int, batch_rows: int,
                  batch_size: int, seq_len: int, vocab_size: int | None = None,
                  seed: int = 0, drift=None, tokens_per_row: int | None = None):
    """One ``FilterSession`` → its ingestion pipeline.

    Builds one round-robin ``LogStream`` partition per plan shard and
    returns a ``Pipeline`` (1 shard) or ``ShardedPipeline`` (shard_map over
    the session's mesh). Device tokenization follows the plan's
    ``tokenize`` spec — there is nothing to wire by hand:
    ``vocab_size``/``tokens_per_row`` default from it (they are only
    required here when the plan has no tokenize stage and the host
    tokenizer needs them).
    """
    from repro.data.stream import DriftConfig

    spec = session.plan.tokenize
    if vocab_size is None:
        if spec is None:
            raise ValueError("vocab_size is required when the plan has no "
                             "TokenizeSpec to default it from")
        vocab_size = spec.vocab_size
    if tokens_per_row is None:
        tokens_per_row = spec.tokens_per_row if spec is not None else 8
    drift = drift or DriftConfig()
    n = session.num_shards if session.sharded else 1
    streams = [LogStream(total_rows=total_rows, batch_rows=batch_rows,
                         seed=seed, drift=drift, shard_id=i, num_shards=n)
               for i in range(n)]
    kw = dict(batch_size=batch_size, seq_len=seq_len, vocab_size=vocab_size,
              tokens_per_row=tokens_per_row)
    if session.sharded:
        return ShardedPipeline(streams, session, **kw)
    return Pipeline(streams[0], session, **kw)
