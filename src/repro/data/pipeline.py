"""Staged ingestion pipeline: stream → adaptive filter → tokenize → pack.

This is where the paper's operator becomes a first-class framework feature:
the filter stage is an ``AdaptiveFilter`` (or a static one — drop-in), its
``OrderState`` is part of the pipeline checkpoint (adaptive ranks survive
restarts, per DESIGN §6), and every host/shard runs its own instance — the
paper's per-executor scope by construction.

Emits fixed-shape LM batches {"tokens": i32[B, S], "labels": i32[B, S]}
ready for ``train_step``. Deterministic given (seed, cursor): the
fault-tolerance test restarts mid-stream and checks the batch sequence is
bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.core.adaptive_filter import AdaptiveFilter
from repro.data import tokenizer
from repro.data.stream import LogStream


@dataclasses.dataclass
class PipelineState:
    stream_cursor: int
    filter_state: dict          # OrderState as numpy arrays
    buffer: np.ndarray          # leftover tokens not yet emitted
    batches_emitted: int
    rows_in: int
    rows_pass: int


class Pipeline:
    def __init__(self, stream: LogStream, filt: AdaptiveFilter,
                 batch_size: int, seq_len: int, vocab_size: int,
                 tokens_per_row: int = 8):
        self.stream = stream
        self.filt = filt
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.tokens_per_row = tokens_per_row
        self._jit_step = filt.jit_step        # compiled once per filter
        self._fstate = filt.init_state()
        self._buffer = np.zeros((0,), np.int32)
        self.batches_emitted = 0
        self.rows_in = 0
        self.rows_pass = 0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------- checkpoint
    def state(self) -> PipelineState:
        return PipelineState(
            stream_cursor=self.stream.cursor,
            filter_state={k: np.asarray(v) for k, v in
                          self._fstate._asdict().items() if k != "stats"}
            | {f"stats.{k}": np.asarray(v) for k, v in
               self._fstate.stats._asdict().items()},
            buffer=self._buffer.copy(),
            batches_emitted=self.batches_emitted,
            rows_in=self.rows_in,
            rows_pass=self.rows_pass,
        )

    def restore(self, st: PipelineState) -> None:
        from repro.core.ordering import OrderState
        from repro.core.stats import FilterStats
        import jax.numpy as jnp

        self.stream.cursor = st.stream_cursor
        fs = st.filter_state
        # pre-CNF checkpoints lack the group fields; for flat chains
        # group_cut ≡ num_cut accumulators start at zero and group_perm is
        # the identity, so these defaults restore them losslessly
        n_groups = int(np.asarray(fs["adj_rank"]).shape[0])
        stats = FilterStats(jnp.asarray(fs["stats.num_cut"]),
                            jnp.asarray(fs["stats.cost_acc"]),
                            jnp.asarray(fs["stats.n_monitored"]),
                            jnp.asarray(fs.get("stats.group_cut",
                                               fs["stats.num_cut"])))
        self._fstate = OrderState(
            perm=jnp.asarray(fs["perm"]), adj_rank=jnp.asarray(fs["adj_rank"]),
            stats=stats, rows_into_epoch=jnp.asarray(fs["rows_into_epoch"]),
            sample_phase=jnp.asarray(fs["sample_phase"]),
            epoch=jnp.asarray(fs["epoch"]),
            group_perm=jnp.asarray(fs.get("group_perm",
                                          np.arange(n_groups,
                                                    dtype=np.int32))))
        self._buffer = st.buffer.copy()
        self.batches_emitted = st.batches_emitted
        self.rows_in = st.rows_in
        self.rows_pass = st.rows_pass

    # -------------------------------------------------------------- iteration
    def __iter__(self) -> Iterator[dict]:
        need = self.batch_size * (self.seq_len + 1)
        for rb in self.stream:
            self._fstate, mask, metrics = self._jit_step(
                self._fstate, rb.columns)
            mask_np = np.asarray(mask)
            survivors = rb.select(mask_np)
            self.rows_in += rb.n_rows
            self.rows_pass += int(mask_np.sum())
            self.last_metrics = {
                "work_units": float(metrics.work_units),
                "perm": np.asarray(metrics.perm).tolist(),
                "epoch": int(metrics.epoch),
            }
            toks = tokenizer.rows_to_tokens(
                survivors, self.vocab_size, self.tokens_per_row)
            self._buffer = np.concatenate([self._buffer, toks])
            while self._buffer.size >= need:
                chunk, self._buffer = self._buffer[:need], self._buffer[need:]
                seq = chunk.reshape(self.batch_size, self.seq_len + 1)
                self.batches_emitted += 1
                yield {"tokens": seq[:, :-1].astype(np.int32),
                       "labels": seq[:, 1:].astype(np.int32)}
