"""Staged ingestion pipeline: stream → adaptive filter → tokenize → pack.

This is where the paper's operator becomes a first-class framework feature:
the filter stage is an ``AdaptiveFilter`` (or a static one — drop-in), its
``OrderState`` is part of the pipeline checkpoint (adaptive ranks survive
restarts, per DESIGN §6), and every host/shard runs its own instance — the
paper's per-executor scope by construction.

Two deployment shapes:

  ``Pipeline``        — one stream, one filter instance (one host process =
                        one executor; run N processes for N executors).
  ``ShardedPipeline`` — one process drives a whole data mesh: S per-shard
                        ``LogStream``s feed ONE ``ShardedAdaptiveFilter``
                        step per iteration (shard_map over the mesh's data
                        axis, per-shard OrderState, scope-controlled stat
                        exchange — see ``core.sharded``).

Both emit fixed-shape LM batches {"tokens": i32[B, S], "labels": i32[B, S]}
ready for ``train_step``, checkpoint/restore bit-identically (the
fault-tolerance tests restart mid-stream and compare batch sequences), and
honour ``compact_output``: survivors then arrive as padded on-device
buffers + counts and the host never boolean-indexes a batch. With
``device_tokenize=True`` (needs ``compact_output``) the tokenize/pack stage
consumes those padded buffers ON DEVICE too (``tokenizer.tokens_from_padded``
— valid-count-masked hash + O(N) cumsum pack), so one ingestion iteration
moves exactly one dense token buffer to the host: stream → filter →
compact → tokenize is a single device-resident pass. Deferred-exchange
epoch boundaries (``AdaptiveFilterConfig.exchange``) and auto capacity
re-tuning (``compact_capacity="auto"``) are driven from here, after each
step.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Iterator, Sequence

import numpy as np

log = logging.getLogger(__name__)

from repro.core.adaptive_filter import AdaptiveFilter
from repro.core.sharded import ShardedAdaptiveFilter
from repro.data import tokenizer
from repro.data.stream import LogStream


def fstate_to_arrays(fstate) -> dict:
    """OrderState → flat dict of numpy arrays (checkpoint encoding).

    Works for single [P]-shaped states and stacked [S, P] sharded states —
    leaves are stored verbatim, stats fields under a ``stats.`` prefix.
    """
    return {k: np.asarray(v) for k, v in fstate._asdict().items()
            if k != "stats"} \
        | {f"stats.{k}": np.asarray(v) for k, v in
           fstate.stats._asdict().items()}


def fstate_from_arrays(fs: dict):
    """Inverse of ``fstate_to_arrays`` (jnp leaves).

    Pre-CNF checkpoints lack the group fields; for flat chains group_cut ≡
    num_cut accumulators start at zero and group_perm is the identity, so
    the defaults restore them losslessly (shape-generic: the identity is
    broadcast over any leading shard axis).
    """
    import jax.numpy as jnp

    from repro.core.ordering import OrderState
    from repro.core.stats import FilterStats

    adj = np.asarray(fs["adj_rank"])
    n_groups = int(adj.shape[-1])
    stats = FilterStats(jnp.asarray(fs["stats.num_cut"]),
                        jnp.asarray(fs["stats.cost_acc"]),
                        jnp.asarray(fs["stats.n_monitored"]),
                        jnp.asarray(fs.get("stats.group_cut",
                                           fs["stats.num_cut"])))
    default_gperm = np.broadcast_to(
        np.arange(n_groups, dtype=np.int32), adj.shape)
    return OrderState(
        perm=jnp.asarray(fs["perm"]), adj_rank=jnp.asarray(fs["adj_rank"]),
        stats=stats, rows_into_epoch=jnp.asarray(fs["rows_into_epoch"]),
        sample_phase=jnp.asarray(fs["sample_phase"]),
        epoch=jnp.asarray(fs["epoch"]),
        group_perm=jnp.asarray(fs.get("group_perm", default_gperm)))


@dataclasses.dataclass
class PipelineState:
    stream_cursor: int
    filter_state: dict          # OrderState as numpy arrays
    buffer: np.ndarray          # leftover tokens not yet emitted
    batches_emitted: int
    rows_in: int
    rows_pass: int


class _LMBatchEmitter:
    """Shared tokenize-buffer-emit tail of both pipelines.

    Expects ``batch_size``, ``seq_len``, ``vocab_size``, ``tokens_per_row``,
    ``_buffer``, and ``batches_emitted`` on self.
    """

    def _emit_tokens(self, toks: np.ndarray) -> Iterator[dict]:
        self._buffer = np.concatenate([self._buffer, toks])
        need = self.batch_size * (self.seq_len + 1)
        while self._buffer.size >= need:
            chunk, self._buffer = self._buffer[:need], self._buffer[need:]
            seq = chunk.reshape(self.batch_size, self.seq_len + 1)
            self.batches_emitted += 1
            yield {"tokens": seq[:, :-1].astype(np.int32),
                   "labels": seq[:, 1:].astype(np.int32)}

    def _emit(self, survivors: np.ndarray) -> Iterator[dict]:
        yield from self._emit_tokens(tokenizer.rows_to_tokens(
            survivors, self.vocab_size, self.tokens_per_row))

    def _warn_dropped(self, n_dropped: int) -> None:
        if n_dropped:
            log.warning(
                "compaction overflow: %d survivors dropped this step "
                "(compact_capacity too small — raise it or use 'auto')",
                n_dropped)


class Pipeline(_LMBatchEmitter):
    def __init__(self, stream: LogStream, filt: AdaptiveFilter,
                 batch_size: int, seq_len: int, vocab_size: int,
                 tokens_per_row: int = 8, device_tokenize: bool = False):
        self.stream = stream
        self.filt = filt
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.tokens_per_row = tokens_per_row
        self._compact = filt.config.compact_output
        if device_tokenize and not self._compact:
            raise ValueError("device_tokenize consumes the padded compacted "
                             "buffers — it needs compact_output=True")
        self._device_tokenize = device_tokenize
        self._jit_step = filt.jit_step_compact if self._compact \
            else filt.jit_step               # compiled once per filter
        self._fstate = filt.init_state()
        self._buffer = np.zeros((0,), np.int32)
        self.batches_emitted = 0
        self.rows_in = 0
        self.rows_pass = 0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------- checkpoint
    def state(self) -> PipelineState:
        return PipelineState(
            stream_cursor=self.stream.cursor,
            filter_state=fstate_to_arrays(self._fstate),
            buffer=self._buffer.copy(),
            batches_emitted=self.batches_emitted,
            rows_in=self.rows_in,
            rows_pass=self.rows_pass,
        )

    def restore(self, st: PipelineState) -> None:
        self.stream.cursor = st.stream_cursor
        self._fstate = fstate_from_arrays(st.filter_state)
        self._buffer = st.buffer.copy()
        self.batches_emitted = st.batches_emitted
        self.rows_in = st.rows_in
        self.rows_pass = st.rows_pass

    # -------------------------------------------------------------- iteration
    def _filter_batch(self, columns: np.ndarray):
        """Run one jitted filter step; returns (survivors | device tokens,
        n_pass).

        ``n_pass`` counts the survivors actually KEPT (and tokenized): under
        a saturating ``compact_capacity`` that is ``n_kept``, not the mask
        popcount — ``rows_pass`` must agree with the emitted token stream.
        With ``device_tokenize`` the first element is the packed token
        stream instead of survivor columns (the batch never comes back to
        the host as rows at all).
        """
        import jax.numpy as jnp

        cols = jnp.asarray(columns, jnp.float32)
        n_rows = int(cols.shape[1])
        prev = self._fstate
        if self._compact:
            cap = self.filt.resolve_capacity(n_rows)
            self._fstate, packed, n_kept, _, metrics = self._jit_step(
                self._fstate, cols, capacity=cap)
            if self._device_tokenize:
                toks, n_tok = tokenizer.tokens_from_padded(
                    packed, n_kept, self.vocab_size, self.tokens_per_row)
                payload = np.asarray(toks)[:int(n_tok)]
            else:
                payload = np.asarray(packed)[:, :int(n_kept)]
            n_pass = int(n_kept)
        else:
            self._fstate, mask, metrics = self._jit_step(self._fstate, cols)
            mask_np = np.asarray(mask)
            payload = columns[:, mask_np]
            n_pass = int(mask_np.sum())
        self._fstate = self.filt.maybe_exchange(self._fstate)
        self.filt.observe_for_capacity(prev, self._fstate, n_rows)
        n_dropped = int(np.asarray(metrics.n_dropped))
        self._warn_dropped(n_dropped)
        self.last_metrics = {
            "work_units": float(metrics.work_units),
            "perm": np.asarray(metrics.perm).tolist(),
            "epoch": int(np.max(np.asarray(self._fstate.epoch))),
            "n_dropped": n_dropped,
        }
        return payload, n_pass

    def __iter__(self) -> Iterator[dict]:
        for rb in self.stream:
            payload, n_pass = self._filter_batch(rb.columns)
            self.rows_in += rb.n_rows
            self.rows_pass += n_pass
            if self._device_tokenize:
                yield from self._emit_tokens(payload)
            else:
                yield from self._emit(payload)


# =============================================================== sharded
@dataclasses.dataclass
class ShardedPipelineState:
    stream_cursors: list        # one LogStream cursor per shard
    filter_state: dict          # stacked OrderState ([S, ...] leaves)
    buffer: np.ndarray
    batches_emitted: int
    rows_in: int
    rows_pass: int


class ShardedPipeline(_LMBatchEmitter):
    """Multi-shard ingestion: S per-shard streams → one shard_map step.

    ``streams[i]`` must be the i-th round-robin partition of one logical
    stream (``LogStream(shard_id=i, num_shards=S)``) — like Spark partitions
    spread over executors. Each iteration pulls one batch per shard,
    block-concatenates them into the [C, S·R] layout ``ShardedAdaptiveFilter``
    expects (shard i owns rows [i·R, (i+1)·R)), runs ONE jitted sharded
    step, and packs survivors shard-major into LM batches. The stacked
    per-shard ``OrderState`` checkpoints/restores as a whole, so every
    shard's adaptive ranks survive a restart.
    """

    def __init__(self, streams: Sequence[LogStream],
                 filt: ShardedAdaptiveFilter, batch_size: int, seq_len: int,
                 vocab_size: int, tokens_per_row: int = 8,
                 device_tokenize: bool = False):
        if len(streams) != filt.num_shards:
            raise ValueError(
                f"{len(streams)} streams for {filt.num_shards} shards")
        self.streams = list(streams)
        self.filt = filt
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.tokens_per_row = tokens_per_row
        self._compact = filt.config.compact_output
        if device_tokenize and not self._compact:
            raise ValueError("device_tokenize consumes the padded compacted "
                             "buffers — it needs compact_output=True")
        self._device_tokenize = device_tokenize
        self._jit_step = filt.jit_step_compact if self._compact \
            else filt.jit_step
        self._fstate = filt.init_state()
        self._buffer = np.zeros((0,), np.int32)
        self.batches_emitted = 0
        self.rows_in = 0
        self.rows_pass = 0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------- checkpoint
    def state(self) -> ShardedPipelineState:
        return ShardedPipelineState(
            stream_cursors=[s.cursor for s in self.streams],
            filter_state=fstate_to_arrays(self._fstate),
            buffer=self._buffer.copy(),
            batches_emitted=self.batches_emitted,
            rows_in=self.rows_in,
            rows_pass=self.rows_pass,
        )

    def restore(self, st: ShardedPipelineState) -> None:
        if len(st.stream_cursors) != len(self.streams):
            raise ValueError(
                f"checkpoint has {len(st.stream_cursors)} shard cursors, "
                f"pipeline has {len(self.streams)} shards — elastic "
                "OrderState reshard is not supported yet (see ROADMAP)")
        for stream, cur in zip(self.streams, st.stream_cursors):
            stream.cursor = int(cur)
        self._fstate = fstate_from_arrays(st.filter_state)
        self._buffer = st.buffer.copy()
        self.batches_emitted = st.batches_emitted
        self.rows_in = st.rows_in
        self.rows_pass = st.rows_pass

    # -------------------------------------------------------------- iteration
    def _filter_block(self, columns: np.ndarray):
        """One sharded step over the [C, S·R] block.

        Returns (survivors shard-major | packed device tokens, n_pass).
        With ``device_tokenize`` the whole filter→compact→tokenize→pack
        chain runs in two jitted calls on the mesh and only the dense token
        stream crosses to the host.
        """
        import jax.numpy as jnp

        n_shards = self.filt.num_shards
        cols = jnp.asarray(columns, jnp.float32)
        n_local = int(cols.shape[1]) // n_shards
        prev = self._fstate
        if self._compact:
            cap = self.filt.resolve_capacity(n_local)
            self._fstate, packed, n_kept, mask, metrics = self._jit_step(
                self._fstate, cols, capacity=cap)
            counts = np.asarray(n_kept)
            if self._device_tokenize:
                toks, n_tok = tokenizer.tokens_from_padded(
                    packed, n_kept, self.vocab_size, self.tokens_per_row)
                payload = np.asarray(toks)[:int(n_tok)]
            else:
                packed_np = np.asarray(packed)
                payload = np.concatenate(
                    [packed_np[s][:, :int(counts[s])]
                     for s in range(n_shards)], axis=1)
            n_pass = int(counts.sum())
        else:
            self._fstate, mask, metrics = self._jit_step(self._fstate, cols)
            mask_np = np.asarray(mask)
            payload = columns[:, mask_np]
            n_pass = int(mask_np.sum())
        self._fstate = self.filt.maybe_exchange(self._fstate)
        self.filt.observe_for_capacity(prev, self._fstate, n_local)
        n_dropped = int(np.asarray(metrics.n_dropped).sum())
        self._warn_dropped(n_dropped)
        self.last_metrics = {
            "work_units": float(np.asarray(metrics.work_units).sum()),
            "perm": np.asarray(metrics.perm).tolist(),   # [S, P]
            "epoch": int(np.asarray(self._fstate.epoch).max()),
            "n_dropped": n_dropped,
        }
        return payload, n_pass

    def __iter__(self) -> Iterator[dict]:
        iters = [iter(s) for s in self.streams]
        while True:
            rbs = []
            for it in iters:
                rb = next(it, None)
                if rb is None:          # a shard ran dry → stream over
                    return
                rbs.append(rb)
            cols = np.concatenate([rb.columns for rb in rbs], axis=1)
            payload, n_pass = self._filter_block(cols)
            self.rows_in += cols.shape[1]
            self.rows_pass += n_pass
            if self._device_tokenize:
                yield from self._emit_tokens(payload)
            else:
                yield from self._emit(payload)


def make_sharded_pipeline(filt: ShardedAdaptiveFilter, *, total_rows: int,
                          batch_rows: int, batch_size: int, seq_len: int,
                          vocab_size: int, seed: int = 0, drift=None,
                          tokens_per_row: int = 8,
                          device_tokenize: bool = False) -> ShardedPipeline:
    """S round-robin partitions of one logical stream → ShardedPipeline."""
    from repro.data.stream import DriftConfig

    drift = drift or DriftConfig()
    streams = [LogStream(total_rows=total_rows, batch_rows=batch_rows,
                         seed=seed, drift=drift, shard_id=i,
                         num_shards=filt.num_shards)
               for i in range(filt.num_shards)]
    return ShardedPipeline(streams, filt, batch_size=batch_size,
                           seq_len=seq_len, vocab_size=vocab_size,
                           tokens_per_row=tokens_per_row,
                           device_tokenize=device_tokenize)
