"""Synthetic structured-log stream with evolving statistics.

Reproduces the paper's experimental dataset — 3 attributes (date, integer,
string), all value distributions normal — plus the property the paper's
technique exists for: *drift*. Batches are generated counter-based (each
batch from its own seeded Generator keyed by (seed, batch_index)), so the
stream is O(1)-restartable from any row offset: the ingredient checkpoint /
elastic-rescale needs.

Columns:
  0 date     ~ N(500, 100)   (days since epoch)
  1 int      ~ N(50, 15)     (e.g. cpuUsage)
  2 str_hash ~ U[0, 2^20)    (hash of a categorical string attribute)

Drift kinds:
  none    — stationary (paper's Fig. 1 setting)
  sine    — column means glide sinusoidally over rows (smooth drift)
  regime  — parameters switch between two regimes every ``period_rows``
            (abrupt drift; the case momentum is designed to survive)

Layouts (``layout=``) — the *physical row order within a batch*, the knob
the tile-statistics skip tier (``core.skip_tier``) lives or dies by. Row
SETS are identical across layouts (a pure permutation), so selectivities,
adopted orders, and survivors-as-a-set are layout-invariant; only the
per-128-row-tile value locality changes:

  iid       — generator order (exchangeable draws; no locality). Default,
              bit-identical to the pre-layout stream.
  clustered — rows sorted by (int, date): the sorted-ingest case — most
              tiles become provably pass/fail under zone maps.
  zordered  — Morton (Z-order) interleave of the date/int rank spaces:
              multi-column locality, the database clustering middle ground.
  shuffled  — explicit random permutation (adversarial for zone maps;
              tiles stay ambiguous and the skip tier should disable).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

BASE_DISTRIBUTIONS = {
    "date": (500.0, 100.0),
    "int": (50.0, 15.0),
}
STR_MOD = 1048576.0  # 2**20, matches predicates.MIX_MOD


def norm_ppf(q: float) -> float:
    """Inverse normal CDF (Acklam's rational approximation, |err| < 1.2e-9)."""
    if not 0.0 < q < 1.0:
        raise ValueError("quantile must be in (0,1)")
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        ql = math.sqrt(-2 * math.log(q))
        return (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if q > phigh:
        ql = math.sqrt(-2 * math.log(1 - q))
        return -(((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    ql = q - 0.5
    r = ql * ql
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * ql / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def threshold_for_quantile(attr: str, q: float) -> float:
    """Threshold t with P(X < t) = q under the BASE (no-drift) distribution."""
    mean, std = BASE_DISTRIBUTIONS[attr]
    return mean + std * norm_ppf(q)


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    kind: str = "none"            # none | sine | regime
    period_rows: int = 2_000_000  # full drift cycle / regime length
    amplitude: float = 1.5        # mean shift in units of base std

    def __post_init__(self) -> None:
        if self.kind not in ("none", "sine", "regime"):
            raise ValueError(f"unknown drift kind {self.kind}")


def _drift_shift(drift: DriftConfig, row_mid: float) -> tuple[float, float, float]:
    """Per-column mean shifts (date_shift_std, int_shift_std, str_offset_frac)."""
    if drift.kind == "none":
        return 0.0, 0.0, 0.0
    phase = row_mid / drift.period_rows
    if drift.kind == "sine":
        s = math.sin(2 * math.pi * phase)
        # columns drift out of phase so the *optimal order* changes, not
        # just the absolute selectivities
        return (drift.amplitude * s,
                -drift.amplitude * math.sin(2 * math.pi * phase + 2.0),
                0.25 * math.sin(2 * math.pi * phase + 4.0))
    # regime: square wave
    regime = int(phase) % 2
    sign = 1.0 if regime == 0 else -1.0
    return (drift.amplitude * sign, -drift.amplitude * sign, 0.2 * sign)


LAYOUTS = ("iid", "clustered", "zordered", "shuffled")


def _part1by1(v: np.ndarray) -> np.ndarray:
    """Spread the low 16 bits of ``v`` over even bit positions (u32)."""
    v = v.astype(np.uint32) & np.uint32(0x0000FFFF)
    v = (v | (v << 8)) & np.uint32(0x00FF00FF)
    v = (v | (v << 4)) & np.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & np.uint32(0x33333333)
    v = (v | (v << 1)) & np.uint32(0x55555555)
    return v


def _layout_order(cols: np.ndarray, layout: str, rng) -> np.ndarray | None:
    """Row permutation realizing ``layout`` (None → keep generator order)."""
    if layout == "iid":
        return None
    if layout == "shuffled":
        return rng.permutation(cols.shape[1])
    if layout == "clustered":
        # primary sort on the int column, date breaks ties — the sorted
        # ingest a warehouse's clustered index produces
        return np.lexsort((cols[0], cols[1]))
    if layout == "zordered":
        # Morton interleave of the 16-bit quantized date/int RANK spaces
        # (ranks, not raw values: Z-order locality should not depend on
        # the columns' absolute scales)
        n = cols.shape[1]
        q = np.empty((2, n), np.uint32)
        for i in (0, 1):
            q[i] = (np.argsort(np.argsort(cols[i], kind="stable"),
                               kind="stable").astype(np.uint64)
                    * 65535 // max(n - 1, 1)).astype(np.uint32)
        morton = _part1by1(q[0]) | (_part1by1(q[1]) << np.uint32(1))
        return np.argsort(morton, kind="stable")
    raise ValueError(f"unknown layout {layout!r}; pick from {LAYOUTS}")


def gen_batch(seed: int, batch_index: int, row_start: int, n_rows: int,
              drift: DriftConfig = DriftConfig(),
              layout: str = "iid") -> np.ndarray:
    """Generate rows [row_start, row_start+n_rows) as f32[3, n_rows].

    Counter-based: depends only on (seed, batch_index, drift, layout),
    never on generator history → restartable and shardable. ``layout``
    permutes rows *within the batch* (see the module docstring) — the row
    set is identical across layouts.
    """
    rng = np.random.Generator(np.random.Philox(key=[seed, batch_index]))
    d_shift, i_shift, s_shift = _drift_shift(drift, row_start + n_rows / 2)

    dmean, dstd = BASE_DISTRIBUTIONS["date"]
    imean, istd = BASE_DISTRIBUTIONS["int"]
    date = rng.normal(dmean + d_shift * dstd, dstd, n_rows)
    intc = rng.normal(imean + i_shift * istd, istd, n_rows)
    strh = (rng.integers(0, int(STR_MOD), n_rows).astype(np.float64)
            + s_shift * STR_MOD) % STR_MOD
    cols = np.stack([date, intc, strh]).astype(np.float32)
    order = _layout_order(cols, layout, rng)
    return cols if order is None else cols[:, order]


class RequestStream:
    """Any counter-based per-batch generator as a restartable stream.

    Adapts ``gen(batch_index, row_start, n_rows) -> f32[C, n_rows]`` to
    the ``LogStream`` contract (``cursor`` / ``state`` / ``restore`` /
    ``batch_rows`` / iteration yielding ``RecordBatch``), so the serving
    ingest thread, ``GuardedSession.run_log_stream``'s rollback cursor
    replay, and the synchronous admission-parity reference all drive
    synthetic request traffic exactly like log batches. ``gen`` MUST be
    pure in its arguments (counter-based, like ``gen_batch`` above) —
    replay and the parity reference regenerate batches by index.
    """

    def __init__(self, gen, total_rows: int, batch_rows: int = 256,
                 start_batch: int = 0, names: tuple = ()):
        if total_rows % batch_rows:
            total_rows = (total_rows // batch_rows) * batch_rows
        if total_rows <= 0:
            raise ValueError("total_rows must cover at least one batch")
        self.gen = gen
        self.total_rows = total_rows
        self.batch_rows = batch_rows
        self.names = names
        self.cursor = start_batch  # global batch index; checkpointable

    @property
    def n_batches(self) -> int:
        return self.total_rows // self.batch_rows

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict) -> None:
        self.cursor = int(state["cursor"])

    def __iter__(self):
        from repro.data.schema import RecordBatch

        while self.cursor < self.n_batches:
            b = self.cursor
            self.cursor += 1   # read live by rollback replay — rewind-safe
            cols = self.gen(b, b * self.batch_rows, self.batch_rows)
            rb = RecordBatch(np.asarray(cols, np.float32),
                             row_offset=b * self.batch_rows)
            if self.names:
                rb.names = self.names
            yield rb


class LogStream:
    """Restartable, shardable iterator of RecordBatches.

    Sharding: batch b goes to shard (b % num_shards) — round-robin keeps
    per-shard drift exposure aligned with wall-clock, like Spark partitions
    spread over executors.
    """

    def __init__(self, total_rows: int, batch_rows: int = 65536, seed: int = 0,
                 drift: DriftConfig = DriftConfig(), shard_id: int = 0,
                 num_shards: int = 1, start_batch: int = 0,
                 layout: str = "iid"):
        if total_rows % batch_rows:
            total_rows = (total_rows // batch_rows) * batch_rows
        if layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r}; pick from {LAYOUTS}")
        self.total_rows = total_rows
        self.batch_rows = batch_rows
        self.seed = seed
        self.drift = drift
        self.layout = layout
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.cursor = start_batch  # global batch index; checkpointable

    @property
    def n_batches(self) -> int:
        return self.total_rows // self.batch_rows

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict) -> None:
        self.cursor = int(state["cursor"])

    def __iter__(self):
        from repro.data.schema import RecordBatch

        while self.cursor < self.n_batches:
            b = self.cursor
            self.cursor += 1
            if b % self.num_shards != self.shard_id:
                continue
            cols = gen_batch(self.seed, b, b * self.batch_rows,
                             self.batch_rows, self.drift, self.layout)
            yield RecordBatch(cols, row_offset=b * self.batch_rows)
