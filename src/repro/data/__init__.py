"""Data substrate: columnar record batches, synthetic drifting log stream
(the paper's 75M-row date/int/string dataset, streaming + restartable),
tokenizer stub, and the staged ingestion pipeline that feeds train_step."""

from repro.data.schema import RecordBatch
from repro.data.stream import (BASE_DISTRIBUTIONS, DriftConfig, LogStream,
                               gen_batch, norm_ppf, threshold_for_quantile)
from repro.data.pipeline import Pipeline, PipelineState

__all__ = [
    "RecordBatch", "BASE_DISTRIBUTIONS", "DriftConfig", "LogStream",
    "gen_batch", "norm_ppf", "threshold_for_quantile", "Pipeline",
    "PipelineState",
]
