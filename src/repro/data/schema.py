"""Columnar record batches — the unit the filter operator consumes.

The paper's dataset has 3 attributes (date, integer, string); we carry any
number of columns as a dense float32 matrix [C, R] (column-major access is
what both the vectorized chain and the Pallas kernel want). String columns
are pre-hashed into [0, 2^20) by the generator (exact in f32).
"""

from __future__ import annotations

import dataclasses

import numpy as np

COL_DATE = 0
COL_INT = 1
COL_STR = 2
DEFAULT_COLUMNS = ("date", "int", "str_hash")


@dataclasses.dataclass
class RecordBatch:
    """One tile of the stream. ``row_offset`` is the global index of row 0 —
    it drives the deterministic-stride monitor sampling and makes the stream
    restartable from a checkpoint."""

    columns: np.ndarray                 # f32[C, R]
    row_offset: int
    names: tuple = DEFAULT_COLUMNS

    @property
    def n_rows(self) -> int:
        return int(self.columns.shape[1])

    @property
    def n_cols(self) -> int:
        return int(self.columns.shape[0])

    def select(self, mask: np.ndarray) -> np.ndarray:
        return self.columns[:, mask]
