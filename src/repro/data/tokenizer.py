"""Hash tokenizer stub: surviving log rows → LM token streams.

A real deployment would tokenize the log's text payload; the assigned-arch
contract allows modality frontends to be stubs. This one is deterministic
and cheap: each surviving row is mixed into ``tokens_per_row`` int tokens via
a splitmix-style integer hash of its column values, so the LM examples are
(a) a pure function of the filtered stream and (b) reproducible across
restarts — which the fault-tolerance tests rely on.
"""

from __future__ import annotations

import numpy as np

_GAMMA = np.uint64(0x9E3779B97F4A7C15)


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + _GAMMA).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x = (x * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(27)
    x = (x * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(31)
    return x


def rows_to_tokens(columns: np.ndarray, vocab_size: int,
                   tokens_per_row: int = 8) -> np.ndarray:
    """f32[C, R] → i32[R * tokens_per_row] token ids in [0, vocab_size)."""
    if columns.shape[1] == 0:
        return np.zeros((0,), np.int32)
    base = np.zeros(columns.shape[1], np.uint64)
    for c in range(columns.shape[0]):
        base = _splitmix(base ^ columns[c].astype(np.float64).view(np.uint64))
    toks = []
    h = base
    for _ in range(tokens_per_row):
        h = _splitmix(h)
        toks.append((h % np.uint64(vocab_size)).astype(np.int32))
    return np.stack(toks, axis=1).reshape(-1)
