"""Hash tokenizer stub: surviving log rows → LM token streams.

A real deployment would tokenize the log's text payload; the assigned-arch
contract allows modality frontends to be stubs. This one is deterministic
and cheap: each surviving row is mixed into ``tokens_per_row`` int tokens via
a splitmix-style integer hash of its column values, so the LM examples are
(a) a pure function of the filtered stream and (b) reproducible across
restarts — which the fault-tolerance tests rely on.

Two implementations of the SAME hash:

  ``rows_to_tokens``        — numpy, host path (dense survivor rows in).
  ``tokens_from_padded``    — jitted jax path over the padded ``[S, C, cap]``
                              survivor buffers + counts that device-side
                              compaction emits, so the tokenize/pack stage
                              runs on the mesh and the batch columns never
                              round-trip through a host boolean index (the
                              "compaction-aware downstream stage" of the
                              single-pass ingestion path). Valid rows are
                              selected by count masking and the tokens are
                              packed shard-major with the same O(N) cumsum
                              scatter the compactor uses — bit-identical to
                              the host stream (pinned by tests).

The jax path is traced under ``jax.experimental.enable_x64`` because the
hash is defined on the u64 bit pattern of the f64-widened column values
(the numpy path's ``astype(float64).view(uint64)``). That makes it a CPU /
GPU device stage today; a TPU deployment would split the mix into u32
limbs — the call-site contract (padded buffers + counts in, packed token
ids + total out) would not change.
"""

from __future__ import annotations

import functools

import numpy as np

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + _GAMMA).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x = (x * _MIX1) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(27)
    x = (x * _MIX2) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(31)
    return x


def rows_to_tokens(columns: np.ndarray, vocab_size: int,
                   tokens_per_row: int = 8) -> np.ndarray:
    """f32[C, R] → i32[R * tokens_per_row] token ids in [0, vocab_size)."""
    if columns.shape[1] == 0:
        return np.zeros((0,), np.int32)
    base = np.zeros(columns.shape[1], np.uint64)
    for c in range(columns.shape[0]):
        base = _splitmix(base ^ columns[c].astype(np.float64).view(np.uint64))
    toks = []
    h = base
    for _ in range(tokens_per_row):
        h = _splitmix(h)
        toks.append((h % np.uint64(vocab_size)).astype(np.int32))
    return np.stack(toks, axis=1).reshape(-1)


# ============================================================== device path
@functools.cache
def _jit_tokens_from_padded():
    """Build (lazily, once) the jitted device tokenizer.

    Deferred import + trace so plain host users never pay for it, and the
    uint64 lowering is set up exactly once under ``enable_x64``.
    """
    import jax
    import jax.numpy as jnp

    def _splitmix_dev(x):
        x = x + jnp.uint64(0x9E3779B97F4A7C15)
        x ^= x >> jnp.uint64(30)
        x = x * jnp.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> jnp.uint64(27)
        x = x * jnp.uint64(0x94D049BB133111EB)
        x ^= x >> jnp.uint64(31)
        return x

    @functools.partial(jax.jit,
                       static_argnames=("vocab_size", "tokens_per_row"))
    def tok(packed, counts, *, vocab_size: int, tokens_per_row: int):
        s, c, cap = packed.shape
        # hash every slot (padding rows hash to garbage and are masked out —
        # branch-free, the device way)
        base = jnp.zeros((s, cap), jnp.uint64)
        for ci in range(c):
            bits = jax.lax.bitcast_convert_type(
                packed[:, ci, :].astype(jnp.float64), jnp.uint64)
            base = _splitmix_dev(base ^ bits)
        toks = []
        h = base
        for _ in range(tokens_per_row):
            h = _splitmix_dev(h)
            toks.append((h % jnp.uint64(vocab_size)).astype(jnp.int32))
        tokens = jnp.stack(toks, axis=-1)            # i32[S, cap, T]
        # valid-count masking + shard-major O(N) pack (same cumsum scatter
        # as the survivor compactor — no sort anywhere in the pipeline)
        valid = (jnp.arange(cap)[None, :] < counts[:, None])   # bool[S, cap]
        flat_valid = jnp.repeat(valid.reshape(-1), tokens_per_row)
        flat = tokens.reshape(-1)
        n = flat.shape[0]
        pos = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
        dest = jnp.where(flat_valid, pos, n)
        out = jnp.zeros((n + 1,), jnp.int32).at[dest].set(flat, mode="drop")
        total = jnp.sum(counts).astype(jnp.int32) * tokens_per_row
        return out[:n], total

    return tok


def tokens_from_padded(packed, counts, vocab_size: int,
                       tokens_per_row: int = 8):
    """Device tokenize+pack over padded survivor buffers.

    ``packed``: f32[S, C, cap] (or [C, cap] for a single pipeline — auto-
    promoted), ``counts``: i32[S] valid widths. Returns (tokens i32[S·cap·T]
    with the first ``n_valid`` entries live, n_valid i32[]) — the first
    ``n_valid`` tokens are bit-identical to ``rows_to_tokens`` applied to
    the shard-major concatenation of the valid survivor slices.
    """
    import jax
    import jax.numpy as jnp

    if packed.ndim == 2:
        packed = packed[None]
        counts = jnp.asarray(counts, jnp.int32).reshape((1,))
    with jax.experimental.enable_x64():
        return _jit_tokens_from_padded()(
            packed, counts, vocab_size=vocab_size,
            tokens_per_row=tokens_per_row)
