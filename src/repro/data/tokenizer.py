"""Hash tokenizer stub: surviving log rows → LM token streams.

A real deployment would tokenize the log's text payload; the assigned-arch
contract allows modality frontends to be stubs. This one is deterministic
and cheap: each surviving row is mixed into ``tokens_per_row`` int tokens via
a splitmix-style integer hash of its column values, so the LM examples are
(a) a pure function of the filtered stream and (b) reproducible across
restarts — which the fault-tolerance tests rely on.

Two implementations of the SAME hash:

  ``rows_to_tokens``        — numpy, host path (dense survivor rows in).
  ``tokens_from_padded``    — jitted jax path over the padded ``[S, C, cap]``
                              survivor buffers + counts that device-side
                              compaction emits, so the tokenize/pack stage
                              runs on the mesh and the batch columns never
                              round-trip through a host boolean index (the
                              "compaction-aware downstream stage" of the
                              single-pass ingestion path). Valid rows are
                              selected by count masking and the tokens are
                              packed shard-major with the same O(N) cumsum
                              scatter the compactor uses — bit-identical to
                              the host stream (pinned by tests).

The hash is defined on the u64 bit pattern of the f64-widened column values
(the numpy path's ``astype(float64).view(uint64)``). The jax path computes
the SAME u64 arithmetic in **u32 limb pairs** — widening f32 bit patterns
to f64 bit patterns by integer exponent/mantissa surgery, 64-bit
add/xor/shift/multiply via (hi, lo) u32 carries, and the final
``% vocab_size`` as a base-256 byte fold (exact for vocab < 2**24) — so it
traces WITHOUT ``jax.experimental.enable_x64`` and lowers on TPU, where
u64 is unsupported. Bit-exactness against the u64 host path is pinned by
``tests/test_tokenizer_u32.py``.
"""

from __future__ import annotations

import functools

import numpy as np

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _max_device_vocab() -> int:
    """Byte-fold modulo ceiling — single-sourced in ``core.plan``
    (imported lazily: this module stays a numpy-only leaf)."""
    from repro.core.plan import MAX_DEVICE_VOCAB
    return MAX_DEVICE_VOCAB


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + _GAMMA).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x = (x * _MIX1) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(27)
    x = (x * _MIX2) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(31)
    return x


def rows_to_tokens(columns: np.ndarray, vocab_size: int,
                   tokens_per_row: int = 8) -> np.ndarray:
    """f32[C, R] → i32[R * tokens_per_row] token ids in [0, vocab_size)."""
    if columns.shape[1] == 0:
        return np.zeros((0,), np.int32)
    base = np.zeros(columns.shape[1], np.uint64)
    for c in range(columns.shape[0]):
        base = _splitmix(base ^ columns[c].astype(np.float64).view(np.uint64))
    toks = []
    h = base
    for _ in range(tokens_per_row):
        h = _splitmix(h)
        toks.append((h % np.uint64(vocab_size)).astype(np.int32))
    return np.stack(toks, axis=1).reshape(-1)


# ====================================================== u32-limb device path
@functools.cache
def _limb_ops():
    """u64 arithmetic as (hi, lo) u32 limb pairs — TPU-lowerable primitives.

    Everything here is exact mod-2^64 integer math: the splitmix constants
    are split into static u32 halves, 64-bit multiply goes through the
    classic 16-bit-limb mulhi decomposition (all intermediates < 2^32), and
    f32→f64 widening is IEEE bit surgery (sign/exponent/mantissa re-bias,
    including subnormal renormalization via count-leading-zeros) so no f64
    value ever exists in the traced program.
    """
    import jax.numpy as jnp
    from jax import lax

    # numpy scalars, NOT jnp: the closure is functools.cache'd, and a
    # jnp constant materialized while some outer trace is live would be a
    # tracer baked into the cache — poisoning every later call
    # (UnexpectedTracerError). numpy scalars are concrete in every
    # context and inline into traces as literals.
    u32 = np.uint32
    M16 = u32(0xFFFF)

    def mul32_wide(a, b):
        """u32 × u32 → (hi, lo) full 64-bit product, via 16-bit limbs."""
        a0, a1 = a & M16, a >> u32(16)
        b0, b1 = b & M16, b >> u32(16)
        t = a0 * b0
        w0 = t & M16
        t = a1 * b0 + (t >> u32(16))
        w2 = t >> u32(16)
        t = a0 * b1 + (t & M16)
        hi = a1 * b1 + w2 + (t >> u32(16))
        lo = (t << u32(16)) | w0
        return hi, lo

    def add64(h, l, ch: int, cl: int):
        """(h,l) + static u64 constant (given as two python ints)."""
        lo = l + u32(cl)
        carry = (lo < l).astype(jnp.uint32)
        return h + u32(ch) + carry, lo

    def shr64(h, l, k: int):
        """logical right shift by static 0 < k < 32."""
        return h >> u32(k), (l >> u32(k)) | (h << u32(32 - k))

    def mul64(h, l, ch: int, cl: int):
        """(h,l) · static u64 constant, low 64 bits."""
        ph, pl = mul32_wide(l, u32(cl))
        ph = ph + l * u32(ch) + h * u32(cl)
        return ph, pl

    def splitmix64(h, l):
        h, l = add64(h, l, 0x9E3779B9, 0x7F4A7C15)
        sh, sl = shr64(h, l, 30)
        h, l = h ^ sh, l ^ sl
        h, l = mul64(h, l, 0xBF58476D, 0x1CE4E5B9)
        sh, sl = shr64(h, l, 27)
        h, l = h ^ sh, l ^ sl
        h, l = mul64(h, l, 0x94D049BB, 0x133111EB)
        sh, sl = shr64(h, l, 31)
        return h ^ sh, l ^ sl

    def f64_bits_of_f32(x):
        """f32[...] → (hi, lo) u32 IEEE-754 bit pattern of float64(x).

        f32→f64 widening is exact, so the f64 bits are a pure function of
        the f32 bits: re-bias the exponent (+896), shift the mantissa up 29
        bits, and renormalize subnormals (value m·2^-149 becomes a normal
        f64 with exponent p+874 where p = floor(log2 m)). Zeros keep their
        sign; inf/NaN map to exponent 2047 with the payload widened the
        same way (preserving the quiet bit).
        """
        bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        s_hi = (bits >> u32(31)) << u32(31)
        e = (bits >> u32(23)) & u32(0xFF)
        m = bits & u32(0x7FFFFF)
        hi_wide = s_hi | (m >> u32(3))           # widened mantissa, hi part
        lo_wide = (m & u32(0x7)) << u32(29)      # widened mantissa, lo part
        hi_norm = hi_wide | ((e + u32(896)) << u32(20))
        # NaNs are QUIETED like hardware cvtss2sd does (f64 quiet bit =
        # mantissa bit 51 = hi bit 19); inf (m == 0) is left alone
        quiet = jnp.where((e == u32(255)) & (m != u32(0)),
                          u32(1) << u32(19), u32(0))
        hi_inf = hi_wide | (u32(0x7FF) << u32(20)) | quiet
        # subnormal: renormalize. p = floor(log2 m) in [0, 22]; the f64
        # mantissa is (m - 2^p) << (52 - p), split across the limbs.
        m_safe = jnp.maximum(m, u32(1))          # keep the dead lane defined
        p = u32(31) - lax.clz(m_safe)
        frac = m_safe ^ (u32(1) << p)
        hi_mant = jnp.where(p <= u32(20),
                            frac << jnp.where(p <= u32(20), u32(20) - p,
                                              u32(0)),
                            frac >> jnp.where(p > u32(20), p - u32(20),
                                              u32(0)))
        lo_sub = jnp.where(p >= u32(21),
                           frac << jnp.where(p >= u32(21), u32(52) - p,
                                             u32(0)),
                           u32(0))
        hi_sub = s_hi | ((p + u32(874)) << u32(20)) | (hi_mant & u32(0xFFFFF))
        is_zero = (e == u32(0)) & (m == u32(0))
        is_sub = (e == u32(0)) & (m != u32(0))
        is_inf = e == u32(255)
        hi = jnp.where(is_zero, s_hi,
                       jnp.where(is_sub, hi_sub,
                                 jnp.where(is_inf, hi_inf, hi_norm)))
        lo = jnp.where(is_zero, u32(0), jnp.where(is_sub, lo_sub, lo_wide))
        return hi, lo

    def mod_u64(h, l, v: int):
        """(h·2^32 + l) % v for static 1 <= v < 2^24, by base-256 byte fold
        (r stays < v, so r·256 + byte < 2^32 — never overflows a limb)."""
        assert 1 <= v < _max_device_vocab()
        r = jnp.zeros_like(h)
        for word in (h, l):
            for shift in (24, 16, 8, 0):
                r = (r * u32(256) + ((word >> u32(shift)) & u32(0xFF))) \
                    % u32(v)
        return r

    return splitmix64, f64_bits_of_f32, mod_u64


@functools.cache
def _jit_tokens_from_padded():
    """Build (lazily, once) the jitted u32-limb device tokenizer."""
    import jax
    import jax.numpy as jnp

    splitmix64, f64_bits_of_f32, mod_u64 = _limb_ops()

    @functools.partial(jax.jit,
                       static_argnames=("vocab_size", "tokens_per_row"))
    def tok(packed, counts, *, vocab_size: int, tokens_per_row: int):
        s, c, cap = packed.shape
        # hash every slot (padding rows hash to garbage and are masked out —
        # branch-free, the device way)
        bh = jnp.zeros((s, cap), jnp.uint32)
        bl = jnp.zeros((s, cap), jnp.uint32)
        for ci in range(c):
            xh, xl = f64_bits_of_f32(packed[:, ci, :])
            bh, bl = splitmix64(bh ^ xh, bl ^ xl)
        toks = []
        h, l = bh, bl
        for _ in range(tokens_per_row):
            h, l = splitmix64(h, l)
            toks.append(mod_u64(h, l, vocab_size).astype(jnp.int32))
        tokens = jnp.stack(toks, axis=-1)            # i32[S, cap, T]
        # valid-count masking + shard-major O(N) pack (same cumsum scatter
        # as the survivor compactor — no sort anywhere in the pipeline)
        valid = (jnp.arange(cap)[None, :] < counts[:, None])   # bool[S, cap]
        flat_valid = jnp.repeat(valid.reshape(-1), tokens_per_row)
        flat = tokens.reshape(-1)
        n = flat.shape[0]
        pos = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
        dest = jnp.where(flat_valid, pos, n)
        out = jnp.zeros((n + 1,), jnp.int32).at[dest].set(flat, mode="drop")
        total = jnp.sum(counts).astype(jnp.int32) * tokens_per_row
        return out[:n], total

    return tok


def tokens_from_padded(packed, counts, vocab_size: int,
                       tokens_per_row: int = 8):
    """Device tokenize+pack over padded survivor buffers (u32-limb path).

    ``packed``: f32[S, C, cap] (or [C, cap] for a single pipeline — auto-
    promoted), ``counts``: i32[S] valid widths. Returns (tokens i32[S·cap·T]
    with the first ``n_valid`` entries live, n_valid i32[]) — the first
    ``n_valid`` tokens are bit-identical to ``rows_to_tokens`` applied to
    the shard-major concatenation of the valid survivor slices. Traces
    without ``enable_x64`` (u32 limb arithmetic throughout — TPU-lowerable);
    requires ``vocab_size < 2**24``.
    """
    import jax.numpy as jnp

    if not 1 <= vocab_size < _max_device_vocab():
        raise ValueError(
            f"device tokenize needs 1 <= vocab_size < {_max_device_vocab()} "
            f"(u32-limb byte-fold modulo), got {vocab_size}")
    if packed.ndim == 2:
        packed = packed[None]
        counts = jnp.asarray(counts, jnp.int32).reshape((1,))
    return _jit_tokens_from_padded()(
        packed, counts, vocab_size=vocab_size, tokens_per_row=tokens_per_row)
