"""jit'd wrappers around the fused filter-chain kernel.

Handles padding to tile multiples, packs the SMEM meta scalars, launches the
kernel, and reduces per-tile counters into the framework-wide
``ChainResult`` contract shared with ``core.filter_exec`` (jnp path) and
``ref.py`` (oracle). ``filter_chain_compact`` additionally fuses survivor
compaction into the same pass (in-kernel cumsum pack + offset-stitch gather
launch — see ``filter_chain.py``). ``interpret=True`` on non-TPU backends,
so the same call validates on CPU and runs compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine.base import ChainResult
from repro.core.predicates import PredicateSpecs
from repro.kernels.filter_chain.filter_chain import (DEFAULT_TILE,
                                                     compact_gather_pallas,
                                                     filter_chain_pallas)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode):
    return jnp.stack([jnp.asarray(n_rows, jnp.int32),
                      jnp.asarray(collect_rate, jnp.int32),
                      jnp.asarray(sample_phase, jnp.int32),
                      jnp.asarray(1 if monitor_mode == "block" else 0,
                                  jnp.int32)])


def _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm, n_rows):
    active_before = jnp.sum(active, axis=0)                  # f32[P]
    cost_in_order = specs.static_cost[perm]
    work = jnp.sum(active_before * cost_in_order)
    n_monitored = jnp.sum(nmon)
    return ChainResult(
        mask=mask_i8[0, :n_rows].astype(bool),
        work_units=work,
        active_before=active_before,
        cut_counts=jnp.sum(cut, axis=0),
        n_monitored=n_monitored,
        monitor_cost=specs.static_cost * n_monitored,
        group_cut_counts=jnp.sum(gcut, axis=0),
    )


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode"))
def filter_chain(columns: jnp.ndarray, specs: PredicateSpecs,
                 perm: jnp.ndarray, *, collect_rate: int,
                 sample_phase, tile: int = DEFAULT_TILE,
                 monitor_mode: str = "row") -> ChainResult:
    """Fused adaptive CNF chain over f32[C, R]; same contract as run_chain.

    monitor_mode: "row" = the paper's stride sampling (bit-exact vs the
    oracle); "block" = contiguous 128-lane slices of every Nth tile — the
    same sampling fraction, vector-friendly on TPU (DESIGN §3.4).
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_cols, n_rows = columns.shape
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)

    mask_i8, active, cut, gcut, nmon = filter_chain_pallas(
        columns, specs, perm.astype(jnp.int32), meta, tile=tile,
        interpret=_should_interpret())

    return _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                          n_rows)


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode",
                                    "capacity", "fill"))
def filter_chain_compact(columns: jnp.ndarray, specs: PredicateSpecs,
                         perm: jnp.ndarray, *, collect_rate: int,
                         sample_phase, capacity: int,
                         tile: int = DEFAULT_TILE, monitor_mode: str = "row",
                         fill: float = 0.0
                         ) -> tuple[ChainResult, jnp.ndarray, jnp.ndarray]:
    """Fused chain + single-pass in-kernel compaction (two small launches).

    Returns (ChainResult, packed f32[C, capacity], n_kept i32[]). Launch 1
    streams each tile HBM→VMEM exactly once and, while the tile is
    resident, packs its survivors to the front of the tile's slot via the
    exclusive mask cumsum (no ``argsort``); the only inter-launch work is an
    O(n_tiles) exclusive cumsum of the per-tile survivor counts; launch 2
    stitches the packed tiles at their global offsets, touching survivor
    bytes only. Saturation semantics match ``filter_exec.compact_fixed``:
    survivors beyond ``capacity`` are dropped and ``n_kept`` saturates.
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_cols, n_rows = columns.shape
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)
    interpret = _should_interpret()

    mask_i8, active, cut, gcut, nmon, packed_tiles, tile_cnt = \
        filter_chain_pallas(columns, specs, perm.astype(jnp.int32), meta,
                            tile=tile, interpret=interpret, compact=True,
                            fill=fill)

    cnt = tile_cnt[:, 0]                                     # i32[T]
    csum = jnp.cumsum(cnt)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), csum.dtype), csum[:-1]]).astype(jnp.int32)
    packed = compact_gather_pallas(packed_tiles, offsets, capacity,
                                   tile=tile, interpret=interpret, fill=fill)
    n_kept = jnp.minimum(csum[-1], capacity).astype(jnp.int32)

    result = _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                            n_rows)
    return result, packed, n_kept
