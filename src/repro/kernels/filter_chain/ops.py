"""jit'd wrappers around the fused filter-chain kernel.

Handles padding to tile multiples, packs the SMEM meta scalars, launches the
kernel, and reduces per-tile counters into the framework-wide
``ChainResult`` contract shared with ``core.filter_exec`` (jnp path) and
``ref.py`` (oracle). ``filter_chain_compact`` additionally fuses survivor
compaction into the same pass (in-kernel cumsum pack + offset-stitch gather
launch — see ``filter_chain.py``). ``interpret=True`` on non-TPU backends,
so the same call validates on CPU and runs compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine.base import ChainResult, SkipInfo
from repro.core.predicates import PredicateSpecs
from repro.kernels.filter_chain.filter_chain import (DEFAULT_TILE, STAT_TILE,
                                                     compact_gather_pallas,
                                                     filter_chain_pallas,
                                                     tile_stats_pallas)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode):
    return jnp.stack([jnp.asarray(n_rows, jnp.int32),
                      jnp.asarray(collect_rate, jnp.int32),
                      jnp.asarray(sample_phase, jnp.int32),
                      jnp.asarray(1 if monitor_mode == "block" else 0,
                                  jnp.int32)])


def _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm, n_rows,
                   skip: SkipInfo | None = None):
    active_before = jnp.sum(active, axis=0)                  # f32[P]
    cost_in_order = specs.static_cost[perm]
    work = jnp.sum(active_before * cost_in_order)
    n_monitored = jnp.sum(nmon)
    zero = jnp.zeros((), jnp.int32)
    if skip is None:
        n_pass_t = n_fail_t = n_amb_t = zero
    else:
        n_pass_t = jnp.sum(skip.pass_tiles.astype(jnp.int32))
        n_fail_t = jnp.sum(skip.fail_tiles.astype(jnp.int32))
        n_amb_t = skip.pass_tiles.shape[0] - n_pass_t - n_fail_t
    return ChainResult(
        mask=mask_i8[0, :n_rows].astype(bool),
        work_units=work,
        active_before=active_before,
        cut_counts=jnp.sum(cut, axis=0),
        n_monitored=n_monitored,
        monitor_cost=specs.static_cost * n_monitored,
        group_cut_counts=jnp.sum(gcut, axis=0),
        n_tiles_pass=n_pass_t,
        n_tiles_fail=n_fail_t,
        n_tiles_ambiguous=n_amb_t,
    )


def _pad_cols(columns, tile):
    n_rows = columns.shape[1]
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    return columns


def _skip_decisions(skip: SkipInfo):
    return (skip.pass_tiles.astype(jnp.int32),
            skip.fail_tiles.astype(jnp.int32))


def skip_triage(columns: jnp.ndarray, specs: PredicateSpecs, *, bloom: bool,
                tile: int = DEFAULT_TILE) -> SkipInfo:
    """Zone-map (+ Bloom) triage pre-pass for the pallas skip tier.

    NOT jitted here: the CNF resolution branches on the predicate ops,
    which must be host constants — callers jit with ``specs`` closed over
    (the session's ``_jit_triage`` does exactly that).

    Pads to the kernel's grid tile with ZEROS (matching the chain launch's
    padding): zero lanes can only weaken a fail proof, and a pass proof they
    satisfy is still intersected with row validity in-kernel, so both
    proofs stay conservative. The min/max summaries come from the Pallas
    stats kernel; the Bloom bitmap and the CNF tile resolution are shared
    jnp glue (``core.skip_tier``) — trace-time constants of the chain, so
    the per-op branching folds away. Tile counts are over the PADDED
    tiling: a ragged tail contributes decided-but-empty sub-tiles.
    """
    from repro.core import skip_tier

    assert STAT_TILE == skip_tier.SKIP_TILE
    padded = _pad_cols(columns, tile)
    mins, maxs = tile_stats_pallas(padded, tile=tile,
                                   interpret=_should_interpret())
    bl = skip_tier.bloom_bitmap(padded, xp=jnp) if bloom else None
    pass_t, fail_t = skip_tier.resolve_tiles(mins, maxs, bl, specs, xp=jnp)
    n_amb = jnp.sum(~(pass_t | fail_t)).astype(jnp.int32)
    return SkipInfo(pass_tiles=pass_t, fail_tiles=fail_t, n_ambiguous=n_amb)


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode"))
def filter_chain(columns: jnp.ndarray, specs: PredicateSpecs,
                 perm: jnp.ndarray, *, collect_rate: int,
                 sample_phase, tile: int = DEFAULT_TILE,
                 monitor_mode: str = "row") -> ChainResult:
    """Fused adaptive CNF chain over f32[C, R]; same contract as run_chain.

    monitor_mode: "row" = the paper's stride sampling (bit-exact vs the
    oracle); "block" = contiguous 128-lane slices of every Nth tile — the
    same sampling fraction, vector-friendly on TPU (DESIGN §3.4).
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_cols, n_rows = columns.shape
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)

    mask_i8, active, cut, gcut, nmon = filter_chain_pallas(
        columns, specs, perm.astype(jnp.int32), meta, tile=tile,
        interpret=_should_interpret())

    return _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                          n_rows)


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode",
                                    "capacity", "fill"))
def filter_chain_compact(columns: jnp.ndarray, specs: PredicateSpecs,
                         perm: jnp.ndarray, *, collect_rate: int,
                         sample_phase, capacity: int,
                         tile: int = DEFAULT_TILE, monitor_mode: str = "row",
                         fill: float = 0.0
                         ) -> tuple[ChainResult, jnp.ndarray, jnp.ndarray]:
    """Fused chain + single-pass in-kernel compaction (two small launches).

    Returns (ChainResult, packed f32[C, capacity], n_kept i32[]). Launch 1
    streams each tile HBM→VMEM exactly once and, while the tile is
    resident, packs its survivors to the front of the tile's slot via the
    exclusive mask cumsum (no ``argsort``); the only inter-launch work is an
    O(n_tiles) exclusive cumsum of the per-tile survivor counts; launch 2
    stitches the packed tiles at their global offsets, touching survivor
    bytes only. Saturation semantics match ``filter_exec.compact_fixed``:
    survivors beyond ``capacity`` are dropped and ``n_kept`` saturates.
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_cols, n_rows = columns.shape
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)
    interpret = _should_interpret()

    mask_i8, active, cut, gcut, nmon, packed_tiles, tile_cnt = \
        filter_chain_pallas(columns, specs, perm.astype(jnp.int32), meta,
                            tile=tile, interpret=interpret, compact=True,
                            fill=fill)

    cnt = tile_cnt[:, 0]                                     # i32[T]
    csum = jnp.cumsum(cnt)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), csum.dtype), csum[:-1]]).astype(jnp.int32)
    packed = compact_gather_pallas(packed_tiles, offsets, capacity,
                                   tile=tile, interpret=interpret, fill=fill)
    n_kept = jnp.minimum(csum[-1], capacity).astype(jnp.int32)

    result = _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                            n_rows)
    return result, packed, n_kept


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode"))
def filter_chain_skip(columns: jnp.ndarray, specs: PredicateSpecs,
                      perm: jnp.ndarray, skip: SkipInfo, *,
                      collect_rate: int, sample_phase,
                      tile: int = DEFAULT_TILE,
                      monitor_mode: str = "row") -> ChainResult:
    """``filter_chain`` with zone-map-decided sub-tiles bypassing the chain.

    ``skip`` comes from ``skip_triage`` on the same batch. Decided sub-tiles
    start with no pending rows (work counters charge only ambiguous rows —
    the row-level work actually done); the monitor lane is untouched, so
    ordering statistics match the unskipped launch bit-exactly.
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_rows = columns.shape[1]
    columns = _pad_cols(columns, tile)
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)

    mask_i8, active, cut, gcut, nmon = filter_chain_pallas(
        columns, specs, perm.astype(jnp.int32), meta, tile=tile,
        interpret=_should_interpret(), skip_decisions=_skip_decisions(skip))

    return _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                          n_rows, skip=skip)


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode",
                                    "capacity", "fill"))
def filter_chain_compact_skip(columns: jnp.ndarray, specs: PredicateSpecs,
                              perm: jnp.ndarray, skip: SkipInfo, *,
                              collect_rate: int, sample_phase, capacity: int,
                              tile: int = DEFAULT_TILE,
                              monitor_mode: str = "row", fill: float = 0.0
                              ) -> tuple[ChainResult, jnp.ndarray,
                                         jnp.ndarray]:
    """``filter_chain_compact`` behind the skip tier.

    Provably-passing sub-tiles are bulk-copied by the same in-kernel cumsum
    pack (their mask lanes arrive pre-set, no predicate work); provably-
    failing sub-tiles contribute nothing to the pack. Saturation semantics
    are unchanged.
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_rows = columns.shape[1]
    columns = _pad_cols(columns, tile)
    meta = _pack_meta(n_rows, collect_rate, sample_phase, monitor_mode)
    interpret = _should_interpret()

    mask_i8, active, cut, gcut, nmon, packed_tiles, tile_cnt = \
        filter_chain_pallas(columns, specs, perm.astype(jnp.int32), meta,
                            tile=tile, interpret=interpret, compact=True,
                            fill=fill, skip_decisions=_skip_decisions(skip))

    cnt = tile_cnt[:, 0]                                     # i32[T]
    csum = jnp.cumsum(cnt)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), csum.dtype), csum[:-1]]).astype(jnp.int32)
    packed = compact_gather_pallas(packed_tiles, offsets, capacity,
                                   tile=tile, interpret=interpret, fill=fill)
    n_kept = jnp.minimum(csum[-1], capacity).astype(jnp.int32)

    result = _reduce_result(mask_i8, active, cut, gcut, nmon, specs, perm,
                            n_rows, skip=skip)
    return result, packed, n_kept
