"""jit'd wrapper around the fused filter-chain kernel.

Handles padding to tile multiples, packs the SMEM meta scalars, launches the
kernel, and reduces per-tile counters into the framework-wide
``ChainResult`` contract shared with ``core.filter_exec`` (jnp path) and
``ref.py`` (oracle). ``interpret=True`` on non-TPU backends, so the same
call validates on CPU and runs compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine.base import ChainResult
from repro.core.predicates import PredicateSpecs
from repro.kernels.filter_chain.filter_chain import (DEFAULT_TILE,
                                                     filter_chain_pallas)


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("collect_rate", "tile", "monitor_mode"))
def filter_chain(columns: jnp.ndarray, specs: PredicateSpecs,
                 perm: jnp.ndarray, *, collect_rate: int,
                 sample_phase, tile: int = DEFAULT_TILE,
                 monitor_mode: str = "row") -> ChainResult:
    """Fused adaptive CNF chain over f32[C, R]; same contract as run_chain.

    monitor_mode: "row" = the paper's stride sampling (bit-exact vs the
    oracle); "block" = contiguous 128-lane slices of every Nth tile — the
    same sampling fraction, vector-friendly on TPU (DESIGN §3.4).
    """
    if monitor_mode not in ("row", "block"):
        raise ValueError(monitor_mode)
    n_cols, n_rows = columns.shape
    pad = (-n_rows) % tile
    if pad:
        columns = jnp.pad(columns, ((0, 0), (0, pad)))
    meta = jnp.stack([jnp.asarray(n_rows, jnp.int32),
                      jnp.asarray(collect_rate, jnp.int32),
                      jnp.asarray(sample_phase, jnp.int32),
                      jnp.asarray(1 if monitor_mode == "block" else 0,
                                  jnp.int32)])

    mask_i8, active, cut, gcut, nmon = filter_chain_pallas(
        columns, specs, perm.astype(jnp.int32), meta, tile=tile,
        interpret=_should_interpret())

    active_before = jnp.sum(active, axis=0)                  # f32[P]
    cost_in_order = specs.static_cost[perm]
    work = jnp.sum(active_before * cost_in_order)
    n_monitored = jnp.sum(nmon)
    return ChainResult(
        mask=mask_i8[0, :n_rows].astype(bool),
        work_units=work,
        active_before=active_before,
        cut_counts=jnp.sum(cut, axis=0),
        n_monitored=n_monitored,
        monitor_cost=specs.static_cost * n_monitored,
        group_cut_counts=jnp.sum(gcut, axis=0),
    )
