"""Pallas TPU kernel: fused adaptive predicate chain over columnar tiles.

Spark evaluates the chain row-at-a-time inside ``processNext``; the TPU
adaptation (DESIGN §3) processes rows in VMEM tiles:

  * one grid step = one (C, TILE) column tile, streamed HBM→VMEM once —
    the whole chain is FUSED into a single pass over the data (Spark's
    operator iterator touches rows once too, but pays per-row dispatch;
    XLA's unfused jnp path would touch HBM once per predicate);
  * predicates are evaluated vector-wise in the adaptive permutation order.
    CNF structure (OR within a group, AND across groups) is tracked with a
    running per-tile OR accumulator: members of the open group only
    evaluate rows not yet passed (vector analogue of the OR short-circuit),
    and when a group closes its accumulator ANDs into the running mask;
  * when a tile has no pending rows for a position, that predicate is
    SKIPPED for the tile (``lax.cond`` — tile-granular short-circuit, the
    vector analogue of the row-level early exit);
  * the monitor lane (paper §2.1) evaluates ALL predicates on
    stride-sampled rows and emits per-tile numCut / per-GROUP cut /
    monitored counts;
  * per-tile ``active_before`` counters reproduce the row-level work model
    exactly (they count rows pending before each chain position), so the
    paper's cost accounting survives vectorization bit-exactly.

Memory layout: predicate spec arrays (i32/f32[P]) live in SMEM (scalar
dispatch data); column tiles and outputs in VMEM. The CNF group ids ride
twice: as an SMEM i32[P] vector for the perm-ordered chain lane (the
permutation is dynamic) and as a STATIC python tuple for the monitor lane's
group reduction (user order → unrolled at trace time). All intra-kernel
compute is 2D (1, TILE)-shaped for VPU lane alignment; TILE is a multiple
of 128.

Grid-step cost model (for §Roofline): bytes/tile = C·TILE·4 in + TILE out;
with in-kernel compaction the tile additionally writes the within-tile
packed survivors (C·TILE·4) and one i32 count; the second (gather) launch
then reads only survivor data — p·C·TILE·4 per tile at pass-rate p — plus
the T-entry offset vector, never the full batch again.  FLOPs/tile ≈
TILE · Σ_{k ≤ stop} cost(perm[k]) — memory-bound at ~0.25–2 FLOP/byte
unless expensive (HASHMIX) predicates dominate.
(``benchmarks/roofline.py::filter_ingest_model`` renders this model.)

Single-pass compaction (two launches, no sort):

  launch 1 (this kernel, ``compact=True``): while the (C, TILE) tile is
    still in VMEM, each grid step computes every survivor's within-tile
    slot as its exclusive mask cumsum (an O(TILE) scan — the argsort the
    jnp path used to pay is gone), scatters survivors to the front of the
    tile's own slot in the packed output, and emits the tile's survivor
    count;
  glue: an O(n_tiles) exclusive cumsum of the counts (XLA, a few hundred
    ints) turns per-tile slots into global offsets;
  launch 2 (``compact_gather_pallas``): one grid step per tile stores the
    packed tile at its global offset into the [C, cap + TILE] output ring.
    Stores overlap by construction — tile t's zero tail is overwritten by
    tile t+1's survivors (the TPU grid is sequential) — so the result is
    the densely packed survivor buffer without reading the full-width
    columns a second time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import predicates as pred_lib

DEFAULT_TILE = 2048  # rows per grid step; multiple of 128 (VPU lanes)
STAT_TILE = 128      # zone-map statistics granularity (= skip_tier.SKIP_TILE)


def _stats_kernel(cols_ref, min_ref, max_ref, *, tile: int):
    """Skip-tier pre-pass: per-STAT_TILE column min/max for one grid tile.

    One (C, TILE) tile in VMEM → (1, C, TILE/STAT_TILE) zone-map
    summaries. The reshape splits the lane dimension into (sub, 128) so
    each reduction runs over full VPU lanes; a production Mosaic kernel
    would fuse this into the ingest DMA, but as a separate launch it still
    reads each byte exactly once and writes only TILE/STAT_TILE summary
    lanes per column.
    """
    sub = tile // STAT_TILE
    x = cols_ref[:, :]                                   # f32[C, TILE]
    t3 = x.reshape(cols_ref.shape[0], sub, STAT_TILE)
    min_ref[0, :, :] = t3.min(axis=2)
    max_ref[0, :, :] = t3.max(axis=2)


def tile_stats_pallas(columns: jnp.ndarray, *, tile: int = DEFAULT_TILE,
                      interpret: bool = True):
    """Zone-map summaries of f32[C, Rp] (Rp % tile == 0).

    Returns (mins f32[C, Rp/STAT_TILE], maxs f32[C, Rp/STAT_TILE]).

    The launch writes tile-major f32[n_tiles, C, sub] blocks — each grid
    step owns one fully-covered (1, C, sub) block, so every block's
    minormost dim is its array's full lane extent (``kernel_audit``'s
    alignment rule; a (C, sub)-strided lane tile would make Mosaic retile
    the summary rows on every step). The transpose back to the external
    f32[C, Rp/STAT_TILE] contract is XLA glue over kilobytes.
    """
    n_cols, n_rows_p = columns.shape
    if n_rows_p % tile:
        raise ValueError(f"padded rows {n_rows_p} not a multiple of {tile}")
    n_tiles = n_rows_p // tile
    sub = tile // STAT_TILE
    kernel = functools.partial(_stats_kernel, tile=tile)
    out_spec = pl.BlockSpec((1, n_cols, sub), lambda i: (i, 0, 0))
    out_shape = jax.ShapeDtypeStruct((n_tiles, n_cols, sub), jnp.float32)
    mins, maxs = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n_cols, tile), lambda i: (0, i))],
        out_specs=[out_spec, out_spec],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
        name="adaptive_filter_tile_stats",
    )(columns)

    def _flat(a):                    # [T, C, sub] → [C, T·sub]
        return a.transpose(1, 0, 2).reshape(n_cols, n_tiles * sub)

    return _flat(mins), _flat(maxs)


def _eval_pred_tile(cols_ref, col_idx, op, t1, t2, rounds):
    """Evaluate one predicate on the whole (C, TILE) tile → bool(1, TILE).

    ``col_idx``/``op``/... are dynamic scalars read from SMEM. The column is
    selected with a dynamic sublane slice; the op dispatch is a scalar
    switch, so only the selected branch's vector work executes (HASHMIX's
    mix loop only runs for HASHMIX predicates — the cost heterogeneity the
    ordering exploits is preserved on-chip).
    """
    x = pl.load(cols_ref, (pl.ds(col_idx, 1), slice(None)))  # f32[1, TILE]

    def _hashmix():
        def body(_, y):
            y = y * pred_lib.MIX_MUL + pred_lib.MIX_ADD
            return y - jnp.floor(y / pred_lib.MIX_MOD) * pred_lib.MIX_MOD
        mixed = jax.lax.fori_loop(0, jnp.maximum(rounds, 1), body, x)
        return mixed > t1

    return jax.lax.switch(op, [
        lambda: x > t1,
        lambda: x < t1,
        lambda: jnp.logical_and(x > t1, x < t2),
        lambda: jnp.round(x) == jnp.round(t1),
        _hashmix,
    ])


def _kernel(# --- SMEM scalar/spec refs ---
            col_ref, op_ref, t1_ref, t2_ref, rounds_ref, perm_ref, group_ref,
            meta_ref,  # i32[4]: (n_rows, collect_rate, sample_phase, mode)
            # --- skip-tier SMEM refs (skip=True), VMEM data, outputs ---
            *refs,  # [pass_ref, fail_ref,] cols_ref, mask_ref, active_ref,
                    # cut_ref, gcut_ref, nmon_ref [, packed_ref, cnt_ref]
            n_preds: int, tile: int, groups: tuple, fill: float = 0.0,
            skip: bool = False):
    if skip:
        pass_ref, fail_ref = refs[0], refs[1]
        refs = refs[2:]
    cols_ref, mask_ref, active_ref, cut_ref, gcut_ref, nmon_ref = refs[:6]
    compact_refs = refs[6:]   # (packed_ref, cnt_ref) when compact=True
    t = pl.program_id(0)
    n_rows = meta_ref[0]
    collect_rate = meta_ref[1]
    sample_phase = meta_ref[2]
    block_mode = meta_ref[3]
    flat = len(set(groups)) == len(groups)   # static: all-singleton groups

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    gidx = t * tile + lane
    valid = gidx < n_rows                                    # bool(1, TILE)

    # ------------------------------------------------------ skip-tier lanes
    # Zone-map triage (skip-tier pre-pass) resolved this grid tile's
    # STAT_TILE sub-tiles host-of-kernel; broadcast the i32 decisions from
    # SMEM into lane masks. Decided sub-tiles start with no pending rows, so
    # the existing ``alive > 0`` cond gives tile-granular skip for free — a
    # fully decided grid tile evaluates ZERO predicates (with BlockSpec
    # streaming the tile regardless; a Mosaic lowering would gate the DMA on
    # the same SMEM scalars so failed tiles never enter VMEM column-wide).
    pass_lane = None
    if skip:
        sub = tile // STAT_TILE
        segs_p, segs_f = [], []
        for j in range(sub):                 # static unroll: SMEM scalars
            segs_p.append(jnp.full((1, STAT_TILE), pass_ref[t * sub + j],
                                   jnp.int32))
            segs_f.append(jnp.full((1, STAT_TILE), fail_ref[t * sub + j],
                                   jnp.int32))
        pass_lane = jnp.concatenate(segs_p, axis=1) > 0
        fail_lane = jnp.concatenate(segs_f, axis=1) > 0
        decided = jnp.logical_or(pass_lane, fail_lane)

    # ----------------------------------------------------------- chain lane
    # survivors of closed groups; decided sub-tiles bypass the row level
    mask = valid if not skip \
        else jnp.logical_and(valid, jnp.logical_not(decided))
    group_or = jnp.zeros((1, tile), bool)     # passes within the open group
    for k in range(n_preds):                 # P static → unrolled on-chip
        pidx = perm_ref[k]
        # group-boundary flags: static True when flat; dynamic SMEM scalar
        # comparisons otherwise (the permutation is data-dependent).
        is_first = True if (flat or k == 0) \
            else group_ref[perm_ref[k - 1]] != group_ref[pidx]
        closes = True if (flat or k == n_preds - 1) \
            else group_ref[perm_ref[k + 1]] != group_ref[pidx]
        pending = mask if is_first is True \
            else jnp.where(is_first, mask,
                           jnp.logical_and(mask, jnp.logical_not(group_or)))
        alive = jnp.sum(pending.astype(jnp.float32))
        active_ref[0, k] = alive
        res = jax.lax.cond(
            alive > 0.0,
            lambda: _eval_pred_tile(cols_ref, col_ref[pidx], op_ref[pidx],
                                    t1_ref[pidx], t2_ref[pidx],
                                    rounds_ref[pidx]),
            lambda: jnp.zeros((1, tile), bool),   # tile short-circuit
        )
        group_or = res if is_first is True \
            else jnp.where(is_first, res, jnp.logical_or(group_or, res))
        new_mask = jnp.logical_and(mask, group_or)
        mask = new_mask if closes is True \
            else jnp.where(closes, new_mask, mask)
    if skip:
        # bulk-keep provably-passing sub-tiles (valid rows only — zero
        # padding can satisfy a proof but never survives); the in-kernel
        # compaction below then bulk-copies them with no predicate work.
        mask = jnp.logical_or(mask, jnp.logical_and(pass_lane, valid))
    mask_ref[0, :] = mask[0].astype(jnp.int8)

    # ------------------------------------------------- in-kernel compaction
    # The tile is still resident in VMEM: pack its survivors to the front of
    # its own slot NOW, so the gather launch never re-reads the full batch.
    # Slot = exclusive cumsum of the mask (O(TILE) scan, no sort); the
    # non-survivors scatter into a dump lane that is sliced off. The zero
    # (``fill``) tail is load-bearing: launch 2 relies on it when its
    # overlapping stores stitch tiles together.
    if compact_refs:
        packed_ref, cnt_ref = compact_refs
        mrow = mask[0]                                   # bool[TILE]
        mi = mrow.astype(jnp.int32)
        pos = jnp.cumsum(mi) - 1                         # within-tile slot
        dest = jnp.where(mrow, pos, tile)
        buf = jnp.full((cols_ref.shape[0], tile + 1), fill, cols_ref.dtype)
        buf = buf.at[:, dest].set(cols_ref[:, :], mode="drop")
        packed_ref[:, :] = buf[:, :tile]
        cnt_ref[0, 0] = jnp.sum(mi)

    # --------------------------------------------------------- monitor lane
    # row mode (paper-exact): deterministic stride over the GLOBAL row index
    # (paper §2.1). block mode (TPU-native, DESIGN §3.4): the same sampling
    # FRACTION delivered as one contiguous 128-lane slice of every
    # ``tile_stride``-th tile — scattered single rows cost a full vector op
    # each on a VPU, a contiguous slice costs one.
    row_sampled = ((gidx + sample_phase) % collect_rate) == 0
    tile_stride = jnp.maximum(collect_rate * 128 // tile, 1)
    block_tile = ((t + sample_phase) % tile_stride) == 0
    block_sampled = jnp.logical_and(block_tile, lane < 128)
    sampled = jnp.logical_and(
        jnp.where(block_mode == 1, block_sampled, row_sampled), valid)
    n_sampled = jnp.sum(sampled.astype(jnp.float32))
    nmon_ref[0, 0] = n_sampled

    members: list[list[int]] = [[] for _ in range(max(groups) + 1)]
    for i, g in enumerate(groups):
        members[g].append(i)

    @pl.when(n_sampled > 0.0)
    def _monitor():
        fails = []
        for p in range(n_preds):             # ALL predicates, user order
            res = _eval_pred_tile(cols_ref, col_ref[p], op_ref[p],
                                  t1_ref[p], t2_ref[p], rounds_ref[p])
            fail = jnp.logical_not(res)
            fails.append(fail)
            cut_ref[0, p] = jnp.sum(
                jnp.logical_and(sampled, fail).astype(jnp.float32))
        for gi, mem in enumerate(members):   # static group reduction
            gfail = fails[mem[0]]
            for m in mem[1:]:
                gfail = jnp.logical_and(gfail, fails[m])
            gcut_ref[0, gi] = jnp.sum(
                jnp.logical_and(sampled, gfail).astype(jnp.float32))

    @pl.when(n_sampled == 0.0)
    def _no_monitor():
        for p in range(n_preds):
            cut_ref[0, p] = 0.0
        for gi in range(len(members)):
            gcut_ref[0, gi] = 0.0


def filter_chain_pallas(columns: jnp.ndarray, specs, perm: jnp.ndarray,
                        meta: jnp.ndarray, *, tile: int = DEFAULT_TILE,
                        interpret: bool = True, compact: bool = False,
                        fill: float = 0.0, skip_decisions=None):
    """Launch the fused chain kernel.

    columns: f32[C, R_padded] with R_padded % tile == 0.
    meta:    i32[4] = (n_rows_actual, collect_rate, sample_phase, mode).
    skip_decisions: optional (pass i32[Rp/STAT_TILE], fail i32[Rp/STAT_TILE])
    from the zone-map triage pre-pass — decided sub-tiles bypass the
    row-level chain (the monitor lane still samples them row-level, keeping
    ordering statistics identical with the tier on or off).
    Returns (mask i8[1,Rp], active f32[n_tiles,P], cut f32[n_tiles,P],
             gcut f32[n_tiles,G], nmon f32[n_tiles,1]); with
    ``compact=True`` additionally (packed f32[C,Rp] — survivors packed to
    the front of each tile's slot, ``fill`` tail — and cnt i32[n_tiles,1]).
    """
    n_cols, n_rows_p = columns.shape
    if n_rows_p % tile:
        raise ValueError(f"padded rows {n_rows_p} not a multiple of tile {tile}")
    n_tiles = n_rows_p // tile
    n_preds = int(specs.column.shape[0])
    groups = specs.groups                    # static tuple (pytree aux)
    n_groups = max(groups) + 1
    garr = jnp.asarray(groups, jnp.int32)

    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    grid = (n_tiles,)

    out_specs = [
        pl.BlockSpec((1, tile), lambda i: (0, i)),
        pl.BlockSpec((1, n_preds), lambda i: (i, 0)),
        pl.BlockSpec((1, n_preds), lambda i: (i, 0)),
        pl.BlockSpec((1, n_groups), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((1, n_rows_p), jnp.int8),
        jax.ShapeDtypeStruct((n_tiles, n_preds), jnp.float32),
        jax.ShapeDtypeStruct((n_tiles, n_preds), jnp.float32),
        jax.ShapeDtypeStruct((n_tiles, n_groups), jnp.float32),
        jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
    ]
    if compact:
        out_specs += [pl.BlockSpec((n_cols, tile), lambda i: (0, i)),
                      pl.BlockSpec((1, 1), lambda i: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((n_cols, n_rows_p), jnp.float32),
                      jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32)]

    skip = skip_decisions is not None
    kernel = functools.partial(_kernel, n_preds=n_preds, tile=tile,
                               groups=groups, fill=fill, skip=skip)
    in_specs = [smem(), smem(), smem(), smem(), smem(), smem(), smem(),
                smem()]
    args = [specs.column, specs.op, specs.t1, specs.t2, specs.rounds, perm,
            garr, meta]
    if skip:
        in_specs += [smem(), smem()]
        args += [skip_decisions[0], skip_decisions[1]]
    in_specs.append(pl.BlockSpec((n_cols, tile), lambda i: (0, i)))
    args.append(columns)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        name="adaptive_filter_chain_skip" if skip else "adaptive_filter_chain",
    )(*args)


def _gather_kernel(off_ref, packed_ref, out_ref, *, tile: int, capacity: int,
                   fill: float):
    """Second launch: stitch packed tiles at their global offsets.

    The output block is the SAME [C, cap + TILE] window for every grid step
    (revisited block). Step t stores its full packed tile at the dynamic
    offset; because offsets advance by the previous tile's survivor count,
    each store's ``fill`` tail is overwritten by the next tile's survivors —
    the sequential TPU grid makes the overlap well-defined. Only survivor
    bytes ever move twice.
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[:, :] = jnp.full(out_ref.shape, fill, out_ref.dtype)

    off = off_ref[t]

    @pl.when(off < capacity)                  # saturated: drop whole tile
    def _store():
        pl.store(out_ref, (slice(None), pl.ds(off, tile)), packed_ref[:, :])


def compact_gather_pallas(packed_tiles: jnp.ndarray, offsets: jnp.ndarray,
                          capacity: int, *, tile: int = DEFAULT_TILE,
                          interpret: bool = True, fill: float = 0.0):
    """Gather within-tile-packed survivors into one [C, capacity] buffer.

    ``packed_tiles``: f32[C, Rp] from the chain launch (``compact=True``);
    ``offsets``: i32[n_tiles] exclusive cumsum of the per-tile counts.
    Reads only the packed tiles + the offset vector — the original columns
    are not touched. Survivors beyond ``capacity`` are dropped (saturation
    semantics identical to ``filter_exec.compact_fixed``).
    """
    n_cols, n_rows_p = packed_tiles.shape
    n_tiles = n_rows_p // tile
    kernel = functools.partial(_gather_kernel, tile=tile, capacity=capacity,
                               fill=fill)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((n_cols, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n_cols, capacity + tile), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cols, capacity + tile),
                                       jnp.float32),
        interpret=interpret,
        name="adaptive_filter_compact_gather",
    )(offsets, packed_tiles)
    return out[:, :capacity]
