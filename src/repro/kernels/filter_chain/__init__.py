"""Fused adaptive filter-chain kernel (the paper's hot spot, TPU-native)."""

from repro.kernels.filter_chain.ops import filter_chain

__all__ = ["filter_chain"]
