"""Pure-jnp oracle for the fused filter-chain kernel.

Deliberately computed a *different* way from both the kernel and
``core.filter_exec.run_chain``: the dense [P, R] outcome matrix is built
up-front (no laziness, no tiling) and the chain is derived from prefix
products — so a bug in the lazy/tiled paths cannot hide in the oracle.
Row-level work accounting (the Spark model) falls out of the prefix masks.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import predicates as pred_lib
from repro.core.filter_exec import ChainResult
from repro.core.predicates import PredicateSpecs


def filter_chain_ref(columns: jnp.ndarray, specs: PredicateSpecs,
                     perm: jnp.ndarray, *, collect_rate: int,
                     sample_phase) -> ChainResult:
    n_rows = columns.shape[1]
    outcomes = pred_lib.eval_all(specs, columns)          # bool[P, R]

    ordered = outcomes[perm]                              # chain order
    prefix = jnp.cumprod(ordered.astype(jnp.int32), axis=0)  # alive after k+1
    mask = prefix[-1].astype(bool)

    alive_after = jnp.sum(prefix, axis=1).astype(jnp.float32)   # f32[P]
    active_before = jnp.concatenate(
        [jnp.full((1,), float(n_rows), jnp.float32), alive_after[:-1]])
    work = jnp.sum(active_before * specs.static_cost[perm])

    # monitor lane: stride-sampled rows, ALL predicates (user order)
    gidx = jnp.arange(n_rows, dtype=jnp.int32)
    sampled = ((gidx + sample_phase) % collect_rate) == 0
    cut = jnp.sum(jnp.logical_and(~outcomes, sampled[None, :]), axis=1)
    n_monitored = jnp.sum(sampled).astype(jnp.float32)

    return ChainResult(
        mask=mask,
        work_units=work,
        active_before=active_before,
        cut_counts=cut.astype(jnp.float32),
        n_monitored=n_monitored,
        monitor_cost=specs.static_cost * n_monitored,
    )
