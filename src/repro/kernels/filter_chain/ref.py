"""Pure-jnp oracle for the fused filter-chain kernel.

Deliberately computed a *different* way from both the kernel and
``core.filter_exec.run_chain``: the dense [P, R] outcome matrix is built
up-front (no laziness, no tiling, no masked short-circuit) and the CNF
chain — mask, pending counts, group cuts — is derived from that matrix with
plain boolean algebra, so a bug in the lazy/tiled paths cannot hide in the
oracle. Row-level work accounting (the Spark model with OR- and AND-level
short-circuit) falls out of the per-position pending masks.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import predicates as pred_lib
from repro.core.engine.base import ChainResult
from repro.core.predicates import PredicateSpecs


def filter_chain_ref(columns: jnp.ndarray, specs: PredicateSpecs,
                     perm: jnp.ndarray, *, collect_rate: int,
                     sample_phase) -> ChainResult:
    n_rows = columns.shape[1]
    outcomes = pred_lib.eval_all(specs, columns)          # bool[P, R]
    groups = np.asarray(specs.groups)
    perm_host = [int(i) for i in np.asarray(perm)]        # oracle runs eager

    # group pass matrix (order-invariant): row passes group g iff ANY member
    # passes; the chain mask is the AND over groups.
    gpass = jnp.stack([jnp.any(outcomes[jnp.asarray(m)], axis=0)
                       for m in specs.group_members])     # bool[G, R]
    mask = jnp.all(gpass, axis=0)

    # work model: walk perm positions; a row is pending at position k iff it
    # passed every group already CLOSED and no earlier member of the OPEN
    # group. (Groups are contiguous in perm by construction.)
    closed_pass = jnp.ones((n_rows,), bool)
    seen_or = jnp.zeros((n_rows,), bool)
    active_before = []
    work = jnp.zeros((), jnp.float32)
    for k, i in enumerate(perm_host):
        if k > 0 and groups[perm_host[k - 1]] != groups[i]:
            closed_pass = jnp.logical_and(closed_pass,
                                          gpass[int(groups[perm_host[k - 1]])])
            seen_or = jnp.zeros((n_rows,), bool)
        pending = jnp.logical_and(closed_pass, ~seen_or)
        alive = jnp.sum(pending).astype(jnp.float32)
        active_before.append(alive)
        work = work + alive * specs.static_cost[i]
        seen_or = jnp.logical_or(seen_or, outcomes[i])

    # monitor lane: stride-sampled rows, ALL predicates (user order)
    gidx = jnp.arange(n_rows, dtype=jnp.int32)
    sampled = ((gidx + sample_phase) % collect_rate) == 0
    cut = jnp.sum(jnp.logical_and(~outcomes, sampled[None, :]), axis=1)
    group_cut = jnp.sum(jnp.logical_and(~gpass, sampled[None, :]), axis=1)
    n_monitored = jnp.sum(sampled).astype(jnp.float32)

    return ChainResult(
        mask=mask,
        work_units=work,
        active_before=jnp.stack(active_before),
        cut_counts=cut.astype(jnp.float32),
        n_monitored=n_monitored,
        monitor_cost=specs.static_cost * n_monitored,
        group_cut_counts=group_cut.astype(jnp.float32),
    )


def compact_fixed_ref(columns, mask, capacity: int, fill: float = 0.0):
    """Host-oracle for fixed-capacity compaction: plain boolean index + pad.

    Deliberately the dumbest possible formulation (numpy boolean indexing,
    eager) so a bug in the cumsum-scatter or the two-launch kernel path
    cannot hide in the oracle. Returns (packed f32[C, capacity], n_kept).
    """
    cols = np.asarray(columns)
    m = np.asarray(mask).astype(bool)
    survivors = cols[:, m][:, :capacity]
    out = np.full((cols.shape[0], capacity), fill, cols.dtype)
    out[:, :survivors.shape[1]] = survivors
    return out, survivors.shape[1]
