"""Hot-path sync lint: AST pass banning host round-trips in traced code.

Functions reachable from the jitted step must never force a device→host
sync — one stray ``.item()`` or ``np.asarray`` inside the traced call
graph serializes the dispatch pipeline (or worse, fails under
``shard_map``). This pass walks ``core/``, ``kernels/``, and
``parallel/``, indexes every function, builds a name-based call graph
from the jitted-step roots, and flags inside that reachable set:

  * ``.item()`` / ``.block_until_ready()`` on anything
  * ``jax.device_get`` / ``jax.block_until_ready``
  * ``np.asarray`` / ``np.array`` (numpy forces the transfer; only the
    base names ``np``/``numpy`` count — ``jnp.asarray`` stays on device)
  * ``int(...)`` / ``float(...)`` over an expression that reads data
    (an attribute or subscript other than ``.shape``/``.ndim``/
    ``.dtype``/``.size`` — casting a traced value concretizes it;
    casting static python ints is fine)
  * ``enable_x64`` / ``jax_enable_x64`` anywhere in the reachable set
    (flipping x64 recompiles the world and breaks the u32-limb contract)

Two syncs are SANCTIONED by design and allowlisted with their reasons:
the skip tier's ambiguous-tile count (sizes a static gather width) and
the deferred-exchange boundary row counter (drives epoch cadence). The
allowlist is qualname-keyed; adding an entry is a reviewed diff, not a
comment.

The call graph is deliberately over-approximate (any call to a name
``foo`` may reach ANY indexed function named ``foo``, attribute calls
match on the terminal name) — for a ban-list, false reachability only
makes the lint stricter, and the explicit module EXCLUDES keep the host
engines (whose whole job is host work) out of the graph.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

from repro.analysis.diagnostics import Diagnostic

#: packages scanned, relative to the repro package root
SCAN_DIRS = ("core", "kernels", "parallel", "serving")

#: host-side modules excluded from the graph: their job IS host work
EXCLUDES = (
    "core/engine/numpy_engine.py",   # host engine (row-exact wall time)
    "core/np_exec.py",               # legacy host executor
    "core/executor_sim.py",          # host simulator
    "kernels/filter_chain/ref.py",   # numpy reference kernel
)

#: jitted-step entry points: every function the session jits, plus the
#: shard_mapped bodies (matched by qualified name against the index)
ROOTS = (
    "FilterSession.step",
    "AdaptiveFilter.step",
    "AdaptiveFilter._step_compact",
    "AdaptiveFilter._step_skip",
    "AdaptiveFilter._step_skip_compact",
    "AdaptiveFilter.exchange_update",
    "ShardedAdaptiveFilter.sharded_step",
    "ShardedAdaptiveFilter.sharded_step_compact",
    "ShardedAdaptiveFilter._sharded_exchange",
    # the serving admission step: queue/host glue must not leak syncs
    # into the gate's drive path (the one sanctioned readback is
    # AdmissionServer._decide, allowlisted below)
    "AdmissionServer._gate_batch",
)

#: qualname → why this host sync is sanctioned. Everything else that
#: syncs inside the reachable set is a finding.
ALLOWLIST: dict[str, str] = {
    "AdaptiveFilter.skip_amb_cap":
        "THE skip-tier sync: the ambiguous-tile count sizes a static "
        "(quantized) gather width — one int per step, by design",
    "AdaptiveFilter.exchange_due":
        "THE deferred-exchange sync: the boundary row counter decides "
        "epoch cadence — one int per presumed boundary, by design",
    "AdaptiveFilter.observe_for_capacity":
        "epoch-boundary auto-capacity retune; reads accumulated stats "
        "only when an epoch just closed, never in the steady step",
    "FilterSession.step":
        "the DRIVER: orchestrates jit calls from the host, so its own "
        "body may sync between them (extracted helpers are audited "
        "individually; the traced functions it calls are the real roots)",
    "FilterSession._observe_skip_arm":
        "skip_tier='auto' tuner observation: block_until_ready gives "
        "honest per-arm wall clock — both arms pay the same sync",
    "FilterSession._sync_rows_into_epoch":
        "deferred-boundary self-heal: one sync per presumed boundary "
        "when the host row counter drifted (states advanced elsewhere)",
    "FilterSession.validate_state":
        "THE guarded-runtime integrity probe: every state invariant "
        "fused into one jitted boolean — one sync per validation "
        "boundary (never per step), driven by runtime.guard",
    "host_pred_rows":
        "trace-time constant: np.asarray reads the closed-over static "
        "PredicateSpecs tuple, never a traced array",
    "_group_matrix":
        "trace-time constant: one-hot of the static CNF groups tuple",
    "cnf_order":
        "trace-time constant: np.asarray reads the static CNF groups "
        "tuple (the ranks sorted around it stay traced xp arrays)",
    "eq_round":
        "trace-time constant: quantizes a static python threshold to its "
        "f32 packing — the arg is never a traced array",
    "bloom_key":
        "trace-time constant: Bloom bit index of a static threshold",
    "AdmissionServer._decide":
        "THE serving dequeue→decision sync: answering rejects and "
        "quarantined batches immediately with a reason code requires "
        "concretizing the gate mask on the host — one readback per "
        "micro-batch, by design",
}

_FORBIDDEN_METHODS = ("item", "block_until_ready")
_NP_NAMES = ("np", "numpy")
_SHAPE_ATTRS = ("shape", "ndim", "dtype", "size")


# ----------------------------------------------------------------- indexing
@dataclasses.dataclass
class _Fn:
    qualname: str          # "Class.method" or "function"
    name: str              # terminal name
    path: Path
    rel: str               # path relative to package root
    node: ast.AST          # FunctionDef / AsyncFunctionDef
    calls: set = dataclasses.field(default_factory=set)


def _index_functions(py_path: Path, rel: str) -> list[_Fn]:
    tree = ast.parse(py_path.read_text(), filename=str(py_path))
    fns: list[_Fn] = []

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}" if prefix else child.name
                fns.append(_Fn(qual, child.name, py_path, rel, child))
                # nested defs (shard_map locals, closures) belong to their
                # parent: violations inside them surface under the parent's
                # qualname, and their callees extend the parent's edge set
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{child.name}.")

    visit(tree, "")
    for fn in fns:
        for sub in ast.walk(fn.node):
            if isinstance(sub, ast.Call):
                callee = _callee_name(sub.func)
                if callee:
                    fn.calls.add(callee)
    return fns


def _callee_name(func: ast.AST) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _reachable(fns: list[_Fn], roots=ROOTS) -> set[str]:
    """Qualnames reachable from the roots through same-name call edges."""
    by_name: dict[str, list[_Fn]] = {}
    by_qual: dict[str, _Fn] = {}
    for fn in fns:
        by_name.setdefault(fn.name, []).append(fn)
        by_qual[fn.qualname] = fn
    seen: set[str] = set()
    frontier = [by_qual[r] for r in roots if r in by_qual]
    while frontier:
        fn = frontier.pop()
        if fn.qualname in seen:
            continue
        seen.add(fn.qualname)
        for callee in fn.calls:
            for cand in by_name.get(callee, ()):
                if cand.qualname not in seen:
                    frontier.append(cand)
    return seen


# -------------------------------------------------------------- the checker
def _reads_data(node: ast.AST) -> bool:
    """True when an int()/float() argument can hold a traced value: it
    dereferences an attribute or subscript that is not a static shape
    query. ``int(x.shape[1])`` is static; ``int(info.n_ambiguous)`` syncs.
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            if sub.attr in _SHAPE_ATTRS:
                return False      # x.shape / arr.ndim: static under trace
        if isinstance(sub, ast.Subscript):
            base = sub.value
            if isinstance(base, ast.Attribute) and base.attr in _SHAPE_ATTRS:
                continue          # x.shape[1]
            return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr not in _SHAPE_ATTRS:
            return True
    return False


def _violations_in(fn: _Fn) -> list[tuple[int, str, str]]:
    """(line, code, message) triples for one function body."""
    out = []
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call):
            callee = node.func
            if isinstance(callee, ast.Attribute):
                if callee.attr in _FORBIDDEN_METHODS:
                    out.append((node.lineno, "hotpath-host-sync",
                                f".{callee.attr}() forces a device→host "
                                "sync"))
                elif callee.attr in ("asarray", "array") and isinstance(
                        callee.value, ast.Name) \
                        and callee.value.id in _NP_NAMES:
                    out.append((node.lineno, "hotpath-host-sync",
                                f"np.{callee.attr}() copies the operand "
                                "to the host"))
                elif callee.attr in ("device_get", "block_until_ready") \
                        and isinstance(callee.value, ast.Name) \
                        and callee.value.id == "jax":
                    out.append((node.lineno, "hotpath-host-sync",
                                f"jax.{callee.attr}() is an explicit "
                                "host sync"))
            elif isinstance(callee, ast.Name):
                if callee.id in ("int", "float") and node.args and \
                        _reads_data(node.args[0]):
                    out.append((node.lineno, "hotpath-host-sync",
                                f"{callee.id}() over a data-bearing "
                                "expression concretizes a traced value"))
        name = None
        if isinstance(node, ast.Attribute):
            name = node.attr
        elif isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and node.value == "jax_enable_x64":
            name = node.value       # jax.config.update("jax_enable_x64", .)
        if name and "enable_x64" in name:
            out.append((node.lineno, "hotpath-enable-x64",
                        "enable_x64 inside the jitted call graph flips "
                        "global precision and recompiles everything"))
    return out


def lint_hotpath(package_root: str | Path | None = None,
                 roots=ROOTS, allowlist: dict | None = None
                 ) -> list[Diagnostic]:
    """Run the hot-path sync lint over an installed ``repro`` tree.

    ``package_root``: directory containing ``core/``/``kernels/``/
    ``parallel/`` (default: the imported ``repro`` package — tests point
    it at a mutated temp copy to prove detection). Findings are error
    severity: a new sync in the hot path is a broken contract, not style.
    """
    if package_root is None:
        # repro is a namespace package (no __init__.py): locate it from a
        # concrete submodule instead of repro.__file__ (which is None)
        from repro.core import plan as _plan
        package_root = Path(_plan.__file__).parent.parent
    package_root = Path(package_root)
    allow = ALLOWLIST if allowlist is None else allowlist

    fns: list[_Fn] = []
    for sub in SCAN_DIRS:
        base = package_root / sub
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            rel = py.relative_to(package_root).as_posix()
            if rel in EXCLUDES:
                continue
            fns.extend(_index_functions(py, rel))

    reachable = _reachable(fns, roots)
    diags: list[Diagnostic] = []
    # a stale allowlist entry is an ERROR, not noise: it means a sanctioned
    # sync was renamed/removed and its exemption now silently dangles —
    # the next function to take the name inherits a free pass nobody
    # reviewed. Keys must resolve by qualname or bare name in the index.
    known_quals = {fn.qualname for fn in fns}
    known_names = {fn.name for fn in fns}
    for key in sorted(allow):
        if key not in known_quals and key not in known_names:
            diags.append(Diagnostic(
                "hotpath-stale-allowlist", "error", f"allowlist:{key}",
                f"ALLOWLIST entry {key!r} matches no indexed function "
                "(qualname or bare name) under "
                f"{'/'.join(SCAN_DIRS)} — the sanctioned sync it "
                "described was renamed or removed",
                "delete the entry, or re-key it to the function's current "
                "qualname"))
    for fn in fns:
        if fn.qualname not in reachable:
            continue
        if fn.qualname in allow or fn.name in allow:
            continue
        for line, code, msg in _violations_in(fn):
            diags.append(Diagnostic(
                code, "error", f"{fn.rel}:{line}",
                f"{msg} (in {fn.qualname}, reachable from the jitted "
                "step)",
                "hoist the host work into the session driver between jit "
                "calls, or — if this sync is genuinely sanctioned — add "
                "the qualname to hotpath_lint.ALLOWLIST with its reason"))
    return diags
