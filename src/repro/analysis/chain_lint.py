"""Chain semantics linter: interval + domain analysis over CNF chains.

The paper's adaptive reordering assumes every predicate can matter; real
(drifting, hand-edited, multi-tenant) chains routinely carry predicates
that provably cannot — unsatisfiable ranges, subsumed duplicates,
always-true guards — which the runtime then spends epochs "learning" to
demote. Pruning/canonicalizing BEFORE adaptive re-optimization is where
the cheap wins are (Liu & Ives, arXiv 1409.6288), so this linter runs at
plan-compile time (``build_session``) and from the CLI.

Semantics are the engines' row-level semantics on **float32** values
(``PredicateSpecs`` packs thresholds to f32, so every proof here quantizes
with ``np.float32`` first — reasoning from the python-float64
``Predicate.t1`` can prove facts the runtime contradicts; see the
linter↔resolver cross-check in tests/test_analysis.py):

  GT        x > t1            satisfying set  (t1, +inf)
  LT        x < t1                            (-inf, t1)
  BETWEEN   t1 < x < t2                       (t1, t2)
  EQ        round(x) == round32(t1) =: r      exactly  [r-0.5, r+0.5]-ish:
            over-approx [r-0.5, r+0.5] closed, under-approx (r-0.5, r+0.5)
            open (the half-even tie at the endpoints falls between)
  HASHMIX   opaque (the mix destroys ordering): over-approx is the whole
            line, under-approx is empty — it never participates in proofs.

Every check uses the approximation in the sound direction: emptiness /
unsatisfiability intersects OVER-approximations (superset ∩ superset = ∅
⇒ exact ∩ exact = ∅); containment (subsumption, always-true) compares an
over-approximation against an under-approximation. ``lint_tile_proofs``
applies the same intervals to zone-map [mn, mx] tiles — the independent
re-derivation of ``skip_tier.resolve_tiles`` that the conformance
property test cross-checks.

Diagnostic codes:

  chain-unsat-predicate   empty satisfying set (e.g. BETWEEN with t2<=t1)
  chain-unsat-group       every member of an OR-group is unsatisfiable
  chain-unsat-conjunction contradictory AND-ed constraints on one column
  chain-subsumed          AND-level: a predicate implied by a stricter one
  chain-subsumed-member   OR-level: a member contained in a wider member
  chain-always-true       predicate passes the whole declared column domain
  chain-group-always-true an OR-group containing an always-true member
  chain-bloom-collision   distinct EQ keys sharing a Bloom bit (mod 128)
  chain-hashmix-shadows   HASHMIX member disables a group's tile-fail proof
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

from repro.analysis.diagnostics import Diagnostic
from repro.core import predicates as pred_lib
from repro.core import skip_tier as skip_tier_lib
from repro.core.predicates import Predicate

_INF = float("inf")


# ============================================================ interval algebra
class Ivl(NamedTuple):
    """An interval of the f32 number line, possibly open at either end."""

    lo: float
    hi: float
    lo_open: bool = False
    hi_open: bool = False

    def is_empty(self) -> bool:
        """Provably empty over float32 values.

        Open-open intervals are additionally empty when no f32 value fits
        strictly between the (f32) endpoints — (t1, nextafter(t1)) holds
        no representable value even though t1 < t2.
        """
        if np.isnan(self.lo) or np.isnan(self.hi):
            return False                    # unknown endpoints prove nothing
        if self.lo > self.hi:
            return True
        if self.lo == self.hi:
            return self.lo_open or self.hi_open
        if self.lo_open and self.hi_open and np.isfinite(self.lo):
            nxt = float(np.nextafter(np.float32(self.lo), np.float32(_INF)))
            return nxt >= self.hi
        return False

    def intersect(self, other: "Ivl") -> "Ivl":
        if (self.lo, not self.lo_open) >= (other.lo, not other.lo_open):
            lo, lo_open = self.lo, self.lo_open
        else:
            lo, lo_open = other.lo, other.lo_open
        if (self.hi, self.hi_open) <= (other.hi, other.hi_open):
            hi, hi_open = self.hi, self.hi_open
        else:
            hi, hi_open = other.hi, other.hi_open
        return Ivl(lo, hi, lo_open, hi_open)

    def hull(self, other: "Ivl") -> "Ivl":
        if (self.lo, not self.lo_open) <= (other.lo, not other.lo_open):
            lo, lo_open = self.lo, self.lo_open
        else:
            lo, lo_open = other.lo, other.lo_open
        if (self.hi, self.hi_open) >= (other.hi, other.hi_open):
            hi, hi_open = self.hi, self.hi_open
        else:
            hi, hi_open = other.hi, other.hi_open
        return Ivl(lo, hi, lo_open, hi_open)

    def contains(self, other: "Ivl") -> bool:
        """other ⊆ self (an empty ``other`` is contained in anything)."""
        if other.is_empty():
            return True
        lo_ok = self.lo < other.lo or (
            self.lo == other.lo and (not self.lo_open or other.lo_open))
        hi_ok = self.hi > other.hi or (
            self.hi == other.hi and (not self.hi_open or other.hi_open))
        return lo_ok and hi_ok

    def disjoint(self, other: "Ivl") -> bool:
        return self.intersect(other).is_empty()


FULL = Ivl(-_INF, _INF)
EMPTY = Ivl(1.0, 0.0)


def _f32(x: float) -> float:
    return float(np.float32(x))


def sat_over(p: Predicate) -> Ivl:
    """Superset of the f32 values satisfying ``p`` (thresholds f32-packed)."""
    t1, t2 = _f32(p.t1), _f32(p.t2)
    if p.op == pred_lib.OP_GT:
        return Ivl(t1, _INF, lo_open=True)
    if p.op == pred_lib.OP_LT:
        return Ivl(-_INF, t1, hi_open=True)
    if p.op == pred_lib.OP_BETWEEN:
        return Ivl(t1, t2, lo_open=True, hi_open=True)
    if p.op == pred_lib.OP_EQ:
        if not np.isfinite(t1):
            return FULL
        r = skip_tier_lib.eq_round(t1)
        # round(x)==r ⇒ |x-r| <= 0.5 regardless of f32 spacing (r is the
        # nearest integer to x); the half-even ties sit on the endpoints
        return Ivl(r - 0.5, r + 0.5)
    return FULL                              # OP_HASHMIX: opaque


def sat_under(p: Predicate) -> Ivl:
    """Subset of the f32 values satisfying ``p`` (∅ when nothing provable)."""
    t1, t2 = _f32(p.t1), _f32(p.t2)
    if p.op == pred_lib.OP_GT:
        return Ivl(t1, _INF, lo_open=True)
    if p.op == pred_lib.OP_LT:
        return Ivl(-_INF, t1, hi_open=True)
    if p.op == pred_lib.OP_BETWEEN:
        return Ivl(t1, t2, lo_open=True, hi_open=True)
    if p.op == pred_lib.OP_EQ:
        if not np.isfinite(t1):
            return EMPTY
        r = skip_tier_lib.eq_round(t1)
        return Ivl(r - 0.5, r + 0.5, lo_open=True, hi_open=True)
    return EMPTY                             # OP_HASHMIX: opaque


def _provable(p: Predicate) -> bool:
    return p.op != pred_lib.OP_HASHMIX


# ================================================================= the linter
def _groups_of(predicates: Sequence[Predicate]) -> list[list[int]]:
    """Predicate indices per OR-group, in first-appearance order (the same
    dense normalization ``predicates.pack`` applies)."""
    gids = pred_lib.normalize_groups(predicates)
    members: dict[int, list[int]] = {}
    for i, g in enumerate(gids):
        members.setdefault(g, []).append(i)
    return [members[g] for g in sorted(members)]


def _loc(i: int, p: Predicate) -> str:
    return f"chain[{i}]:{p.name}"


def _group_label(predicates, members) -> str:
    g = predicates[members[0]].group
    return repr(g) if g is not None else f"#{members[0]}"


def lint_chain(predicates: Sequence[Predicate],
               domains: dict[int, tuple[float, float]] | None = None,
               ) -> list[Diagnostic]:
    """All chain-semantics findings for one CNF chain.

    ``domains`` optionally maps column index → closed [lo, hi] bounds the
    data layer guarantees (e.g. the paper stream's string-hash column is
    [0, 2^24)); always-true detection only fires with a declared domain.
    """
    preds = list(predicates)
    diags: list[Diagnostic] = []
    groups = _groups_of(preds)
    over = [sat_over(p) for p in preds]
    under = [sat_under(p) for p in preds]

    # ---- unsatisfiable predicates / groups --------------------------------
    unsat = [ov.is_empty() for ov in over]
    for i, p in enumerate(preds):
        if unsat[i]:
            diags.append(Diagnostic(
                "chain-unsat-predicate", "error", _loc(i, p),
                f"predicate can never pass: satisfying set of "
                f"{p.describe()} is empty over f32",
                "fix the thresholds (BETWEEN needs t1 < t2 with room for "
                "an f32 value between) or delete the predicate"))
    for members in groups:
        if len(members) > 1 and all(unsat[i] for i in members):
            label = _group_label(preds, members)
            diags.append(Diagnostic(
                "chain-unsat-group", "error", f"group {label}",
                f"every member of OR-group {label} is individually "
                f"unsatisfiable — the group cuts all rows",
                "fix at least one member or delete the group"))

    # ---- contradictory conjunction per column -----------------------------
    # only groups whose members ALL constrain the same column can constrain
    # that column (a mixed-column group can be satisfied elsewhere); the
    # over-approx of an OR is the hull of its members' over-approxes.
    by_col: dict[int, list[tuple[str, Ivl]]] = {}
    for members in groups:
        cols = {preds[i].column for i in members}
        if len(cols) != 1:
            continue
        if any(unsat[i] for i in members) and not all(
                unsat[i] for i in members):
            # hull over live members only (dead ones add nothing to the OR)
            live = [i for i in members if not unsat[i]]
        else:
            live = members
        gov = over[live[0]]
        for i in live[1:]:
            gov = gov.hull(over[i])
        label = _group_label(preds, members) if len(members) > 1 \
            else preds[members[0]].name
        by_col.setdefault(cols.pop(), []).append((label, gov))
    for col, entries in by_col.items():
        if len(entries) < 2:
            continue
        acc = FULL
        for _, iv in entries:
            acc = acc.intersect(iv)
        if acc.is_empty() and not any(iv.is_empty() for _, iv in entries):
            names = ", ".join(label for label, _ in entries)
            diags.append(Diagnostic(
                "chain-unsat-conjunction", "error", f"column {col}",
                f"AND-ed constraints on column {col} are contradictory: "
                f"{names} admit no common f32 value — the chain cuts "
                "every row",
                "loosen one of the conflicting bounds or delete one "
                "conjunct"))

    # ---- subsumption ------------------------------------------------------
    singles = [m[0] for m in groups if len(m) == 1]
    reported: set[int] = set()
    for j in singles:                        # j: the redundant candidate
        if unsat[j] or not _provable(preds[j]):
            continue
        for i in singles:
            if i == j or unsat[i] or not _provable(preds[i]):
                continue
            if preds[i].column != preds[j].column:
                continue
            # p_i ⊆ p_j  ⇒  p_j is implied by p_i (AND-level redundancy);
            # identical sets keep the EARLIER statement
            if under[j].contains(over[i]) and (
                    not under[i].contains(over[j]) or i < j):
                if j not in reported:
                    reported.add(j)
                    diags.append(Diagnostic(
                        "chain-subsumed", "warning", _loc(j, preds[j]),
                        f"{preds[j].name!r} is implied by the stricter "
                        f"{preds[i].name!r} on column {preds[j].column} — "
                        "it can never cut a row the chain keeps",
                        "delete it (the canonicalizer does; note the plan "
                        "fingerprint changes — see README 'Static "
                        "analysis')"))
                break
    for members in groups:
        if len(members) < 2:
            continue
        for j in members:                    # j: the redundant member
            if unsat[j] or not _provable(preds[j]):
                continue
            for i in members:
                if i == j or unsat[i] or not _provable(preds[i]):
                    continue
                if preds[i].column != preds[j].column:
                    continue
                # OR-level: member j ⊆ member i ⇒ j adds nothing
                if under[i].contains(over[j]) and (
                        not under[j].contains(over[i]) or i < j):
                    diags.append(Diagnostic(
                        "chain-subsumed-member", "warning",
                        _loc(j, preds[j]),
                        f"OR-member {preds[j].name!r} is contained in the "
                        f"wider {preds[i].name!r} — it can never pass a "
                        "row the group rejects",
                        "delete the narrower member"))
                    break

    # ---- always-true under declared domains -------------------------------
    always = [False] * len(preds)
    if domains:
        for i, p in enumerate(preds):
            dom = domains.get(p.column)
            if dom is None or not _provable(p):
                continue
            if under[i].contains(Ivl(_f32(dom[0]), _f32(dom[1]))):
                always[i] = True
        for members in groups:
            hits = [i for i in members if always[i]]
            if not hits:
                continue
            if len(members) == 1:
                i, p = hits[0], preds[hits[0]]
                diags.append(Diagnostic(
                    "chain-always-true", "warning", _loc(i, p),
                    f"{p.name!r} passes the entire declared domain "
                    f"{domains[p.column]} of column {p.column} — it never "
                    "cuts and only costs",
                    "delete it, or fix the domain declaration if the data "
                    "layer's bounds changed"))
            else:
                label = _group_label(preds, members)
                names = ", ".join(preds[i].name for i in hits)
                diags.append(Diagnostic(
                    "chain-group-always-true", "warning", f"group {label}",
                    f"OR-group {label} contains always-true member(s) "
                    f"{names} — the whole group never cuts",
                    "delete the group (an OR with a tautological member "
                    "is a tautology)"))

    # ---- Bloom key collisions --------------------------------------------
    # the Bloom bit array is per-column (``bloom[col, :, key]``), so only
    # same-column EQ keys can collide
    seen_keys: dict[tuple[int, int], tuple[int, float]] = {}
    for i, p in enumerate(preds):
        if p.op != pred_lib.OP_EQ or not np.isfinite(_f32(p.t1)):
            continue
        r = skip_tier_lib.eq_round(_f32(p.t1))
        key = skip_tier_lib.bloom_key(_f32(p.t1))
        prev = seen_keys.get((p.column, key))
        if prev is not None and prev[1] != r:
            j, rj = prev
            diags.append(Diagnostic(
                "chain-bloom-collision", "warning", _loc(i, p),
                f"EQ keys {rj:g} ({preds[j].name!r}) and {r:g} "
                f"({p.name!r}) collide under the skip-tier Bloom "
                f"quantizer (both ≡ {key} mod "
                f"{skip_tier_lib.BLOOM_BITS}) — tiles holding one key "
                "are never Bloom-skipped for the other",
                "pick equality keys distinct modulo 128, or accept the "
                "weaker zonemap-only fail proof for these"))
        else:
            seen_keys[(p.column, key)] = (i, r)

    # ---- HASHMIX shadowing a group's tile-fail proof ----------------------
    for members in groups:
        if len(members) < 2:
            continue
        mix = [i for i in members if not _provable(preds[i])]
        provable = [i for i in members if _provable(preds[i])]
        if mix and provable:
            label = _group_label(preds, members)
            diags.append(Diagnostic(
                "chain-hashmix-shadows", "info", f"group {label}",
                f"OR-group {label} mixes HASHMIX member(s) "
                f"({', '.join(preds[i].name for i in mix)}) with provable "
                "ones — a group tile-fail proof needs EVERY member "
                "provably failed, so the skip tier can never fail-skip "
                "this group's tiles",
                "expected for regex-like members; to recover fail-skips, "
                "split the HASHMIX into its own AND-ed group if semantics "
                "allow"))

    return diags


# ============================================================= canonicalizer
@dataclasses.dataclass(frozen=True)
class CanonResult:
    """``canonicalize_chain`` output: the rewritten chain + consequences."""

    predicates: tuple
    removed: tuple            # (index, Predicate, code) per dropped entry
    diagnostics: tuple
    fingerprint_note: str

    @property
    def changed(self) -> bool:
        return bool(self.removed)


def canonicalize_chain(predicates: Sequence[Predicate],
                       domains: dict[int, tuple[float, float]] | None = None,
                       ) -> CanonResult:
    """Drop provably-redundant predicates; report the fingerprint fallout.

    Removes AND-subsumed predicates, OR-subsumed members, always-true
    singletons, and whole always-true groups. Unsatisfiable findings are
    NOT auto-fixed (deleting them silently would change which rows
    survive) — they stay as errors for a human. Because
    ``FilterPlan.fingerprint`` hashes the chain, any removal changes the
    fingerprint: checkpoints written under the old chain refuse to restore
    into the canonical plan, and the note says so.
    """
    preds = list(predicates)
    diags = lint_chain(preds, domains=domains)
    drop: dict[int, str] = {}
    by_name_loc = {}
    for i, p in enumerate(preds):
        by_name_loc[_loc(i, p)] = i
    group_members = {_group_label(preds, m): m for m in _groups_of(preds)
                     if len(m) > 1}
    for d in diags:
        if d.code in ("chain-subsumed", "chain-subsumed-member",
                      "chain-always-true"):
            i = by_name_loc.get(d.location)
            if i is not None:
                drop.setdefault(i, d.code)
        elif d.code == "chain-group-always-true":
            label = d.location.removeprefix("group ")
            for i in group_members.get(label, ()):
                drop.setdefault(i, d.code)
    kept = [p for i, p in enumerate(preds) if i not in drop]
    removed = tuple((i, preds[i], code) for i, code in sorted(drop.items()))
    if not removed:
        note = "chain already canonical: fingerprint unchanged, " \
               "checkpoints stay compatible"
    elif not kept:
        note = "every predicate is provably redundant — refusing to emit " \
               "an empty chain; fix the chain by hand"
        kept = preds
        removed = ()
    else:
        note = (
            f"canonicalization removed {len(removed)} predicate(s) "
            f"({', '.join(p.name for _, p, _ in removed)}); "
            "FilterPlan.fingerprint() changes, so checkpoints written "
            "under the old chain will refuse to restore — migrate by "
            "restoring under the OLD plan and re-saving from a session "
            "built on the canonical one")
    return CanonResult(tuple(kept), removed, tuple(diags), note)


# ===================================================== zone-map tile proofs
def lint_tile_proofs(predicates: Sequence[Predicate],
                     mins: np.ndarray, maxs: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Interval-analysis re-derivation of the skip tier's tri-state proofs.

    ``mins``/``maxs``: f32[C, T] zone maps. Returns (pass bool[T],
    fail bool[T]) — a tile provably passes a member iff its [mn, mx] hull
    fits inside the member's under-approximated satisfying set, provably
    fails iff the hull is disjoint from the over-approximation; group/chain
    folds are the CNF folds of ``skip_tier.resolve_tiles``. This is the
    linter side of the resolver↔linter conformance contract: a tile proved
    always-fail here must never be classified pass by the resolver (and
    vice versa) — pinned by the property test in tests/test_analysis.py.

    No Bloom input: Bloom bits only ADD fail proofs, so the contract stays
    one-directional against a Bloom-armed resolver.
    """
    mins = np.asarray(mins, np.float32)
    maxs = np.asarray(maxs, np.float32)
    n_tiles = mins.shape[1]
    preds = list(predicates)
    groups = _groups_of(preds)
    pass_t = np.ones((n_tiles,), bool)
    fail_t = np.zeros((n_tiles,), bool)
    for members in groups:
        gp = np.zeros((n_tiles,), bool)
        gf = np.ones((n_tiles,), bool)
        for i in members:
            p = preds[i]
            un, ov = sat_under(p), sat_over(p)
            mp = np.zeros((n_tiles,), bool)
            mf = np.zeros((n_tiles,), bool)
            for t in range(n_tiles):
                mn = float(mins[p.column, t])
                mx = float(maxs[p.column, t])
                if np.isnan(mn) or np.isnan(mx):
                    continue                 # NaN lanes: never provable
                hull = Ivl(mn, mx)
                mp[t] = un.contains(hull)
                mf[t] = ov.disjoint(hull)
            gp |= mp
            gf &= mf
        pass_t &= gp
        fail_t |= gf
    return pass_t & ~fail_t, fail_t
