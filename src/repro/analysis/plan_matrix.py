"""Exhaustive plan-space audit: every valid FilterPlan combination.

The representative five-plan matrix in the CLI covers each audited
contract once; this pass closes the gap Lyu et al. (arxiv 2403.00995)
call out for large adaptive parameter spaces — the tooling must sweep the
space itself, not the default configuration. Three stages:

  enumerate    the full engine × scope × exchange × shards × compaction ×
               tokenize × skip-tier × cost-mode product, filtered through
               ``FilterPlan``'s constructor (``validate_combo`` IS the
               validity oracle — this pass cannot drift from it);
  dedupe       by *compiled identity*: the tuple of properties that
               change which XLA modules a session compiles (host engines
               fall back to the jnp step; 'auto' capacity compiles the
               same module family as a fixed width; 'auto' skip tier
               resolves to its measured on-arm). Two plans with equal
               identity compile byte-identical module structures, so
               auditing one audits both;
  audit        drive ``hlo_audit.audit_plan`` + ``jaxpr_lint`` over the
               deduped set under a compile budget, selected greedily for
               axis-value coverage (every identity-axis value appears in
               at least one audited plan before any value appears twice).
               Whatever the budget excludes is LOGGED, never silently
               dropped.

Also home of ``fingerprint_coverage``: the checkpoint-compatibility
contract that every ``FilterPlan`` field is either hashed by
``fingerprint()`` or declared in ``plan.FINGERPRINT_RUNTIME_ONLY`` —
proven behaviorally, by constructing plan pairs that differ in exactly
one field and comparing fingerprints.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.diagnostics import Diagnostic

#: default compile budget for the matrix audit (CI overrides via --budget)
DEFAULT_BUDGET = 12


# -------------------------------------------------------------- enumeration
def _scope_exchange():
    yield "per_shard", "eager"
    yield "per_batch", "eager"
    for ex in ("eager", "deferred", "deferred-async"):
        yield "centralized", ex


def enumerate_plans():
    """Every valid plan combination as (name, FilterPlan), deterministic.

    Validity is decided by constructing the plan — ``FilterPlan.__post_init__``
    funnels through ``validate_combo``, the single source of cross-field
    rules — so this enumeration can never disagree with the validator.
    """
    import jax

    from repro.core import engine as engine_lib
    from repro.core.plan import FilterPlan, TokenizeSpec
    from repro.core.predicates import paper_filters_4

    preds = paper_filters_4("fig1")
    shard_choices = (1, 4) if jax.device_count() >= 4 else (1,)
    compact_choices = (("plain", False, None), ("batchcap", True, None),
                       ("cap512", True, 512), ("autocap", True, "auto"))
    out = []
    for engine in engine_lib.available_engines():
        for scope, exchange in _scope_exchange():
            for shards in shard_choices:
                for cname, compact, capacity in compact_choices:
                    for tokenize in (None, TokenizeSpec(32000)):
                        for skip in ("off", "zonemap", "zonemap+bloom",
                                     "auto"):
                            for cost in ("static", "measured"):
                                name = (f"{engine}/{scope}/{exchange}/"
                                        f"sh{shards}/{cname}/"
                                        f"tok{int(tokenize is not None)}/"
                                        f"{skip}/{cost}")
                                try:
                                    plan = FilterPlan(
                                        predicates=preds, engine=engine,
                                        scope=scope, exchange=exchange,
                                        shards=shards, compact=compact,
                                        capacity=capacity,
                                        tokenize=tokenize, skip_tier=skip,
                                        cost_mode=cost)
                                except ValueError:
                                    continue
                                out.append((name, plan))
    return out


# --------------------------------------------------------- compiled identity
def compiled_identity(plan) -> tuple:
    """The properties that decide which XLA module structures a session
    compiles. Equal identity ⇒ byte-identical module structure ⇒ one
    audit covers the whole equivalence class."""
    from repro.core.engine import get_engine
    from repro.core.predicates import OP_EQ

    eng = get_engine(plan.engine)
    step_engine = plan.engine if eng.traceable else "jnp"   # host fallback
    cap = plan.capacity
    cap_kind = "batch" if cap is None else "fixed"          # auto ≡ fixed:
    # the auto tuner re-quantizes WIDTH, not module structure
    skip = plan.skip_tier
    if skip == "auto":                                      # tuner's on-arm
        skip = "zonemap+bloom" \
            if any(p.op == OP_EQ for p in plan.predicates) else "zonemap"
    return (("engine", step_engine), ("scope", plan.scope),
            ("exchange", plan.exchange), ("shards", plan.shards),
            ("compact", cap_kind if plan.compact else "off"),
            ("tokenize", plan.tokenize is not None), ("skip", skip),
            ("cost", plan.cost_mode))


def dedupe_plans(named):
    """First representative per compiled identity, enumeration order."""
    seen, out = set(), []
    for name, plan in named:
        key = compiled_identity(plan)
        if key in seen:
            continue
        seen.add(key)
        out.append((name, plan, key))
    return out


def select_within_budget(deduped, budget: int):
    """Greedy axis-value coverage: pick the plan adding the most unseen
    (axis, value) pairs until the budget is spent or coverage saturates.
    Returns (selected, skipped) — both deterministic."""
    if budget <= 0 or budget >= len(deduped):
        return list(deduped), []
    covered: set = set()
    remaining = list(deduped)
    selected = []
    while remaining and len(selected) < budget:
        best_i, best_gain = 0, -1
        for i, (_, _, key) in enumerate(remaining):
            gain = len(set(key) - covered)
            if gain > best_gain:
                best_i, best_gain = i, gain
        if best_gain == 0:
            covered = set()  # every axis value covered — start a fresh
            continue         # round so the rest of the budget still buys
            # maximally-diverse COMBINATIONS, not arbitrary ones
        pick = remaining.pop(best_i)
        covered |= set(pick[2])
        selected.append(pick)
    return selected, remaining


# ------------------------------------------------------------------- audits
def matrix_audit(*, budget: int = DEFAULT_BUDGET, rows_per_shard: int = 512,
                 log=print) -> list[Diagnostic]:
    """Compile-audit (HLO) + IR-lint (jaxpr) the deduped valid plan space
    under ``budget`` compiles. Exclusions are logged, never silent."""
    from repro.analysis import hlo_audit, jaxpr_lint

    named = enumerate_plans()
    deduped = dedupe_plans(named)
    selected, skipped = select_within_budget(deduped, budget)
    log(f"matrix: {len(named)} valid plan combination(s), "
        f"{len(deduped)} distinct compiled identities, auditing "
        f"{len(selected)} (budget {budget or 'unlimited'})")
    if skipped:
        log("matrix: identity-equivalent or beyond budget, NOT audited: "
            + ", ".join(name for name, _, _ in skipped[:8])
            + (f" … +{len(skipped) - 8} more" if len(skipped) > 8 else ""))
    diags: list[Diagnostic] = []
    for name, plan, _ in selected:
        found = list(hlo_audit.audit_plan(plan,
                                          rows_per_shard=rows_per_shard))
        found += jaxpr_lint.lint_plan_jaxprs(plan,
                                             rows_per_shard=rows_per_shard)
        log(f"matrix: {name}: {len(found)} finding(s)")
        diags += found
    diags += fingerprint_coverage()
    return diags


# ----------------------------------------------------- fingerprint coverage
def _probe_pairs():
    """Per-field (base_kwargs, variant_kwargs) plan pairs differing in
    exactly that field — both sides valid by construction."""
    from repro.core.ordering import OrderingConfig
    from repro.core.plan import TokenizeSpec
    from repro.core.predicates import paper_filters_4

    preds = paper_filters_4("fig1")
    return {
        "predicates": ({}, {"predicates": preds[:-1]}),
        "ordering": ({}, {"ordering": OrderingConfig(collect_rate=77)}),
        "engine": ({"engine": "jnp"}, {"engine": "pallas"}),
        "scope": ({"scope": "per_shard"}, {"scope": "per_batch"}),
        "shards": ({"shards": 1}, {"shards": 2}),
        "axis_name": ({"axis_name": "data"}, {"axis_name": "x"}),
        "adaptive": ({"adaptive": True}, {"adaptive": False}),
        "cost_mode": ({"engine": "numpy", "cost_mode": "static"},
                      {"engine": "numpy", "cost_mode": "measured"}),
        "compact": ({"compact": False}, {"compact": True}),
        "capacity": ({"compact": True, "capacity": None},
                     {"compact": True, "capacity": 256}),
        "slack": ({"slack": 1.5}, {"slack": 2.0}),
        "exchange": ({"scope": "centralized", "exchange": "eager"},
                     {"scope": "centralized", "exchange": "deferred"}),
        "tokenize": ({"compact": True, "tokenize": None},
                     {"compact": True, "tokenize": TokenizeSpec(1000)}),
        "skip_tier": ({"skip_tier": "off"}, {"skip_tier": "zonemap"}),
    }


def fingerprint_coverage(runtime_only=None) -> list[Diagnostic]:
    """Every ``FilterPlan`` field must be hashed by ``fingerprint()`` XOR
    declared runtime-only — the checkpoint-compatibility partition.

    Behavioral proof per field: build two valid plans differing only in
    that field and compare fingerprints. A field with no probe pair and
    no declaration is itself an error — a brand-new field cannot ship
    without picking a side. ``runtime_only`` overrides the declared set
    (the seeded-defect tests simulate drifted declarations with it).
    """
    from repro.core import plan as plan_lib
    from repro.core.predicates import paper_filters_4

    declared = plan_lib.FINGERPRINT_RUNTIME_ONLY \
        if runtime_only is None else frozenset(runtime_only)
    probes = _probe_pairs()
    preds = paper_filters_4("fig1")

    def build(kw):
        kw = dict(kw)
        kw.setdefault("predicates", preds)
        return plan_lib.FilterPlan(**kw)

    diags: list[Diagnostic] = []
    for field in dataclasses.fields(plan_lib.FilterPlan):
        name = field.name
        loc = f"plan:fingerprint:{name}"
        if name not in probes:
            if name not in declared:
                diags.append(Diagnostic(
                    "plan-fingerprint-unprobed", "error", loc,
                    f"FilterPlan.{name} has no fingerprint-coverage probe "
                    "and is not declared runtime-only — its checkpoint-"
                    "compatibility contract is undefined",
                    "add a probe pair to plan_matrix._probe_pairs() (if "
                    "the field is semantic) or list it in "
                    "plan.FINGERPRINT_RUNTIME_ONLY (if execution-only)"))
            continue
        base_kw, var_kw = probes[name]
        hashed = build(base_kw).fingerprint() != build(var_kw).fingerprint()
        if hashed and name in declared:
            diags.append(Diagnostic(
                "plan-fingerprint-conflict", "error", loc,
                f"FilterPlan.{name} is declared runtime-only but "
                "fingerprint() hashes it — checkpoints would refuse to "
                "move across a field the declaration promises is portable",
                "remove the field from FINGERPRINT_RUNTIME_ONLY or stop "
                "hashing it"))
        elif not hashed and name not in declared:
            diags.append(Diagnostic(
                "plan-fingerprint-uncovered", "error", loc,
                f"FilterPlan.{name} is neither hashed by fingerprint() "
                "nor declared runtime-only — changing it would silently "
                "load incompatible checkpoints",
                "hash the field in fingerprint() or declare it in "
                "plan.FINGERPRINT_RUNTIME_ONLY"))
    return diags
