"""The one Diagnostic ABI every analysis pass emits.

All three passes (``chain_lint``, ``hlo_audit``, ``hotpath_lint``) return
flat lists of ``Diagnostic`` records — code, severity, location, message,
fix hint — so the CLI, the ``build_session`` lint hook, and the test
harness consume one shape regardless of which pass produced a finding.

Severity contract:

  error    the plan/program is wrong (unsatisfiable chain, collective in a
           collective-free module, host sync in the hot path). The CLI
           exits nonzero; ``build_session`` raises.
  warning  provably wasted work (subsumed / always-true predicates, Bloom
           key collisions). The CLI prints and exits 0 (nonzero under
           ``--strict``); ``build_session`` warns once per finding.
  info     advisory structure notes (e.g. a HASHMIX member shadowing a
           group's tile-fail proof). Never fatal.
"""

from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding from one analysis pass.

    ``code`` is a stable kebab-case identifier (``chain-unsat-group``,
    ``hlo-step-collective``, ``hotpath-host-sync``, ...) — tests and CI
    match on it, never on the prose. ``location`` is ``file.py:LINE`` for
    source findings and a chain/plan coordinate (``chain[2]:int_lo``,
    ``plan:step-hlo``) for semantic ones.
    """

    code: str
    severity: str
    location: str
    message: str
    fix_hint: str = ""

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"bad severity {self.severity!r}; pick from {SEVERITIES}")

    def render(self) -> str:
        hint = f"\n    hint: {self.fix_hint}" if self.fix_hint else ""
        return f"[{self.severity:7s}] {self.code} @ {self.location}: " \
               f"{self.message}{hint}"


def errors(diags) -> list[Diagnostic]:
    return [d for d in diags if d.severity == "error"]


def warnings_of(diags) -> list[Diagnostic]:
    return [d for d in diags if d.severity == "warning"]


def render_report(diags, *, title: str | None = None) -> str:
    """Human-readable report, errors first, stable within severity."""
    order = {s: i for i, s in enumerate(SEVERITIES)}
    lines = [] if title is None else [f"== {title}"]
    for d in sorted(diags, key=lambda d: (order[d.severity], d.location,
                                          d.code)):
        lines.append(d.render())
    if not diags:
        lines.append("clean (no findings)")
    return "\n".join(lines)


def to_json(diags) -> list[dict]:
    return [dataclasses.asdict(d) for d in diags]
