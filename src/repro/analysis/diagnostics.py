"""The one Diagnostic ABI every analysis pass emits.

All three passes (``chain_lint``, ``hlo_audit``, ``hotpath_lint``) return
flat lists of ``Diagnostic`` records — code, severity, location, message,
fix hint — so the CLI, the ``build_session`` lint hook, and the test
harness consume one shape regardless of which pass produced a finding.

Severity contract:

  error    the plan/program is wrong (unsatisfiable chain, collective in a
           collective-free module, host sync in the hot path). The CLI
           exits nonzero; ``build_session`` raises.
  warning  provably wasted work (subsumed / always-true predicates, Bloom
           key collisions). The CLI prints and exits 0 (nonzero under
           ``--strict``); ``build_session`` warns once per finding.
  info     advisory structure notes (e.g. a HASHMIX member shadowing a
           group's tile-fail proof). Never fatal.
"""

from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding from one analysis pass.

    ``code`` is a stable kebab-case identifier (``chain-unsat-group``,
    ``hlo-step-collective``, ``hotpath-host-sync``, ...) — tests and CI
    match on it, never on the prose. ``location`` is ``file.py:LINE`` for
    source findings and a chain/plan coordinate (``chain[2]:int_lo``,
    ``plan:step-hlo``) for semantic ones.
    """

    code: str
    severity: str
    location: str
    message: str
    fix_hint: str = ""

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"bad severity {self.severity!r}; pick from {SEVERITIES}")

    def render(self) -> str:
        hint = f"\n    hint: {self.fix_hint}" if self.fix_hint else ""
        return f"[{self.severity:7s}] {self.code} @ {self.location}: " \
               f"{self.message}{hint}"


def errors(diags) -> list[Diagnostic]:
    return [d for d in diags if d.severity == "error"]


def warnings_of(diags) -> list[Diagnostic]:
    return [d for d in diags if d.severity == "warning"]


def render_report(diags, *, title: str | None = None) -> str:
    """Human-readable report, errors first, stable within severity."""
    order = {s: i for i, s in enumerate(SEVERITIES)}
    lines = [] if title is None else [f"== {title}"]
    for d in sorted(diags, key=lambda d: (order[d.severity], d.location,
                                          d.code)):
        lines.append(d.render())
    if not diags:
        lines.append("clean (no findings)")
    return "\n".join(lines)


def canonical(diags) -> list[Diagnostic]:
    """Deterministic order + exact-duplicate removal.

    The matrix audit runs the same passes over many plans, so findings
    rooted in shared code (a predicate-chain warning, a kernel note)
    surface once per plan; exact duplicates carry no information and make
    ``--json`` output depend on audit order. Canonical form — stable sort
    by (location, code, severity, message, fix_hint), then dedupe — makes
    the report a *set*, byte-reproducible across runs and pass orderings.
    Pinned by ``tests/test_ir_analysis.py``.
    """
    order = {s: i for i, s in enumerate(SEVERITIES)}
    seen: set[tuple] = set()
    out: list[Diagnostic] = []
    for d in sorted(diags, key=lambda d: (d.location, d.code,
                                          order[d.severity], d.message,
                                          d.fix_hint)):
        key = (d.code, d.severity, d.location, d.message, d.fix_hint)
        if key in seen:
            continue
        seen.add(key)
        out.append(d)
    return out


def to_json(diags) -> list[dict]:
    return [dataclasses.asdict(d) for d in diags]


# ----------------------------------------------------------------- SARIF
_SARIF_LEVEL = {"error": "error", "warning": "warning", "info": "note"}


def _sarif_location(location: str) -> dict:
    """Map a Diagnostic location onto a SARIF location object.

    ``file.py:LINE`` becomes a physicalLocation (uri resolved best-effort:
    as given, else under ``src/repro/``); semantic coordinates
    (``chain[2]:int_lo``, ``plan:step-hlo``, ``jaxpr:step``) become
    logicalLocations so viewers still group them.
    """
    import pathlib

    path, _, line = location.rpartition(":")
    if path and line.isdigit() and "." in path:
        uri = path
        if not pathlib.Path(uri).exists():
            cand = pathlib.Path("src/repro") / uri
            if cand.exists():
                uri = str(cand)
        return {"physicalLocation": {
            "artifactLocation": {"uri": uri},
            "region": {"startLine": int(line)}}}
    return {"logicalLocations": [{"fullyQualifiedName": location}]}


def to_sarif(diags, *, tool_name: str = "repro-analysis") -> dict:
    """SARIF 2.1.0 log for code-scanning upload (CI's ``--sarif`` path).

    One run, one rule per distinct code (so the scanning UI groups
    findings by rule), fix hints carried as the result message's second
    line. Input should already be ``canonical()`` — this function
    preserves order, it does not re-sort.
    """
    rules: dict[str, dict] = {}
    results = []
    for d in diags:
        rules.setdefault(d.code, {
            "id": d.code,
            "defaultConfiguration": {"level": _SARIF_LEVEL[d.severity]},
        })
        text = d.message if not d.fix_hint else \
            f"{d.message}\nhint: {d.fix_hint}"
        results.append({
            "ruleId": d.code,
            "level": _SARIF_LEVEL[d.severity],
            "message": {"text": text},
            "locations": [_sarif_location(d.location)],
        })
    return {
        "$schema": "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                   "master/Schemata/sarif-schema-2.1.0.json",
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": tool_name,
                "informationUri": "https://arxiv.org/abs/1905.01349",
                "rules": sorted(rules.values(), key=lambda r: r["id"]),
            }},
            "results": results,
        }],
    }
