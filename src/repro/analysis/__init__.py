"""repro.analysis: static analysis over plans, IR, compiled HLO, kernels,
and source.

Six passes, one ``Diagnostic`` ABI (code, severity, location, fix hint):

  chain_lint     interval + domain analysis over CNF predicate chains —
                 unsatisfiable predicates/groups/conjunctions, subsumption,
                 always-true members, Bloom-quantizer collisions, HASHMIX
                 shadowing; plus a canonicalizer with the fingerprint
                 consequence spelled out. Runs automatically inside
                 ``build_session`` (errors raise, warnings warn once).
  hlo_audit      compiles a FilterSession and audits the jitted step /
                 exchange / tokenize HLO: collective presence/absence per
                 scope×exchange, host callbacks, f64 leaks, bounded trace
                 count across ragged skip-tier widths.
  hotpath_lint   AST ban of host-sync idioms (``.item()``, ``np.asarray``,
                 ``int()/float()`` on traced data, ``device_get``,
                 ``block_until_ready``, ``enable_x64``) in functions
                 reachable from the jitted step, with a reasoned allowlist
                 for the sanctioned syncs (stale entries are errors).
  jaxpr_lint     IR-tier dataflow lint over the traced session jaxprs —
                 f64 promotion, captured 0-d device constants (recompile /
                 tracer-leak hazards), dead subcomputations, degenerate
                 broadcasts, host callbacks at primitive level, missed
                 donation opportunities.
  kernel_audit   static memory-safety verifier over the Pallas kernels:
                 symbolic in-bounds proof of every BlockSpec index map
                 across the whole grid, 128-lane/8-sublane tile alignment,
                 per-grid-step VMEM working-set bound, and a cross-check
                 that the captured geometry reproduces the roofline byte
                 model (``benchmarks/roofline.py::filter_ingest_model``).
  plan_matrix    enumerate the FULL valid plan space via ``validate_combo``,
                 dedupe by compiled identity, drive hlo_audit + jaxpr_lint
                 over it under a compile budget; plus the
                 ``fingerprint_coverage`` checkpoint-partition proof.

CLI: ``python -m repro.analysis --all`` (exits nonzero on error-severity
findings; ``--json`` for machine consumption, ``--sarif`` for
code-scanning upload, ``--strict`` to also fail on warnings). Findings
are ``canonical()``-ized — deterministically ordered, exact duplicates
removed — before emission.
"""

from repro.analysis.diagnostics import (Diagnostic, SEVERITIES, canonical,
                                        errors, render_report, to_json,
                                        to_sarif, warnings_of)
from repro.analysis.chain_lint import (CanonResult, canonicalize_chain,
                                       lint_chain, lint_tile_proofs)
from repro.analysis.hlo_audit import (audit_plan, audit_step_text,
                                      collectives_in, has_f64,
                                      host_callbacks_in)
from repro.analysis.hotpath_lint import ALLOWLIST, lint_hotpath
from repro.analysis.jaxpr_lint import (lint_jaxpr, lint_plan_jaxprs,
                                       lint_session_jaxprs)
from repro.analysis.kernel_audit import (audit_kernels, audit_launches,
                                         capture_launches)
from repro.analysis.plan_matrix import (compiled_identity, enumerate_plans,
                                        fingerprint_coverage, matrix_audit)

__all__ = [
    "Diagnostic", "SEVERITIES", "errors", "warnings_of", "render_report",
    "to_json", "to_sarif", "canonical",
    "lint_chain", "canonicalize_chain", "lint_tile_proofs", "CanonResult",
    "audit_plan", "audit_step_text", "collectives_in", "has_f64",
    "host_callbacks_in",
    "lint_hotpath", "ALLOWLIST",
    "lint_jaxpr", "lint_session_jaxprs", "lint_plan_jaxprs",
    "audit_kernels", "audit_launches", "capture_launches",
    "enumerate_plans", "compiled_identity", "matrix_audit",
    "fingerprint_coverage",
]
