"""repro.analysis: static analysis over plans, compiled HLO, and source.

Three passes, one ``Diagnostic`` ABI (code, severity, location, fix hint):

  chain_lint     interval + domain analysis over CNF predicate chains —
                 unsatisfiable predicates/groups/conjunctions, subsumption,
                 always-true members, Bloom-quantizer collisions, HASHMIX
                 shadowing; plus a canonicalizer with the fingerprint
                 consequence spelled out. Runs automatically inside
                 ``build_session`` (errors raise, warnings warn once).
  hlo_audit      compiles a FilterSession and audits the jitted step /
                 exchange / tokenize HLO: collective presence/absence per
                 scope×exchange, host callbacks, f64 leaks, bounded trace
                 count across ragged skip-tier widths.
  hotpath_lint   AST ban of host-sync idioms (``.item()``, ``np.asarray``,
                 ``int()/float()`` on traced data, ``device_get``,
                 ``block_until_ready``, ``enable_x64``) in functions
                 reachable from the jitted step, with a reasoned allowlist
                 for the sanctioned syncs.

CLI: ``python -m repro.analysis --all`` (exits nonzero on error-severity
findings; ``--json`` for machine consumption, ``--strict`` to also fail
on warnings).
"""

from repro.analysis.diagnostics import (Diagnostic, SEVERITIES, errors,
                                        render_report, to_json, warnings_of)
from repro.analysis.chain_lint import (CanonResult, canonicalize_chain,
                                       lint_chain, lint_tile_proofs)
from repro.analysis.hlo_audit import (audit_plan, audit_step_text,
                                      collectives_in, has_f64,
                                      host_callbacks_in)
from repro.analysis.hotpath_lint import ALLOWLIST, lint_hotpath

__all__ = [
    "Diagnostic", "SEVERITIES", "errors", "warnings_of", "render_report",
    "to_json",
    "lint_chain", "canonicalize_chain", "lint_tile_proofs", "CanonResult",
    "audit_plan", "audit_step_text", "collectives_in", "has_f64",
    "host_callbacks_in",
    "lint_hotpath", "ALLOWLIST",
]
