"""IR-tier dataflow lint over traced jaxprs.

``hlo_audit`` greps compiled modules — cheap but coarse: by HLO time the
compiler has fused away the structure that explains a finding. This pass
runs one level earlier, on the jaxpr from ``jax.make_jaxpr``, where every
primitive still carries its operand/result avals and closed-over
constants are first-class. Six rules, each a dataflow scan over the
closed jaxpr (recursing into ``pjit``/``cond``/``while``/``shard_map``
subjaxprs):

  jaxpr-f64                 error    a float64 aval anywhere (operand,
                                     result, or closed-over constant) —
                                     the u32-limb tokenizer and the f32
                                     kernel ABI both break under x64
  jaxpr-host-callback       error    callback/infeed/outfeed primitives —
                                     complements the HLO custom-call grep
                                     at the level where the offending op
                                     is still named
  jaxpr-scalar-capture      warning  a 0-d closed-over constant: a python
                                     scalar (or 0-d array) captured by the
                                     traced closure bakes a trace-time
                                     value into the executable — change it
                                     and the old trace silently keeps
                                     running (recompile hazard)
  jaxpr-dead-code           warning  an effect-free equation whose outputs
                                     are never consumed — work XLA will
                                     DCE, but its presence means the
                                     source computes something it throws
                                     away
  jaxpr-degenerate-broadcast info    broadcast_in_dim to the operand's own
                                     shape (a no-op reshape smell)
  jaxpr-missed-donation     info     input buffers whose shape/dtype match
                                     an output — donation candidates; the
                                     state-threading step legitimately
                                     matches, so this stays advisory

Entry points: ``lint_jaxpr`` for one ``ClosedJaxpr`` (the seeded-defect
tests drive this directly) and ``lint_session_jaxprs`` which pulls every
jitted callable of a ``FilterSession`` via ``FilterSession.make_jaxprs``
and lints each.
"""

from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic

#: primitive names that move data to/from the host at trace level
_CALLBACK_MARKERS = ("callback", "infeed", "outfeed", "host_local")

#: data-movement/selection primitives EXCLUDED from the dead-code scan:
#: jax's own transform machinery synthesizes dead ones (vmap of
#: lax.switch evaluates every branch and select_n's the results; the
#: unchosen branches' shuffles stay in the jaxpr with dropped outputs).
#: Flagging those would indict the batching rules, not the source — the
#: rule is after discarded COMPUTE (sin/mul/reduce/...), which is what
#: "the source pays trace time for nothing" actually means.
_DEAD_CODE_EXEMPT = frozenset({
    "select_n", "broadcast_in_dim", "concatenate", "convert_element_type",
    "reshape", "transpose", "squeeze", "slice", "dynamic_slice", "copy",
})


# ------------------------------------------------------------- jaxpr walking
def _iter_jaxprs(v):
    """Yield every (sub)jaxpr reachable from an eqn param value."""
    if v is None:
        return
    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):   # ClosedJaxpr
        yield v.jaxpr
        return
    if hasattr(v, "eqns") and hasattr(v, "invars"):        # raw Jaxpr
        yield v
        return
    if isinstance(v, (tuple, list)):
        for item in v:
            yield from _iter_jaxprs(item)


def _closed_consts(v):
    """Closed-over constants of an eqn param value, when it carries any."""
    if hasattr(v, "consts") and hasattr(v, "jaxpr"):
        return list(v.consts)
    if isinstance(v, (tuple, list)):
        out = []
        for item in v:
            out.extend(_closed_consts(item))
        return out
    return []


def _walk(jaxpr, depth=0):
    """(eqn, depth) over a jaxpr and all its subjaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn, depth
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                yield from _walk(sub, depth + 1)


def _is_dropvar(var) -> bool:
    return type(var).__name__ == "DropVar"


def _aval(var):
    return getattr(var, "aval", None)


def _is_f64(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and str(dtype) == "float64"


# ------------------------------------------------------------------ the pass
def lint_jaxpr(closed_jaxpr, *, name: str) -> list[Diagnostic]:
    """Run every IR rule over one ``ClosedJaxpr``.

    ``name`` labels the traced callable (``step``, ``exchange``, ...);
    findings locate as ``jaxpr:{name}``.
    """
    diags: list[Diagnostic] = []
    loc = f"jaxpr:{name}"
    jaxpr = closed_jaxpr.jaxpr

    # ---- closed-over constants: f64 + 0-d scalar captures
    consts = list(closed_jaxpr.consts)
    for eqn, _ in _walk(jaxpr):
        for v in eqn.params.values():
            consts.extend(_closed_consts(v))
    n_scalar = 0
    for c in consts:
        if getattr(c, "ndim", None) == 0:
            n_scalar += 1
        if _is_f64(_aval(c)) or str(getattr(c, "dtype", "")) == "float64":
            diags.append(Diagnostic(
                "jaxpr-f64", "error", loc,
                f"closed-over constant with dtype float64 in '{name}'",
                "keep captured constants f32 (jnp.float32(...)) — x64 "
                "recompiles the world and breaks the u32-limb contract"))
    if n_scalar:
        diags.append(Diagnostic(
            "jaxpr-scalar-capture", "warning", loc,
            f"{n_scalar} 0-d closed-over constant(s) in '{name}': a "
            "captured python scalar bakes its trace-time value into the "
            "executable — updating it later silently reuses the stale "
            "trace",
            "thread the scalar as a traced argument, or mark it static "
            "(static_argnames) so a change forces a visible retrace"))

    # ---- per-equation scans
    n_donation = 0
    for eqn, _ in _walk(jaxpr):
        prim = eqn.primitive.name
        if any(m in prim for m in _CALLBACK_MARKERS):
            diags.append(Diagnostic(
                "jaxpr-host-callback", "error", loc,
                f"host-callback primitive '{prim}' inside '{name}' — a "
                "device→host round trip on every invocation",
                "hoist the host work into the session driver between jit "
                "calls (see hotpath_lint's allowlist contract)"))
        for var in (*eqn.invars, *eqn.outvars):
            if _is_f64(_aval(var)):
                diags.append(Diagnostic(
                    "jaxpr-f64", "error", loc,
                    f"float64 aval at primitive '{prim}' in '{name}'",
                    "find the promotion source (python float math on a "
                    "traced value, np.float64 constant) and pin it to f32"))
                break
        if prim == "broadcast_in_dim":
            in_aval, out_aval = _aval(eqn.invars[0]), _aval(eqn.outvars[0])
            if (in_aval is not None and out_aval is not None
                    and in_aval.shape == out_aval.shape):
                diags.append(Diagnostic(
                    "jaxpr-degenerate-broadcast", "info", loc,
                    f"broadcast_in_dim to its own shape {in_aval.shape} "
                    f"in '{name}' (no-op)",
                    "drop the broadcast; it is shape bookkeeping only"))

    # ---- dead code: per jaxpr LEVEL, effect-free eqns nobody consumes.
    # jax's trace finalization rewrites unused outvars to DropVar, so an
    # eqn whose outputs are ALL dropped (or all unconsumed) is the "source
    # computed something and threw it away" case.
    def _dead_scan(jx):
        used = {id(v) for v in jx.outvars if not _is_dropvar(v)}
        for eqn in jx.eqns:
            for v in eqn.invars:
                used.add(id(v))
        for eqn in jx.eqns:
            if eqn.outvars and not eqn.effects \
                    and eqn.primitive.name not in _DEAD_CODE_EXEMPT \
                    and all(_is_dropvar(v) or id(v) not in used
                            for v in eqn.outvars):
                diags.append(Diagnostic(
                    "jaxpr-dead-code", "warning", loc,
                    f"'{eqn.primitive.name}' result is never consumed in "
                    f"'{name}' — dead subcomputation",
                    "delete the unused computation at the source (XLA "
                    "would DCE it, but the source still pays trace time)"))
            for v in eqn.params.values():
                for sub in _iter_jaxprs(v):
                    _dead_scan(sub)

    _dead_scan(jaxpr)

    # ---- missed donation: top-level invars aliasable onto outvars
    out_sigs: dict[tuple, int] = {}
    for v in jaxpr.outvars:
        aval = _aval(v)
        if aval is not None and getattr(aval, "ndim", 0) >= 1:
            sig = (aval.shape, str(aval.dtype))
            out_sigs[sig] = out_sigs.get(sig, 0) + 1
    for v in jaxpr.invars:
        aval = _aval(v)
        if aval is None or getattr(aval, "ndim", 0) < 1:
            continue
        sig = (aval.shape, str(aval.dtype))
        if out_sigs.get(sig, 0) > 0:
            out_sigs[sig] -= 1
            n_donation += 1
    if n_donation:
        diags.append(Diagnostic(
            "jaxpr-missed-donation", "info", loc,
            f"{n_donation} input buffer(s) of '{name}' match an output's "
            "shape/dtype — donation candidates (the threaded OrderState "
            "legitimately matches; jit(donate_argnums=...) would reuse "
            "the buffers)",
            "advisory: donate state-sized args if peak memory matters"))
    return diags


# -------------------------------------------------------------- session glue
def lint_session_jaxprs(session, batch) -> list[Diagnostic]:
    """Trace every jitted callable the session drives and lint each.

    ``batch``: host f32[C, R] (R a multiple of the shard count); the trace
    shapes match what ``FilterSession.step`` would dispatch.
    """
    diags: list[Diagnostic] = []
    for name, closed in session.make_jaxprs(batch).items():
        diags.extend(lint_jaxpr(closed, name=name))
    return diags


def lint_plan_jaxprs(plan, *, rows_per_shard: int = 512) -> list[Diagnostic]:
    """Build a session from ``plan`` and lint all its traced callables."""
    import numpy as np

    from repro.core.session import build_session

    session = build_session(plan)
    n_cols = max(p.column for p in plan.predicates) + 1
    rng = np.random.default_rng(0)
    batch = rng.uniform(-64, 64, (n_cols, rows_per_shard
                                  * session.num_shards)).astype(np.float32)
    return lint_session_jaxprs(session, batch)
