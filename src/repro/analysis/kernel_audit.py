"""Static memory-safety verifier for the Pallas filter-chain kernels.

The kernels in ``kernels/filter_chain/`` hand-write grid/BlockSpec index
arithmetic; nothing checked it statically, and the ROADMAP's Mosaic
prefix-DMA gather means more of it is coming. This pass captures every
``pallas_call`` launch geometry (grid, BlockSpecs, operand shapes) by
intercepting the launch — the kernel body never runs — and proves, for a
sweep of supported (rows, cols, capacity, tile) shapes:

  kernel-oob-access        error    a BlockSpec index map demands a block
                                    outside the (tile-padded) array for
                                    some grid point, or the gather ring
                                    lacks the TILE of slack its guarded
                                    dynamic store relies on
  kernel-misaligned-tile   error    a VMEM block whose lane (last) dim is
                                    neither a multiple of 128 nor the
                                    array's full lane extent — Mosaic
                                    retiles it with a layout change on
                                    every access
  kernel-misaligned-sublane warning a VMEM block sublane dim that is not
                                    1, a multiple of 8, or the full
                                    sublane extent
  kernel-vmem-pressure     error    double-buffered per-grid-step working
                                    set exceeds the ~16 MiB VMEM budget
  kernel-model-drift       error    captured per-grid-step HBM bytes
                                    disagree with ``benchmarks/roofline.py
                                    ::filter_ingest_model``'s per-launch
                                    charges (the two models are the same
                                    contract, single-sourced in spirit —
                                    they must not contradict)
  kernel-constant-drift    error    module tiling constants broke their
                                    invariants (DEFAULT_TILE % 128,
                                    STAT_TILE == skip_tier.SKIP_TILE, ...)
  kernel-interpret-only    warning  a construct that runs under
                                    ``interpret=True`` but will not lower
                                    to Mosaic as written: a dynamic lane
                                    offset (``pl.ds`` with a traced start
                                    in the minormost index slot) — the
                                    safety net the prefix-DMA lowering
                                    lands behind

``capture_launches`` sweeps the real entry points (chain with/without
compaction, skip-tier decisions on/off, the compact gather, the
zone-map stats pre-pass); ``audit_launches`` runs the geometry checks on
any list of ``Launch`` records, which is what the seeded-defect tests
drive directly.
"""

from __future__ import annotations

import ast
import dataclasses
import importlib
import importlib.util
import itertools
import math
from pathlib import Path

import numpy as np

from repro.analysis.diagnostics import Diagnostic

#: per-core VMEM budget the working-set bound checks against (bytes)
VMEM_BUDGET = 16 * 2 ** 20
#: pipeline double-buffering factor applied to the block working set
DOUBLE_BUFFER = 2

#: default (rows_padded, n_rows_actual, capacity, tile) shape sweep —
#: ragged actual row counts, minimum/large capacities, a non-default tile
DEFAULT_SHAPES = (
    (2048, 2048, 128, 2048),
    (4096, 3100, 1024, 2048),
    (8192, 8192, 8192, 2048),
    (4096, 4000, 512, 512),
)


# ------------------------------------------------------------ capture layer
@dataclasses.dataclass
class BlockInfo:
    """One BlockSpec, reduced to what the geometry checks need."""

    block_shape: tuple | None        # None: whole array (SMEM scalars)
    index_map: object                # callable grid→block indices, or None
    memory_space: str                # "smem" | "vmem"


@dataclasses.dataclass
class Launch:
    """One captured ``pallas_call`` launch geometry."""

    name: str
    grid: tuple
    in_specs: list                   # list[BlockInfo], aligned with in_shapes
    out_specs: list
    in_shapes: list                  # list[(shape tuple, dtype str)]
    out_shapes: list
    ctx: dict = dataclasses.field(default_factory=dict)


def _space_of(spec) -> str:
    return "smem" if "smem" in str(getattr(spec, "memory_space", "")).lower() \
        else "vmem"


def _info_of(spec) -> BlockInfo:
    shape = getattr(spec, "block_shape", None)
    return BlockInfo(None if shape is None else tuple(shape),
                     getattr(spec, "index_map", None), _space_of(spec))


def _as_list(v):
    return list(v) if isinstance(v, (list, tuple)) else [v]


class _Recorder:
    """Context manager replacing ``pl.pallas_call`` with a geometry tap.

    The fake launch records (grid, specs, operand/result shapes) and
    returns zeros of the declared out_shape — the kernel body never
    executes, so capture is O(shapes), not O(rows).
    """

    def __init__(self):
        self.launches: list[Launch] = []
        self._real = None

    def __enter__(self):
        from jax.experimental import pallas as pl
        self._real = pl.pallas_call
        launches = self.launches

        def fake_pallas_call(kernel, *, grid=None, in_specs=None,
                             out_specs=None, out_shape=None, name=None,
                             **_kw):
            single = not isinstance(out_shape, (list, tuple))

            def runner(*args):
                import jax.numpy as jnp
                launches.append(Launch(
                    name=name or getattr(kernel, "__name__", "<kernel>"),
                    grid=(grid,) if isinstance(grid, int) else tuple(grid),
                    in_specs=[_info_of(s) for s in _as_list(in_specs)],
                    out_specs=[_info_of(s) for s in _as_list(out_specs)],
                    in_shapes=[(tuple(a.shape), str(a.dtype))
                               for a in args],
                    out_shapes=[(tuple(o.shape), str(o.dtype))
                                for o in _as_list(out_shape)],
                ))
                outs = [jnp.zeros(o.shape, o.dtype)
                        for o in _as_list(out_shape)]
                return outs[0] if single else outs

            return runner

        pl.pallas_call = fake_pallas_call
        return self

    def __exit__(self, *exc):
        from jax.experimental import pallas as pl
        pl.pallas_call = self._real
        return False


def capture_launches(shapes=DEFAULT_SHAPES) -> list[Launch]:
    """Drive every kernel entry point across ``shapes`` under the tap.

    ``shapes``: (rows_padded, n_rows_actual, capacity, tile) tuples.
    Returns one ``Launch`` per ``pallas_call``, annotated with the launch
    context (tile, capacity, actual rows) the audit checks need.
    """
    import jax.numpy as jnp

    from repro.core import predicates as pred_lib

    # the jitted `filter_chain` re-export shadows the module name in the
    # package namespace; import the module itself explicitly
    fc = importlib.import_module("repro.kernels.filter_chain.filter_chain")
    specs = pred_lib.pack(pred_lib.paper_filters_4("fig1"))
    n_cols = int(np.max(np.asarray(specs.column))) + 1
    n_preds = int(specs.column.shape[0])
    perm = jnp.arange(n_preds, dtype=jnp.int32)

    out: list[Launch] = []
    for rows_p, n_rows, cap, tile in shapes:
        if rows_p % tile or tile % fc.STAT_TILE:
            raise ValueError(f"bad sweep shape {(rows_p, n_rows, cap, tile)}")
        cols = jnp.zeros((n_cols, rows_p), jnp.float32)
        meta = jnp.asarray([n_rows, 100, 0, 0], jnp.int32)
        n_sub = rows_p // fc.STAT_TILE
        decisions = (jnp.zeros((n_sub,), jnp.int32),
                     jnp.zeros((n_sub,), jnp.int32))
        ctx = {"tile": tile, "rows_padded": rows_p, "n_rows": n_rows,
               "capacity": cap, "n_cols": n_cols}
        with _Recorder() as rec:
            fc.filter_chain_pallas(cols, specs, perm, meta, tile=tile)
            fc.filter_chain_pallas(cols, specs, perm, meta, tile=tile,
                                   compact=True)
            fc.filter_chain_pallas(cols, specs, perm, meta, tile=tile,
                                   compact=True, skip_decisions=decisions)
            fc.compact_gather_pallas(cols, jnp.zeros((rows_p // tile,),
                                                     jnp.int32),
                                     cap, tile=tile)
            fc.tile_stats_pallas(cols, tile=tile)
        for launch in rec.launches:
            launch.ctx = dict(ctx)
        out.extend(rec.launches)
    return out


# ---------------------------------------------------------- geometry checks
def _dtype_bytes(dtype: str) -> int:
    return np.dtype(dtype).itemsize


def _block_bytes(block, dtype) -> int:
    return int(np.prod(block)) * _dtype_bytes(dtype)


def _check_spec(launch: Launch, kind: str, i: int, spec: BlockInfo,
                arr_shape: tuple, dtype: str) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    loc = f"kernel:{launch.name}:{kind}[{i}]"
    if spec.memory_space == "smem" or spec.block_shape is None:
        return diags
    block = spec.block_shape
    if len(block) != len(arr_shape):
        diags.append(Diagnostic(
            "kernel-oob-access", "error", loc,
            f"block rank {len(block)} != array rank {len(arr_shape)} "
            f"({block} vs {arr_shape})", "fix the BlockSpec shape"))
        return diags

    # ---- in-bounds: every grid point's block index must address an
    # existing (tile-padded) block in every dimension
    n_blocks = [max(1, math.ceil(a / b)) for a, b in zip(arr_shape, block)]
    if spec.index_map is not None:
        for point in itertools.product(*(range(g) for g in launch.grid)):
            idx = spec.index_map(*point)
            idx = (idx,) if not isinstance(idx, tuple) else idx
            for d, (bi, nb) in enumerate(zip(idx, n_blocks)):
                if not 0 <= int(bi) < nb:
                    diags.append(Diagnostic(
                        "kernel-oob-access", "error", loc,
                        f"grid point {point}: index map demands block "
                        f"{tuple(int(x) for x in idx)} but dim {d} has "
                        f"only {nb} block(s) of {block[d]} over extent "
                        f"{arr_shape[d]} — rows past the array would be "
                        "read/written",
                        "fix the index map (block indices, not element "
                        "offsets) or the grid size"))
                    break
            else:
                continue
            break                     # one finding per spec is enough

    # ---- lane / sublane alignment (f32 native tile is (8, 128))
    lane = block[-1]
    if lane % 128 and lane != arr_shape[-1]:
        diags.append(Diagnostic(
            "kernel-misaligned-tile", "error", loc,
            f"lane (last) block dim {lane} is neither a multiple of 128 "
            f"nor the full array extent {arr_shape[-1]} — Mosaic retiles "
            "this block with a layout change on every access",
            "pad the block to 128 lanes or restructure so the minormost "
            "dim is fully covered (see the stats-kernel layout)"))
    if len(block) >= 2:
        sub = block[-2]
        if sub not in (1, arr_shape[-2]) and sub % 8:
            diags.append(Diagnostic(
                "kernel-misaligned-sublane", "warning", loc,
                f"sublane block dim {sub} is not 1, a multiple of 8, or "
                f"the full extent {arr_shape[-2]}",
                "round the sublane dim to the 8-row f32 granule"))
    return diags


def _vmem_working_set(launch: Launch) -> int:
    total = 0
    for spec, (shape, dtype) in zip(
            launch.in_specs + launch.out_specs,
            launch.in_shapes + launch.out_shapes):
        if spec.memory_space == "smem":
            continue
        block = spec.block_shape if spec.block_shape is not None else shape
        total += _block_bytes(block, dtype)
    return DOUBLE_BUFFER * total


def audit_launches(launches) -> list[Diagnostic]:
    """Geometry checks over captured (or hand-built) ``Launch`` records."""
    diags: list[Diagnostic] = []
    for launch in launches:
        for kind, specs, shapes in (("in", launch.in_specs,
                                     launch.in_shapes),
                                    ("out", launch.out_specs,
                                     launch.out_shapes)):
            for i, (spec, (shape, dtype)) in enumerate(zip(specs, shapes)):
                diags += _check_spec(launch, kind, i, spec, shape, dtype)

        ws = _vmem_working_set(launch)
        if ws > VMEM_BUDGET:
            diags.append(Diagnostic(
                "kernel-vmem-pressure", "error", f"kernel:{launch.name}",
                f"double-buffered per-grid-step working set {ws} B "
                f"exceeds the {VMEM_BUDGET} B VMEM budget",
                "shrink the tile or split the launch"))

        # the gather's guarded dynamic store (off < capacity, extent TILE)
        # is only in-bounds because the output ring carries TILE of slack
        if "compact_gather" in launch.name and launch.ctx:
            cap, tile = launch.ctx["capacity"], launch.ctx["tile"]
            width = launch.out_shapes[0][0][-1]
            if width < cap + tile:
                diags.append(Diagnostic(
                    "kernel-oob-access", "error",
                    f"kernel:{launch.name}:out[0]",
                    f"output ring width {width} < capacity {cap} + tile "
                    f"{tile}: the guarded dynamic store pl.ds(off, "
                    f"{tile}) with off ≤ {cap - 1} would write past the "
                    "buffer",
                    "allocate [C, capacity + tile] and slice the ring "
                    "down after the launch"))
    return diags


# --------------------------------------------------- roofline byte contract
def _load_roofline():
    """``benchmarks.roofline`` — by import when the repo root is on the
    path, by file location otherwise (installed-package runs)."""
    try:
        return importlib.import_module("benchmarks.roofline")
    except ImportError:
        pass
    from repro.core import plan as _plan
    root = Path(_plan.__file__).resolve().parents[3]
    cand = root / "benchmarks" / "roofline.py"
    if not cand.is_file():
        return None
    spec = importlib.util.spec_from_file_location("_kernel_audit_roofline",
                                                  cand)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _chain_geometry_bytes(launch: Launch) -> tuple[int, bool]:
    """(per-grid-step data bytes, is_compact) for a chain launch.

    Data traffic = the column tile in + the mask out (+ the packed tile
    and i32 count with in-kernel compaction). The f32 monitor counters
    (active/cut/gcut/nmon) are bookkeeping lanes the byte model
    deliberately ignores — a few hundred bytes against megabyte tiles.
    """
    tile = launch.ctx["tile"]
    total = 0
    compact = False
    for spec, (shape, dtype) in zip(launch.in_specs, launch.in_shapes):
        if spec.memory_space != "smem":
            total += _block_bytes(spec.block_shape, dtype)    # column tile
    for spec, (shape, dtype) in zip(launch.out_specs, launch.out_shapes):
        block = spec.block_shape
        if dtype == "int8":                                   # mask lane
            total += _block_bytes(block, dtype)
        elif dtype == "int32":                                # tile count
            total += _block_bytes(block, dtype)
            compact = True
        elif dtype == "float32" and block[-1] == tile:        # packed tile
            total += _block_bytes(block, dtype)
    return total, compact


def crosscheck_roofline(launches) -> list[Diagnostic]:
    """The captured launch geometry and the analytic byte model must agree.

    At pass_rate=1.0 the model's survivor quantization is exact, so each
    launch family has a closed-form prediction the geometry must match
    byte-for-byte: chain-only = C·T·B + T; fused launch 1 adds the packed
    tile + count; fused launch 2 = offset + packed read + stitched write;
    the stats pre-pass = the summary write half of ``bytes_summary``.
    """
    roofline = _load_roofline()
    if roofline is None:
        return [Diagnostic(
            "kernel-model-drift", "warning", "kernel:roofline",
            "benchmarks/roofline.py not found — byte-model cross-check "
            "skipped", "run from a checkout with benchmarks/ present")]
    diags: list[Diagnostic] = []

    def drift(name, what, geom, model):
        diags.append(Diagnostic(
            "kernel-model-drift", "error", f"kernel:{name}",
            f"{what}: captured geometry moves {geom} B/grid-step but "
            f"filter_ingest_model charges {model:.0f} B — the kernel and "
            "the roofline model contradict",
            "change BOTH the kernel and "
            "benchmarks/roofline.py::filter_ingest_model together; they "
            "are one contract"))

    for launch in launches:
        if not launch.ctx:
            continue
        tile, n_cols = launch.ctx["tile"], launch.ctx["n_cols"]
        model = roofline.filter_ingest_model(n_cols=n_cols, tile=tile,
                                             pass_rate=1.0)
        if launch.name.startswith("adaptive_filter_chain"):
            geom, compact = _chain_geometry_bytes(launch)
            if compact:
                if geom != model["bytes_fused_launch1"]:
                    drift(launch.name, "fused launch 1 (chain+pack)",
                          geom, model["bytes_fused_launch1"])
            elif geom != model["bytes_chain_only"]:
                drift(launch.name, "chain-only launch", geom,
                      model["bytes_chain_only"])
        elif "compact_gather" in launch.name:
            packed_block = next(
                s.block_shape for s in launch.in_specs
                if s.memory_space != "smem")
            read = _block_bytes(packed_block, "float32")
            geom = 4 + read + read    # offset + packed read + stitch write
            if geom != model["bytes_fused_launch2"]:
                drift(launch.name, "fused launch 2 (gather)", geom,
                      model["bytes_fused_launch2"])
        elif "tile_stats" in launch.name:
            geom = sum(_block_bytes(s.block_shape, d)
                       for s, (_, d) in zip(launch.out_specs,
                                            launch.out_shapes))
            want = model["bytes_summary"] / 2        # the write half
            if geom != want:
                drift(launch.name, "zone-map summary write", geom, want)
    return diags


# ------------------------------------------------ interpret-only AST screen
def _is_static(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) or (
        isinstance(node, ast.UnaryOp) and _is_static(node.operand))


def scan_interpret_only(source_path: Path | None = None) -> list[Diagnostic]:
    """Flag dynamic lane offsets: ``pl.load``/``pl.store`` whose minormost
    index is ``pl.ds`` with a traced start.

    Interpret mode executes them as plain array indexing; Mosaic requires
    lane offsets to be static/aligned — the real lowering replaces this
    with a scalar-prefetched DMA, which is exactly the ROADMAP item this
    screen is the safety net for. A dynamic SUBLANE slice (e.g. the
    chain's column select) lowers fine and is not flagged.
    """
    if source_path is None:
        fc = importlib.import_module(
            "repro.kernels.filter_chain.filter_chain")
        source_path = Path(fc.__file__)
    tree = ast.parse(source_path.read_text(), filename=str(source_path))
    diags: list[Diagnostic] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("load", "store")
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "pl" and len(node.args) >= 2):
            continue
        idx = node.args[1]
        elts = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        last = elts[-1]
        if (isinstance(last, ast.Call)
                and isinstance(last.func, ast.Attribute)
                and last.func.attr == "ds" and last.args
                and not _is_static(last.args[0])):
            diags.append(Diagnostic(
                "kernel-interpret-only", "warning",
                f"{source_path.name}:{node.lineno}",
                f"pl.{node.func.attr} with a DYNAMIC lane offset "
                "(pl.ds over a traced start in the minormost slot) — "
                "runs under interpret=True, will not lower to Mosaic "
                "as written",
                "gate the Mosaic build on the scalar-prefetch DMA "
                "lowering (ROADMAP: prefix-DMA gather); interpret-mode "
                "use is sanctioned meanwhile"))
    return diags


# ---------------------------------------------------------- module constants
def check_constants() -> list[Diagnostic]:
    from repro.core import skip_tier as skip_tier_lib
    from repro.core.adaptive_filter import CAPACITY_QUANTUM

    fc = importlib.import_module("repro.kernels.filter_chain.filter_chain")
    diags = []
    loc = "kernel:constants"
    if fc.DEFAULT_TILE % 128:
        diags.append(Diagnostic(
            "kernel-constant-drift", "error", loc,
            f"DEFAULT_TILE {fc.DEFAULT_TILE} is not a multiple of the "
            "128-lane VPU width", "restore the 128 alignment"))
    if fc.STAT_TILE != skip_tier_lib.SKIP_TILE:
        diags.append(Diagnostic(
            "kernel-constant-drift", "error", loc,
            f"STAT_TILE {fc.STAT_TILE} != skip_tier.SKIP_TILE "
            f"{skip_tier_lib.SKIP_TILE}: the zone-map granularity forked",
            "single-source the granule"))
    if CAPACITY_QUANTUM % 128:
        diags.append(Diagnostic(
            "kernel-constant-drift", "error", loc,
            f"CAPACITY_QUANTUM {CAPACITY_QUANTUM} is not 128-lane "
            "aligned: auto-capacity widths would misalign every packed "
            "buffer", "quantize capacities to 128s"))
    return diags


# ------------------------------------------------------------------- driver
def audit_kernels(shapes=DEFAULT_SHAPES, *, model_check: bool = True
                  ) -> list[Diagnostic]:
    """The full kernel audit: capture + geometry + constants + AST screen
    + roofline byte-model cross-check."""
    launches = capture_launches(shapes)
    diags = audit_launches(launches)
    diags += check_constants()
    diags += scan_interpret_only()
    if model_check:
        diags += crosscheck_roofline(launches)
    return diags
