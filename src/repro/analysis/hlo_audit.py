"""Compiled-plan auditor: statically checks the HLO a FilterPlan lowers to.

The repo's hardest-won invariants lived as ad-hoc subprocess greps:
PER_SHARD / deferred-exchange steps are collective-free, nothing inside
``session.step`` calls back to the host, the u32-limb tokenizer never
materializes an f64, and the skip tier's quantized gather keeps the jit
cache bounded across ragged batches. This module is those pins as a
reusable pass: ``audit_plan`` compiles a session for the plan, lowers the
jitted step / exchange / tokenize callables, and audits the HLO text —
the same contract surface the ROADMAP's serving / bandit / multi-tenant
directions need to validate many plans against one engine (Strider-style,
arXiv 1705.05688).

Expectations are derived FROM the plan, so the auditor is one call per
plan, not one grep per mode:

  scope            per_shard / per_batch     step must be collective-free
                   centralized + eager       step must carry the collective
                                             (num_shards > 1 meshes only)
                   centralized + deferred*   step collective-free; the
                                             boundary-exchange module must
                                             carry the one collective
  any              step must be free of host callbacks / infeed / outfeed
  tokenize set     step + tokenizer modules must never contain an f64 op
  skip_tier on     distinct step traces across ragged ambiguous-tile
                   counts must stay within the 16-tile quantization bound

Diagnostic codes: ``hlo-step-collective``, ``hlo-missing-collective``,
``hlo-host-callback``, ``hlo-f64-in-tokenize``, ``hlo-unbounded-traces``
(all error severity — each is a broken compile contract).
"""

from __future__ import annotations

import re

import numpy as np

from repro.analysis.diagnostics import Diagnostic

#: collective HLO op kinds (shared with the roofline analyzer —
#: ``launch.hlo_analysis._COLLECTIVES`` is the same tuple; re-declared here
#: so importing the auditor never drags the launch layer in)
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

#: host-transfer markers inside compiled HLO: python callbacks lower to
#: custom-calls whose target names a callback trampoline; infeed/outfeed
#: are the raw host-transfer ops
_CALLBACK_RE = re.compile(r"custom-call.*callback", re.IGNORECASE)
_HOST_OPS = ("infeed", "outfeed", "send(", "send-done", "recv(", "recv-done")


# ------------------------------------------------------------- text queries
def collectives_in(text: str) -> list[str]:
    """Collective op kinds present in an HLO module (sorted, deduped)."""
    found = {kind for kind in COLLECTIVE_OPS
             for line in text.splitlines()
             if re.search(rf"\b{kind}(-start)?\(", line)}
    return sorted(found)


def host_callbacks_in(text: str) -> list[str]:
    """Lines evidencing a host round-trip inside a compiled module."""
    hits = []
    for line in text.splitlines():
        s = line.strip()
        if _CALLBACK_RE.search(s):
            hits.append(s[:160])
        elif any(f" {op}" in s or s.startswith(op) for op in _HOST_OPS):
            if "custom-call" in s or s.split("=")[-1].strip().startswith(
                    ("infeed", "outfeed", "send", "recv")):
                hits.append(s[:160])
    return hits


def has_f64(text: str) -> bool:
    return "f64[" in text or " f64 " in text


# -------------------------------------------------------------- the auditor
def _synth_batch(plan, rows_per_shard: int, shards: int) -> np.ndarray:
    """Deterministic f32[C, S·R] batch shaped for the plan's chain."""
    n_cols = max(p.column for p in plan.predicates) + 1
    rng = np.random.default_rng(7)
    return rng.uniform(-64.0, 64.0,
                       (n_cols, rows_per_shard * shards)).astype(np.float32)


def _expectations(plan, num_shards: int):
    """(step_must_be_collective_free, collective_expected_somewhere)."""
    deferred = plan.exchange != "eager"
    step_free = plan.scope != "centralized" or deferred
    # on a 1-shard mesh the partitioner elides the psum — only demand the
    # collective's PRESENCE when there is an actual mesh to merge across
    expect_present = plan.scope == "centralized" and num_shards > 1 \
        and plan.adaptive
    return step_free, expect_present


def audit_plan(plan, mesh=None, *, rows_per_shard: int = 512,
               ragged_batches: int = 6) -> list[Diagnostic]:
    """Compile ``plan`` and statically audit every module it executes.

    ``mesh``: optional ``jax.sharding.Mesh`` (as ``build_session``); the
    collective presence/absence checks are strongest on a >1-device mesh
    (CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
    Returns error-severity diagnostics only — a clean plan audits to [].
    """
    from repro.core.session import build_session

    session = build_session(plan, mesh=mesh)
    diags: list[Diagnostic] = []
    shards = session.num_shards
    batch = _synth_batch(plan, rows_per_shard, shards)
    state = session.init_state()

    step_free, expect_present = _expectations(plan, shards)
    step_text = session.compiled_step_text(state, batch)
    diags += audit_step_text(step_text, plan, num_shards=shards)

    # the boundary exchange / retune module: deferred CENTRALIZED must show
    # its one collective HERE (and only here)
    if plan.scope == "centralized" and plan.exchange != "eager" \
            and expect_present:
        ex_text = session.compiled_exchange_text(state)
        if not collectives_in(ex_text):
            diags.append(Diagnostic(
                "hlo-missing-collective", "error", "plan:exchange-hlo",
                f"deferred exchange on a {shards}-shard mesh compiled "
                "without any collective — shard statistics are never "
                "merged and every shard re-ranks on local evidence only",
                "the exchange_update psum was dropped; check "
                "reduce_stats wiring under shard_map"))

    # compact / tokenize module (unsharded path lowers them separately)
    if plan.compact and not session.sharded:
        f = session.filter
        cap = f.resolve_capacity(batch.shape[1])
        compact_text = f._jit_compact.lower(
            state, batch, capacity=cap).compile().as_text()
        diags += audit_step_text(compact_text, plan, num_shards=shards,
                                 location="plan:compact-hlo")

    if plan.tokenize is not None:
        diags += _audit_tokenizer(plan, rows_per_shard, shards)

    if plan.skip_tier not in ("off", None) and not session.sharded:
        diags += _audit_trace_count(session, batch,
                                    ragged_batches=ragged_batches)
    return diags


def audit_step_text(step_text: str, plan, *, num_shards: int,
                    location: str = "plan:step-hlo") -> list[Diagnostic]:
    """Audit one compiled per-step module against the plan's contract."""
    diags: list[Diagnostic] = []
    step_free, expect_present = _expectations(plan, num_shards)
    colls = collectives_in(step_text)
    if step_free and colls:
        why = "PER_SHARD/PER_BATCH scopes never exchange statistics" \
            if plan.scope != "centralized" else \
            f"exchange={plan.exchange!r} defers the merge to the " \
            "boundary module"
        diags.append(Diagnostic(
            "hlo-step-collective", "error", location,
            f"per-step HLO for scope={plan.scope!r} "
            f"exchange={plan.exchange!r} contains collectives "
            f"{colls} — {why}, so the step module must compile "
            "collective-free",
            "a cross-shard reduce leaked into the step trace; move it "
            "into the boundary exchange or drop it"))
    if not step_free and expect_present and not colls:
        diags.append(Diagnostic(
            "hlo-missing-collective", "error", location,
            f"eager CENTRALIZED step on a {num_shards}-shard mesh "
            "compiled without any collective — monitor counters are "
            "never globally merged",
            "the per-step reduce_stats psum was dropped"))
    hits = host_callbacks_in(step_text)
    if hits:
        diags.append(Diagnostic(
            "hlo-host-callback", "error", location,
            f"compiled step round-trips to the host ({len(hits)} "
            f"site(s); first: {hits[0]!r}) — the hot step must stay on "
            "device end to end",
            "remove the callback/infeed from the traced step; host work "
            "belongs in the session driver between jit calls"))
    if plan.tokenize is not None and has_f64(step_text):
        diags.append(Diagnostic(
            "hlo-f64-in-tokenize", "error", location,
            "f64 op in a tokenize-plan step module: the u32-limb "
            "tokenizer contract is that no f64 value ever exists in the "
            "traced program (TPUs have no u64/f64 fast path)",
            "something upcast to float64 — check for python-float "
            "promotion or an enable_x64 leak"))
    return diags


def _audit_tokenizer(plan, rows_per_shard: int, shards: int
                     ) -> list[Diagnostic]:
    """Lower the u32-limb tokenize jit for this plan and ban f64 ops."""
    import jax.numpy as jnp

    from repro.data import tokenizer

    ts = plan.tokenize
    n_cols = max(p.column for p in plan.predicates) + 1
    tok = tokenizer._jit_tokens_from_padded()
    packed = jnp.zeros((max(shards, 1), n_cols, rows_per_shard), jnp.float32)
    counts = jnp.zeros((max(shards, 1),), jnp.int32)
    text = tok.lower(packed, counts, vocab_size=ts.vocab_size,
                     tokens_per_row=ts.tokens_per_row).compile().as_text()
    if has_f64(text):
        return [Diagnostic(
            "hlo-f64-in-tokenize", "error", "plan:tokenize-hlo",
            "f64 op in the compiled u32-limb tokenizer module — the "
            "f32→f64 widening must stay integer bit surgery "
            "(data/tokenizer._limb_ops), never a real float64 convert",
            "check that no enable_x64 context wraps the trace and that "
            "the limb ops were not edited to use jnp.float64")]
    return []


def _audit_trace_count(session, batch: np.ndarray, *, ragged_batches: int
                       ) -> list[Diagnostic]:
    """Drive ragged ambiguous-tile widths; the jit cache must stay within
    the 16-tile gather quantization bound.

    The skip tier's one host sync sizes a static gather width, quantized
    by ``skip_tier.quantize_amb_cap`` to multiples of 16 tiles precisely
    so distinct trace count is O(n_tiles/16), not O(n_tiles). An edit
    that drops the quantization still passes every correctness test —
    only the trace count betrays it.
    """
    from repro.core import skip_tier as skip_tier_lib

    f = session.filter
    n_rows = batch.shape[1]
    n_tiles = -(-n_rows // skip_tier_lib.SKIP_TILE)
    bound = len({skip_tier_lib.quantize_amb_cap(k, n_tiles)
                 for k in range(n_tiles + 1)})
    rng = np.random.default_rng(11)
    state = session.init_state()
    for i in range(ragged_batches):
        # vary how many tiles the zone maps can resolve: mix fully-provable
        # constant tiles with straddling ones in a different ratio per batch
        cols = np.asarray(batch).copy()
        n_flat = (i * n_tiles) // max(ragged_batches - 1, 1)
        flat_rows = n_flat * skip_tier_lib.SKIP_TILE
        cols[:, :flat_rows] = 1e9          # provably fails any bounded chain
        cols[:, flat_rows:] = rng.uniform(
            -64.0, 64.0, cols[:, flat_rows:].shape).astype(np.float32)
        state, _ = session.step(state, cols)
    jit_fns = [("skip", f._jit_step_skip),
               ("skip-compact", f._jit_step_skip_compact)]
    diags = []
    for name, fn in jit_fns:
        if fn is None:
            continue
        n_traces = fn._cache_size()
        if n_traces > bound:
            diags.append(Diagnostic(
                "hlo-unbounded-traces", "error", f"plan:{name}-jit-cache",
                f"{n_traces} distinct traces of the {name} step after "
                f"{ragged_batches} ragged batches over {n_tiles} tiles — "
                f"the 16-tile quantization contract bounds it at {bound}",
                "skip_amb_cap stopped quantizing the gather width "
                "(skip_tier.quantize_amb_cap) — every distinct ambiguous "
                "count now compiles its own module"))
    return diags
