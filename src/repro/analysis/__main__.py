"""CLI: ``python -m repro.analysis`` — run the static-analysis passes.

    python -m repro.analysis --all             # every pass below
    python -m repro.analysis --chain --json    # machine-readable findings
    python -m repro.analysis --hlo             # compile-audit representative plans
    python -m repro.analysis --hotpath         # AST sync lint over the package
    python -m repro.analysis --jaxpr           # IR dataflow lint (jaxpr tier)
    python -m repro.analysis --kernels         # Pallas memory-safety verifier
    python -m repro.analysis --matrix          # full plan-space audit (budgeted)
    python -m repro.analysis --all --sarif out.sarif   # code-scanning upload

Exit status: nonzero iff any error-severity finding (any finding at all
under ``--strict``). Findings are canonicalized (stable order, exact
duplicates removed) before counting/emission, so ``--json`` and
``--sarif`` are byte-reproducible across runs and pass orderings. The CI
``analysis`` job runs ``--all`` on a forced 4-device host so the
collective presence/absence checks bite; ``--matrix`` sweeps the deduped
valid plan space under a compile budget (``--budget``, default 12).

Chain targets: every shape in ``configs.paper_filters.CNF_SHAPES`` under
the declared paper domains, plus ``build_plan()`` from every example
script (``--examples DIR``, default ./examples when present) — examples
that define no ``build_plan`` are skipped with a note, not an error.
"""

from __future__ import annotations

import argparse
import importlib.util
import json
import sys
from pathlib import Path

from repro.analysis import diagnostics as diag_lib
from repro.analysis import chain_lint, hlo_audit, hotpath_lint


# ------------------------------------------------------------ chain targets
def _example_plans(examples_dir: Path):
    """(name, FilterPlan) from every example exposing ``build_plan()``."""
    out, skipped = [], []
    for py in sorted(examples_dir.glob("*.py")):
        spec = importlib.util.spec_from_file_location(
            f"_analysis_example_{py.stem}", py)
        mod = importlib.util.module_from_spec(spec)
        try:
            spec.loader.exec_module(mod)
        except Exception as e:            # an unimportable example is its
            skipped.append((py.name, f"import failed: {e}"))   # own problem
            continue
        build = getattr(mod, "build_plan", None)
        if build is None:
            skipped.append((py.name, "no build_plan()"))
            continue
        out.append((py.name, build()))
    return out, skipped


def run_chain_pass(examples_dir: Path | None, log) -> list:
    from repro.configs import paper_filters

    diags = []
    domains = paper_filters.paper_domains()
    for shape in paper_filters.CNF_SHAPES:
        found = chain_lint.lint_chain(paper_filters.filter_chain(shape),
                                      domains=domains)
        log(diag_lib.render_report(found, title=f"chain: paper '{shape}'"))
        diags += found
    if examples_dir is not None and examples_dir.is_dir():
        plans, skipped = _example_plans(examples_dir)
        for name, plan in plans:
            # no domains: example chains assign their own column meanings
            # (the paper domains are keyed to the paper chain's columns)
            found = chain_lint.lint_chain(plan.predicates)
            log(diag_lib.render_report(found, title=f"chain: {name}"))
            diags += found
        for name, why in skipped:
            log(f"== chain: {name}\nskipped ({why})")
    return diags


# -------------------------------------------------------------- hlo targets
def _plan_matrix():
    """Representative plans covering every audited contract."""
    import jax

    from repro.core.plan import FilterPlan, TokenizeSpec
    from repro.core.predicates import paper_filters_4, paper_filters_cnf

    preds = paper_filters_4("fig1")
    shards = 4 if jax.device_count() >= 4 else 1
    plans = [
        ("per-shard", FilterPlan(predicates=preds, scope="per_shard",
                                 shards=shards)),
        ("eager-centralized", FilterPlan(predicates=preds,
                                         scope="centralized",
                                         shards=shards)),
        ("deferred-centralized", FilterPlan(predicates=preds,
                                            scope="centralized",
                                            shards=shards,
                                            exchange="deferred")),
        ("compact-tokenize", FilterPlan(predicates=paper_filters_cnf("fig1"),
                                        compact=True,
                                        tokenize=TokenizeSpec(32000))),
        ("skip-tier", FilterPlan(predicates=preds,
                                 skip_tier="zonemap+bloom")),
    ]
    return plans, shards


def run_hlo_pass(log) -> list:
    diags = []
    plans, shards = _plan_matrix()
    if shards == 1:
        log("hlo: single-device host — collective-PRESENCE checks are "
            "vacuous here (CI forces 4 devices); absence checks still bite")
    for name, plan in plans:
        found = hlo_audit.audit_plan(plan)
        log(diag_lib.render_report(found, title=f"hlo: {name}"))
        diags += found
    return diags


def run_jaxpr_pass(log) -> list:
    """IR-tier dataflow lint over the representative plans' jaxprs."""
    from repro.analysis import jaxpr_lint

    diags = []
    plans, _ = _plan_matrix()
    for name, plan in plans:
        found = jaxpr_lint.lint_plan_jaxprs(plan)
        log(diag_lib.render_report(found, title=f"jaxpr: {name}"))
        diags += found
    return diags


def run_kernel_pass(log) -> list:
    """Pallas memory-safety verifier + roofline byte-model cross-check."""
    from repro.analysis import kernel_audit

    found = kernel_audit.audit_kernels()
    log(diag_lib.render_report(found, title="kernels: filter_chain"))
    return found


def run_matrix_pass(log, budget: int) -> list:
    from repro.analysis import plan_matrix

    found = plan_matrix.matrix_audit(budget=budget, log=log)
    log(diag_lib.render_report(found, title="matrix: full plan space"))
    return found


# ------------------------------------------------------------------- driver
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: chain linter, compiled-plan HLO "
                    "auditor, hot-path sync lint")
    ap.add_argument("--chain", action="store_true",
                    help="lint the CNF chains (configs + example plans)")
    ap.add_argument("--hlo", action="store_true",
                    help="compile+audit the representative plan matrix")
    ap.add_argument("--hotpath", action="store_true",
                    help="AST host-sync lint over core/kernels/parallel")
    ap.add_argument("--jaxpr", action="store_true",
                    help="IR dataflow lint over the traced session jaxprs")
    ap.add_argument("--kernels", action="store_true",
                    help="Pallas kernel memory-safety verifier + roofline "
                         "byte-model cross-check")
    ap.add_argument("--matrix", action="store_true",
                    help="audit the FULL valid plan space (deduped by "
                         "compiled identity, under --budget compiles)")
    ap.add_argument("--budget", type=int, default=None,
                    help="compile budget for --matrix (default "
                         "plan_matrix.DEFAULT_BUDGET; 0 = unlimited)")
    ap.add_argument("--all", action="store_true", help="run all passes")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array on stdout")
    ap.add_argument("--sarif", type=Path, default=None, metavar="PATH",
                    help="also write findings as SARIF 2.1.0 to PATH "
                         "(code-scanning upload format)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on warnings too")
    ap.add_argument("--examples", type=Path, default=None,
                    help="directory of example scripts to collect "
                         "build_plan() chains from (default: ./examples)")
    args = ap.parse_args(argv)
    if not (args.chain or args.hlo or args.hotpath or args.jaxpr
            or args.kernels or args.matrix or args.all):
        ap.error("pick at least one pass (--chain/--hlo/--hotpath/--jaxpr/"
                 "--kernels/--matrix/--all)")

    lines: list[str] = []
    log = lines.append if args.json else print

    diags = []
    if args.all or args.chain:
        examples = args.examples
        if examples is None:
            cand = Path.cwd() / "examples"
            examples = cand if cand.is_dir() else None
        diags += run_chain_pass(examples, log)
    if args.all or args.hlo:
        diags += run_hlo_pass(log)
    if args.all or args.hotpath:
        found = hotpath_lint.lint_hotpath()
        log(diag_lib.render_report(found, title="hotpath: src/repro"))
        diags += found
    if args.all or args.jaxpr:
        diags += run_jaxpr_pass(log)
    if args.all or args.kernels:
        diags += run_kernel_pass(log)
    if args.all or args.matrix:
        from repro.analysis import plan_matrix as plan_matrix_lib
        budget = plan_matrix_lib.DEFAULT_BUDGET \
            if args.budget is None else args.budget
        diags += run_matrix_pass(log, budget)

    diags = diag_lib.canonical(diags)
    n_err = len(diag_lib.errors(diags))
    n_warn = len(diag_lib.warnings_of(diags))
    if args.sarif is not None:
        args.sarif.write_text(
            json.dumps(diag_lib.to_sarif(diags), indent=2) + "\n")
    if args.json:
        print(json.dumps(diag_lib.to_json(diags), indent=2))
    else:
        print(f"\n{n_err} error(s), {n_warn} warning(s), "
              f"{len(diags) - n_err - n_warn} info note(s)")
    if n_err:
        return 1
    if args.strict and n_warn:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
