"""Checkpointing for fault tolerance (DESIGN §6).

Design (orbax is not available offline; this is a self-contained equivalent
for the features the runtime needs):

  * Layout: one directory per step, one ``.npz`` per host shard plus a json
    manifest (tree structure, shapes, dtypes, step metadata, data-pipeline
    state INCLUDING the adaptive filter's OrderState — ranks survive
    restarts).
  * Atomicity: write into ``<dir>.tmp`` then ``os.replace`` — a crash
    mid-save never corrupts the latest checkpoint; restore picks the newest
    COMMITTED step.
  * Async: ``save(..., blocking=False)`` hands the host arrays to a worker
    thread; ``wait()`` joins before the next save (single in-flight, like
    production async checkpointers).
  * Elastic restore: arrays are saved unsharded per host (process-local
    view); ``load_checkpoint`` re-shards onto whatever mesh the restore-time
    launcher provides, so N→M device restarts work (tested in
    tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import pathlib
import shutil
import threading
import zlib

import jax
import numpy as np

log = logging.getLogger(__name__)


def _flatten(tree, prefix=""):
    """Flatten pytree to {path: leaf} with stable, readable keys."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):               # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_like(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_like(template[k], flat, f"{prefix}{k}/")
                for k in template}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_like(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_like(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template))
    return flat[prefix[:-1]]


def save_checkpoint(directory, step: int, tree, *, extra: dict | None = None,
                    process_id: int = 0) -> pathlib.Path:
    """Atomic blocking save of ``tree`` (+ json-serializable ``extra``)."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:010d}"
    tmp = directory / f"step_{step:010d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    # numpy can't serialize ml_dtypes (bfloat16, fp8): store raw bit views
    # and record the logical dtype in the manifest
    encoded = {}
    dtypes = {}
    for k, v in arrays.items():
        dtypes[k] = str(v.dtype)
        if v.dtype.kind == "V" or v.dtype.name not in np.sctypeDict:
            v = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
        encoded[k] = v
    np.savez(tmp / f"shard_{process_id}.npz",
             **{k.replace("/", "\x1f"): v for k, v in encoded.items()})
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
        # per-array crc32 of the ENCODED bytes (the bit-view trick means
        # the stored representation is what storage can rot): a restore
        # verifies these before deserializing, and the newest-valid
        # fallback in load_checkpoint skips steps that fail
        "crc32": {k: zlib.crc32(
            np.ascontiguousarray(v).tobytes()) for k, v in encoded.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # commit point
    return final


def latest_step(directory) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def _committed_steps(directory: pathlib.Path) -> list[int]:
    if not directory.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                  if p.is_dir() and p.name.startswith("step_")
                  and not p.name.endswith(".tmp")
                  and (p / "manifest.json").exists())


def _load_one(path: pathlib.Path, template, process_id: int):
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / f"shard_{process_id}.npz") as z:
        flat = {k.replace("\x1f", "/"): z[k] for k in z.files}
    crcs = manifest.get("crc32", {})
    for k, want in crcs.items():
        if k not in flat:
            raise ValueError(f"corrupt checkpoint {path.name}: array {k!r} "
                             "listed in the manifest is missing")
        got = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
        if got != int(want):
            raise ValueError(
                f"corrupt checkpoint {path.name}: crc32 mismatch on {k!r} "
                f"(stored {int(want):#010x}, computed {got:#010x}) — the "
                "blob was truncated or bit-flipped in storage")
    for k, want in manifest["dtypes"].items():
        if k in flat and str(flat[k].dtype) != want:
            import ml_dtypes
            flat[k] = flat[k].view(np.dtype(getattr(ml_dtypes, want, want)))
    return _unflatten_like(template, flat), manifest["extra"]


def load_checkpoint(directory, template, *, step: int | None = None,
                    shardings=None, process_id: int = 0):
    """Restore into the structure of ``template``; optionally re-shard onto
    ``shardings`` (same pytree structure) — the elastic-rescale path.

    Integrity: every array's crc32 (recorded in the manifest since the
    guarded-runtime schema) is verified before deserializing. With
    ``step=None`` the restore walks committed steps NEWEST-FIRST and falls
    back past corrupted/truncated ones (each skip logged), raising only
    when no step loads cleanly; an explicit ``step`` fails hard instead.
    Pre-crc manifests (no ``crc32`` field) load unverified.
    """
    directory = pathlib.Path(directory)
    if step is not None:
        candidates = [step]
    else:
        candidates = list(reversed(_committed_steps(directory)))
        if not candidates:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    last_err: Exception | None = None
    for s in candidates:
        path = directory / f"step_{s:010d}"
        try:
            tree, extra = _load_one(path, template, process_id)
        except (ValueError, OSError, KeyError) as e:
            if step is not None:
                raise
            log.warning("checkpoint %s is corrupt (%s); falling back to "
                        "the previous committed step", path.name, e)
            last_err = e
            continue
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, sh: jax.device_put(x, sh), tree, shardings)
        return tree, extra, s
    raise ValueError(
        f"every committed checkpoint in {directory} failed integrity "
        f"verification; newest error: {last_err}")


@dataclasses.dataclass
class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async saves."""

    directory: str
    keep: int = 3
    _worker: threading.Thread | None = None

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def save(self, step: int, tree, *, extra=None, blocking=True):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before async

        def do():
            save_checkpoint(self.directory, step, host_tree, extra=extra)
            self._gc()

        if blocking:
            do()
        else:
            self._worker = threading.Thread(target=do, daemon=True)
            self._worker.start()

    def restore(self, template, *, step=None, shardings=None):
        return load_checkpoint(self.directory, template, step=step,
                               shardings=shardings)

    def _gc(self):
        d = pathlib.Path(self.directory)
        steps = sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(d / f"step_{s:010d}", ignore_errors=True)
