"""Loop-aware analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every instruction ONCE —
a ``lax.scan`` over 61 layers reports 1/61 of the real FLOPs (verified
experimentally; see EXPERIMENTS §Roofline methodology). All our models scan
over layers precisely so HLO stays small, so the roofline terms MUST
multiply while-loop bodies by their trip counts. This module parses the
optimized HLO text and computes, recursively through while/fusion/call ops:

  * flops             — 2·prod(result)·prod(contracted dims) per dot/conv
                        (contracted sizes from a module-wide name→shape
                        registry, since operands are printed as bare names)
  * hbm_bytes         — Σ (operand + result bytes) of executed top-level
                        instructions (post-fusion this is a faithful HBM
                        traffic model: a fusion reads its params and writes
                        its outputs exactly once)
  * collective_bytes  — wire-byte model from per-shard buffer size b and
                        replica-group size g: all-reduce 2·b·(g-1)/g,
                        all-gather / reduce-scatter / all-to-all b·(g-1)/g,
                        collective-permute b

All shapes in optimized HLO are PER-DEVICE, so every number reported is
per-chip. Trip counts come from the integer constant in each while's
condition computation (static for lax.scan; falls back to 1 with a flag).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+([\w\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_KINDS = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "partition-id", "replica-id", "after-all",
               "domain", "opt-barrier"}


def _shapes_in(text: str):
    return _SHAPE_RE.findall(text)


def _shape_bytes(text: str) -> float:
    total = 0
    for dt, dims in _shapes_in(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return float(total)


def _first_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _result_elems(text: str) -> float:
    total = 0
    for dt, dims in _shapes_in(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return float(total)


@dataclasses.dataclass
class Instruction:
    name: str
    result: str
    kind: str
    args: str        # text inside op(...), up to first ')'
    attrs: str       # text after the args


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list


def parse_hlo(text: str) -> tuple[dict, dict]:
    """Returns (computations, name→result-shape-text registry)."""
    comps: dict[str, Computation] = {}
    shapes: dict[str, str] = {}
    cur = None
    for raw in text.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" "):
            m = _HDR_RE.match(raw.strip())
            if m:
                cur = Computation(m.group(2), [])
                comps[cur.name] = cur
                if m.group(1):
                    comps["__entry__"] = cur
                continue
            if raw.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INST_RE.match(raw)
        if not m:
            continue
        name, result, kind, rest = m.groups()
        args, _, attrs = rest.partition(")")
        inst = Instruction(name, result, kind, args, attrs)
        cur.instructions.append(inst)
        shapes[name] = result
    return comps, shapes


def _operands(inst: Instruction) -> list[str]:
    return re.findall(r"%([\w\.\-]+)", inst.args)


def _attr_comp(inst: Instruction, attr: str):
    m = re.search(attr + r"=%?([\w\.\-]+)", inst.attrs)
    return m.group(1) if m else None


def _trip_count(cond: Computation | None) -> int | None:
    if cond is None:
        return None
    best = None
    for inst in cond.instructions:
        if inst.kind == "constant":
            m = re.fullmatch(r"(-?\d+)", inst.args.strip())
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    return best


def _group_size(inst: Instruction, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", inst.attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", inst.attrs)
    if m:
        return int(m.group(2))
    return default


def _big_operand_feeds_buffer(dus: Instruction, pname: str,
                              comp: "Computation") -> bool:
    """True if ``pname`` reaches the dynamic-update-slice's BUFFER argument
    (operand 0) through transparent ops — i.e. the aliased in-place case."""
    ops = _operands(dus)
    if not ops:
        return False
    insts = {i.name: i for i in comp.instructions}
    name, seen = ops[0], set()
    while name and name not in seen:
        seen.add(name)
        if name == pname:
            return True
        i2 = insts.get(name)
        if i2 is None or i2.kind not in ("convert", "bitcast", "copy",
                                         "reshape", "broadcast"):
            return False
        nxt = _operands(i2)
        name = nxt[0] if nxt else None
    return False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.collective_bytes += other.collective_bytes * times
        self.unknown_trip_loops += other.unknown_trip_loops
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] += v * times


class Analyzer:
    def __init__(self, comps: dict, shapes: dict, default_group: int):
        self.comps = comps
        self.shapes = shapes
        self.default_group = default_group
        self.cache: dict[str, Cost] = {}

    # ops that neither read nor write HBM inside a fusion — we walk through
    # them when tracing a parameter to its "terminal" consumers
    _TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "broadcast"}

    def _fusion_traffic(self, inst: Instruction) -> float:
        """HBM bytes for a fusion, alias/slice-aware.

        Patterns XLA executes with O(slice) traffic that naive
        operand+result counting books at O(buffer):
          * a parameter consumed (possibly through converts/bitcasts) ONLY
            by dynamic-slice ops — the lax.scan per-layer stack access —
            → charge the slice bytes;
          * a parameter consumed ONLY as the buffer argument of
            dynamic-update-slice — the in-place cache update, aliased under
            donation (GSPMD's sharded-DUS select counts as buffer use too)
            → charge 0 read; the write is the update-slice size.
        """
        sub = _attr_comp(inst, "calls")
        comp = self.comps.get(sub or "")
        if comp is None:
            return _shape_bytes(inst.result) + self.operand_bytes(inst)
        ops = _operands(inst)

        params: dict[int, str] = {}
        consumers: dict[str, list] = {}
        for i2 in comp.instructions:
            if i2.kind == "parameter":
                m = re.fullmatch(r"(-?\d+)", i2.args.strip())
                if m:
                    params[int(m.group(1))] = i2.name
            for o in _operands(i2):
                consumers.setdefault(o, []).append(i2)

        def terminals(name, seen=None):
            """Terminal (non-transparent) consumers of ``name``."""
            seen = seen if seen is not None else set()
            outs = []
            for c in consumers.get(name, []):
                if c.name in seen:
                    continue
                seen.add(c.name)
                if c.kind in self._TRANSPARENT:
                    outs.extend(terminals(c.name, seen))
                else:
                    outs.append(c)
            return outs

        def root_inst():
            r = comp.instructions[-1]
            while r.kind in self._TRANSPARENT:
                srcs = [s for s in _operands(r) if s in self.shapes]
                if not srcs:
                    break
                nxt = next((i2 for i2 in comp.instructions
                            if i2.name == srcs[0]), None)
                if nxt is None:
                    break
                r = nxt
            return r

        root = root_inst()
        read = 0.0
        write = _shape_bytes(inst.result)
        for idx, opname in enumerate(ops):
            full_b = _shape_bytes(self.shape_text(opname))
            pname = params.get(idx)
            if pname is None:
                read += full_b
                continue
            terms = terminals(pname)
            if terms and all(t.kind == "dynamic-slice" for t in terms):
                read += sum(_shape_bytes(t.result) for t in terms)
            elif terms and all(
                    t.kind == "dynamic-update-slice" and
                    _big_operand_feeds_buffer(t, pname, comp)
                    for t in terms):
                read += 0.0                    # aliased in-place buffer
            else:
                read += full_b
        if root.kind == "dynamic-update-slice":
            upd_ops = _operands(root)
            if len(upd_ops) >= 2:
                write = _shape_bytes(self.shapes.get(upd_ops[1], ""))
        return read + write

    def shape_text(self, name: str) -> str:
        return self.shapes.get(name, "")

    def operand_bytes(self, inst: Instruction) -> float:
        return sum(_shape_bytes(self.shape_text(o)) for o in _operands(inst))

    def dot_flops(self, inst: Instruction) -> float:
        elems = _result_elems(inst.result)
        ops = _operands(inst)
        if not ops:
            return 0.0
        lhs_dims = _first_dims(self.shape_text(ops[0]))
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
        contracted = 1
        if m and m.group(1):
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    contracted *= lhs_dims[ci]
        return 2.0 * elems * contracted

    def comp_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self.cache:
            return self.cache[key]
        cost = Cost()
        self.cache[key] = cost
        comp = self.comps.get(name)
        if comp is None:
            return cost
        if fused:
            # inside a fused computation only the MXU ops matter — byte
            # traffic is accounted at the fusion boundary by the caller
            for inst in comp.instructions:
                if inst.kind in ("dot", "convolution"):
                    cost.flops += self.dot_flops(inst)
                elif inst.kind in ("fusion", "call"):
                    sub = _attr_comp(inst, "calls") or _attr_comp(inst, "to_apply")
                    if sub:
                        cost.add(self.comp_cost(sub, fused=True))
            return cost
        for inst in comp.instructions:
            k = inst.kind
            if k in _FREE_KINDS:
                continue
            if k == "while":
                body = self.comp_cost(_attr_comp(inst, "body") or "")
                cond_name = _attr_comp(inst, "condition") or ""
                trips = _trip_count(self.comps.get(cond_name))
                if trips is None:
                    trips = 1
                    cost.unknown_trip_loops += 1
                cost.add(body, trips)
                cost.add(self.comp_cost(cond_name), trips)
                continue
            if k == "conditional":
                subs = re.findall(r"%([\w\.\-]+)", inst.attrs)
                branch = [self.comp_cost(s) for s in subs if s in self.comps]
                if branch:
                    cost.add(max(branch, key=lambda c: c.flops + c.hbm_bytes))
                cost.hbm_bytes += _shape_bytes(inst.result)
                continue
            if k in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                     "scatter", "select-and-scatter", "reduce-window"):
                for attr in ("calls", "to_apply"):
                    sub = _attr_comp(inst, attr)
                    if sub:
                        cost.add(self.comp_cost(sub, fused=True))
                if k == "fusion":
                    cost.hbm_bytes += self._fusion_traffic(inst)
                else:
                    cost.hbm_bytes += _shape_bytes(inst.result)
                    cost.hbm_bytes += self.operand_bytes(inst)
                continue
            if k in ("dot", "convolution"):
                cost.flops += self.dot_flops(inst)
                cost.hbm_bytes += _shape_bytes(inst.result)
                cost.hbm_bytes += self.operand_bytes(inst)
                continue
            if k in _COLLECTIVES or (k.endswith("-start")
                                     and k[:-6] in _COLLECTIVES):
                kind = k[:-6] if k.endswith("-start") else k
                b = _shape_bytes(inst.result)
                if k.endswith("-start"):
                    b /= 2.0          # result tuple repeats the buffer
                g = _group_size(inst, self.default_group)
                if kind == "all-reduce":
                    wire = 2.0 * b * (g - 1) / max(g, 1)
                elif kind == "collective-permute":
                    wire = float(b)
                else:
                    wire = float(b) * (g - 1) / max(g, 1)
                cost.collective_bytes += wire
                cost.collective_breakdown[kind] += wire
                cost.hbm_bytes += 2.0 * b
                continue
            if k.endswith("-done"):
                continue
            # generic top-level op (copy, dynamic-update-slice, iota, ...)
            cost.hbm_bytes += _shape_bytes(inst.result)
            if k in ("copy", "dynamic-slice", "dynamic-update-slice", "slice",
                     "concatenate", "transpose", "convert", "broadcast",
                     "reshape", "select", "compare", "add", "multiply",
                     "pad", "gather", "iota", "exponential", "tanh"):
                cost.hbm_bytes += self.operand_bytes(inst)
        return cost


def analyze(hlo_text: str, *, default_group: int = 1) -> dict:
    """Entry point: per-chip loop-aware cost of an optimized HLO module."""
    comps, shapes = parse_hlo(hlo_text)
    if "__entry__" not in comps:
        raise ValueError("could not locate ENTRY computation")
    an = Analyzer(comps, shapes, default_group)
    cost = an.comp_cost(comps["__entry__"].name)
    return {
        "flops_per_chip": cost.flops,
        "hbm_bytes_per_chip": cost.hbm_bytes,
        "collective_wire_bytes_per_chip": cost.collective_bytes,
        "collective_breakdown": dict(cost.collective_breakdown),
        "unknown_trip_loops": cost.unknown_trip_loops,
    }
