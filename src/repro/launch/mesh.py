"""Production meshes (contract §MULTI-POD DRY-RUN).

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 placeholder devices before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "dryrun.py must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older jax: no devices kwarg
        import numpy as np
        return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device subprocess tests."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
