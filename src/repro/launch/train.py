"""End-to-end training driver (deliverable b): adaptive-filter data pipeline
→ LM train loop with checkpoint/restart.

CPU-scale example (the ~100M-class config):
  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen2.5-14b --smoke --steps 200 --batch 8 --seq 256

``--smoke`` swaps in the reduced same-family config so the run fits a
laptop; on real hardware drop it and point --ckpt-dir at durable storage.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.paper_filters import DEFAULT as PAPER
from repro.core import (FilterPlan, OrderingConfig, TokenizeSpec,
                        build_session, paper_filters_4, paper_filters_cnf)
from repro.data.pipeline import Pipeline
from repro.data.stream import DriftConfig, LogStream
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.runtime import (FailureInjector, GracefulShutdown, GuardedSession,
                           TrainDriver)


def parse_capacity(text: str | None) -> int | str | None:
    """``--compact-capacity`` value: int, "auto", or None (batch width)."""
    if text is None:
        return None
    if text == "auto":
        return "auto"
    return int(text)


def build_pipeline(cfg, *, batch: int, seq: int, total_rows: int,
                   ordering: OrderingConfig, drift: DriftConfig,
                   shard_id: int = 0, num_shards: int = 1,
                   chain: str = "flat", filter_shards: int = 1,
                   filter_scope: str = "per_shard",
                   compact_output: bool = False,
                   compact_capacity: int | str | None = None,
                   exchange: str = "eager",
                   device_tokenize: bool = False,
                   guarded: bool = False):
    """One ingestion pipeline, declared as ONE ``FilterPlan``.

    Every CLI knob maps to a plan field (engine × scope × shards ×
    compaction × exchange × tokenize — the whole matrix is validated once,
    in the plan); ``build_session`` compiles it and the pipeline drives
    ``session.step``. ``filter_shards > 1`` needs that many visible devices
    — on a CPU host force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    if filter_shards > 1 and filter_shards > jax.device_count():
        raise SystemExit(
            f"--filter-shards {filter_shards} > visible devices "
            f"({jax.device_count()}); set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={filter_shards} "
            "or run on a bigger mesh")
    preds = (paper_filters_cnf if chain == "cnf" else paper_filters_4)("fig1")
    plan = FilterPlan(
        predicates=preds, ordering=ordering, scope=filter_scope,
        shards=filter_shards, compact=compact_output,
        capacity=compact_capacity, exchange=exchange,
        tokenize=TokenizeSpec(cfg.vocab) if device_tokenize else None)
    session = build_session(plan)
    if guarded:
        # the self-healing wrapper: quarantine poisoned batches, validate
        # state at boundaries, retry/degrade/roll back on failures — the
        # pipeline drives it through the identical step API
        session = GuardedSession(session)
    if filter_shards > 1:
        from repro.data.pipeline import make_pipeline
        return make_pipeline(session, total_rows=total_rows,
                             batch_rows=65536, drift=drift, batch_size=batch,
                             seq_len=seq, vocab_size=cfg.vocab)
    stream = LogStream(total_rows=total_rows, batch_rows=65536,
                       drift=drift, shard_id=shard_id, num_shards=num_shards)
    return Pipeline(stream, session, batch_size=batch, seq_len=seq,
                    vocab_size=cfg.vocab)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--rows", type=int, default=20_000_000)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--chain", choices=["flat", "cnf"], default="flat",
                    help="filter shape: the paper's conjunction or its "
                         "CNF (AND-of-OR) variant")
    ap.add_argument("--filter-shards", type=int, default=1,
                    help="run the adaptive filter data-parallel over this "
                         "many mesh shards (shard_map; needs that many "
                         "visible devices)")
    ap.add_argument("--filter-scope",
                    choices=["per_batch", "per_shard", "centralized"],
                    default="per_shard",
                    help="lifetime/locality of the adaptive metadata "
                         "(paper §2.2)")
    ap.add_argument("--compact-output", action="store_true",
                    help="device-side survivor compaction (padded gather + "
                         "count instead of a host boolean index)")
    ap.add_argument("--compact-capacity", default=None,
                    help="compaction width: an int, or 'auto' to track the "
                         "monitor lane's pass-rate (slack-padded, "
                         "re-quantized to 128s at epoch boundaries); "
                         "default = batch width (lossless)")
    ap.add_argument("--exchange",
                    choices=["eager", "deferred", "deferred-async"],
                    default="eager",
                    help="CENTRALIZED stat exchange cadence: per-step psum "
                         "(eager), one collective per epoch (deferred), or "
                         "epoch-late folding (deferred-async)")
    ap.add_argument("--device-tokenize", action="store_true",
                    help="tokenize/pack the padded compacted buffers on "
                         "device (needs --compact-output); the host only "
                         "ever sees the dense token stream")
    ap.add_argument("--guarded", action="store_true",
                    help="wrap the filter session in the self-healing "
                         "GuardedSession (quarantine poisoned batches, "
                         "state validation, retry/degrade/rollback)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg, peak_lr=args.lr,
                                      warmup=20, total=args.steps),
                      donate_argnums=(0, 1))

    ordering = OrderingConfig(collect_rate=PAPER.ordering.collect_rate,
                              calculate_rate=500_000,
                              momentum=PAPER.ordering.momentum)
    pipeline = build_pipeline(cfg, batch=args.batch, seq=args.seq,
                              total_rows=args.rows, ordering=ordering,
                              drift=PAPER.drift, chain=args.chain,
                              filter_shards=args.filter_shards,
                              filter_scope=args.filter_scope,
                              compact_output=args.compact_output,
                              compact_capacity=parse_capacity(
                                  args.compact_capacity),
                              exchange=args.exchange,
                              device_tokenize=args.device_tokenize,
                              guarded=args.guarded)

    driver = TrainDriver(step_fn=step_fn, pipeline=pipeline, params=params,
                         opt_state=opt_state, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         injector=FailureInjector())
    if args.resume and driver.try_restore():
        print(f"[train] resumed from step {driver.step}")

    t0 = time.time()
    with GracefulShutdown() as stop:
        done = driver.run(args.steps, stop=stop)
    dt = time.time() - t0
    if stop.requested:
        # the driver already flushed a final checkpoint before returning
        print(f"[train] shutdown requested at step {driver.step}: "
              f"checkpoint flushed to {args.ckpt_dir}")
        print(f"[train] resume: python -m repro.launch.train --resume "
              f"--ckpt-dir {args.ckpt_dir} --arch {args.arch} "
              f"--steps {args.steps}"
              + (" --smoke" if args.smoke else "")
              + (" --guarded" if args.guarded else ""))
    losses = driver.history
    print(f"[train] done={done} steps={driver.step} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({dt:.1f}s, {driver.step / max(dt, 1e-9):.2f} steps/s)"
          + (f" guard[{pipeline._session.health.summary()}]"
             if args.guarded else ""))
    print(f"[train] pipeline: rows_in={pipeline.rows_in} "
          f"rows_pass={pipeline.rows_pass} "
          f"filter perm={pipeline.last_metrics.get('perm')} "
          f"epochs={pipeline.last_metrics.get('epoch')} "
          f"n_dropped={pipeline.last_metrics.get('n_dropped', 0)}"
          + (f" per_shard={pipeline.last_metrics['n_dropped_per_shard']}"
             if "n_dropped_per_shard" in pipeline.last_metrics else ""))


if __name__ == "__main__":
    main()
