"""Serving driver: batched requests through a guardrail predicate chain
(the paper's operator on the serving path) into prefill + decode.

The adaptive filter plays the role production guardrails play: a chain of
request-rejection predicates (rate limits, token budgets, heuristic abuse
scores) whose costs/selectivities drift with traffic mix — reordered online
exactly like the data-pipeline filters.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --requests 64 --new-tokens 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import (FilterPlan, OP_GT, OP_LT, OrderingConfig, Predicate,
                        build_session)
from repro.models.registry import batch_for, build_model
from repro.runtime import GracefulShutdown, GuardedSession


def guardrail_chain():
    """Request-feature predicates: col0=prompt_len, col1=abuse_score,
    col2=user_budget, col3=allowlist flag. Admission policy (CNF):

        len_ok AND (allowlisted OR budget_ok) AND (allowlisted OR abuse_ok)

    i.e. ``allowlisted OR (budget_ok AND abuse_ok)`` distributed into
    AND-of-OR groups — allowlisted traffic skips the expensive budget/abuse
    checks via the OR short-circuit, and the adaptive ordering learns to
    probe the cheap allowlist bit first when allowlisted traffic dominates.
    """
    allow = dict(column=3, op=OP_GT, t1=0.5, static_cost=0.2)
    return [
        Predicate("len_ok", column=0, op=OP_LT, t1=900.0, static_cost=1.0),
        Predicate("allow_b", group="allow_or_budget", **allow),
        Predicate("budget_ok", column=2, op=OP_GT, t1=10.0, static_cost=1.5,
                  group="allow_or_budget"),
        Predicate("allow_a", group="allow_or_abuse", **allow),
        Predicate("abuse_ok", column=1, op=OP_LT, t1=0.92, static_cost=4.0,
                  group="allow_or_abuse"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--guarded", action="store_true",
                    help="wrap the guardrail session in the self-healing "
                         "GuardedSession (quarantine poisoned request "
                         "batches, validate state, degrade on failures) "
                         "and report its health counters")
    ap.add_argument("--state-out", default="/tmp/repro_serve_state.json",
                    help="where a graceful SIGINT/SIGTERM flushes the "
                         "guardrail OrderState (versioned session blob)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    # the guardrail stage is ONE declarative plan: compile it to a session
    # and drive the single step entry point (same API the data pipelines
    # use, so serve/train metrics agree field-for-field)
    session = build_session(FilterPlan(
        predicates=guardrail_chain(),
        ordering=OrderingConfig(collect_rate=4, calculate_rate=64,
                                momentum=0.3)))
    if args.guarded:
        session = GuardedSession(session)
    fstate = session.init_state()

    rng = np.random.default_rng(0)
    admitted = rejected = dropped = 0
    fmetrics = {}
    t0 = time.time()
    stop = GracefulShutdown()
    with stop:
        for i in range(0, args.requests, args.batch):
            if stop.requested:
                break
            feats = np.stack([rng.normal(600, 250, args.batch),
                              rng.beta(2, 8, args.batch),
                              rng.normal(50, 30, args.batch),
                              (rng.uniform(size=args.batch) < 0.3)
                              .astype(float),
                              ]).astype(np.float32)
            fstate, res = session.step(fstate, feats)
            mask = res.mask_np
            fmetrics = res.metrics_dict()
            admitted += int(mask.sum())
            rejected += int((~mask).sum())
            dropped += fmetrics["n_dropped"]
            if not mask.any():
                continue
            batch = batch_for(cfg, args.batch, args.prompt_len,
                              kind="prefill")
            batch.pop("labels", None)
            logits, cache = prefill(params, batch)
            cap = args.prompt_len + args.new_tokens
            cache = _grow_cache(model, cache, args.batch, cap)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for t in range(args.new_tokens):
                if cfg.embeds_input:
                    step_in = jnp.zeros((args.batch, 1, cfg.d_model),
                                        jnp.bfloat16)
                else:
                    step_in = tok
                logits, cache = decode(params, step_in, cache,
                                       jnp.asarray(args.prompt_len + t))
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    if stop.requested:
        # graceful shutdown: flush the guardrail state and say how to resume
        blob = session.save_state(fstate)
        payload = {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                   for k, v in blob.items() if k != "arrays"}
        payload["arrays"] = {k: np.asarray(v).tolist()
                             for k, v in blob["arrays"].items()}
        payload["dtypes"] = {k: str(np.asarray(v).dtype)
                             for k, v in blob["arrays"].items()}
        with open(args.state_out, "w") as f:
            json.dump(payload, f)
        print(f"[serve] shutdown requested: guardrail state flushed to "
              f"{args.state_out}")
        print(f"[serve] resume: python -m repro.launch.serve --arch "
              f"{args.arch} (state blob restores via "
              "FilterSession.restore_state)")
    health = f" guard[{session.health.summary()}]" if args.guarded else ""
    print(f"[serve] admitted={admitted} rejected={rejected} "
          f"n_dropped={dropped} "
          f"guardrail perm={fmetrics.get('perm')} "
          f"epochs={fmetrics.get('epoch')} ({dt:.1f}s){health}")


def _grow_cache(model, cache, batch, capacity):
    """Pad prefill-sized cache buffers out to decode capacity."""
    import jax.numpy as jnp

    fresh = model.init_cache(batch, capacity)

    def fit(old, new):
        if old.shape == new.shape:
            return old
        pads = [(0, n - o) for o, n in zip(old.shape, new.shape)]
        return jnp.pad(old, pads)

    return jax.tree.map(fit, cache, fresh)


if __name__ == "__main__":
    main()
