"""Serving CLI: the continuous-batching admission server, thin.

All mechanism lives in ``repro.serving`` — this launcher only builds the
pieces (drifting-mix traffic → ``RequestStream``, guardrail plan →
session, slot executor) and wires them into ``AdmissionServer``, then
writes ``BENCH_serve.json`` and applies the CI smoke gates:

  * PARITY (correctness, hard): the queued server's admit/reject
    sequence and final ``OrderState`` must be bit-identical to a
    synchronous reference run over the same seeded traffic — queuing
    changes latency, never admission decisions.
  * requests/sec and p99 admission latency (perf, sim executor only):
    conservative absolute floors that catch gross stalls (per-batch
    recompiles, a blocked queue) without flaking on slow CI runners.

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --executor model \
      --arch gemma2-9b --smoke --requests 64 --new-tokens 16
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import FilterPlan, OrderingConfig, build_session
from repro.data.stream import RequestStream
from repro.runtime import GracefulShutdown, GuardedSession, GuardPolicy
from repro.serving import (AdmissionServer, ServerConfig, SimExecutor,
                           TrafficConfig, TrafficGenerator, guardrail_chain,
                           phase_of, synchronous_reference)

__all__ = ["guardrail_chain", "ModelSlotExecutor", "main"]


class ModelSlotExecutor:
    """Real prefill/decode in the slots: each admitted request prefills
    a batch-1 prompt into its freed slot and decodes one token per
    server tick until ``new_tokens`` are out — continuous batching at
    slot granularity (per-slot caches stay independent; packing the
    per-tick decodes into one batched call is the ROADMAP follow-up)."""

    def __init__(self, arch: str, smoke: bool, prompt_len: int,
                 new_tokens: int):
        import jax

        from repro.configs import get_config, get_smoke_config
        from repro.models.registry import batch_for, build_model

        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.model = build_model(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self._jit_prefill = jax.jit(self.model.prefill)
        self._jit_decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self._batch_for = batch_for
        self.prompt_len = prompt_len
        self.new_tokens = new_tokens

    def prefill(self, ticket):
        import jax.numpy as jnp

        batch = self._batch_for(self.cfg, 1, self.prompt_len, kind="prefill")
        batch.pop("labels", None)
        logits, cache = self._jit_prefill(self.params, batch)
        cache = _grow_cache(self.model, cache, 1,
                            self.prompt_len + self.new_tokens)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return {"cache": cache, "tok": tok, "t": 0}

    def advance(self, ctx):
        import jax.numpy as jnp

        if self.cfg.embeds_input:
            step_in = jnp.zeros((1, 1, self.cfg.d_model), jnp.bfloat16)
        else:
            step_in = ctx["tok"]
        logits, cache = self._jit_decode(self.params, step_in, ctx["cache"],
                                         jnp.asarray(self.prompt_len
                                                     + ctx["t"]))
        ctx = {"cache": cache,
               "tok": jnp.argmax(logits, -1)[:, None].astype(jnp.int32),
               "t": ctx["t"] + 1}
        return ctx, ctx["t"] >= self.new_tokens


def _grow_cache(model, cache, batch, capacity):
    """Pad prefill-sized cache buffers out to decode capacity."""
    import jax
    import jax.numpy as jnp

    fresh = model.init_cache(batch, capacity)

    def fit(old, new):
        if old.shape == new.shape:
            return old
        pads = [(0, n - o) for o, n in zip(old.shape, new.shape)]
        return jnp.pad(old, pads)

    return jax.tree.map(fit, cache, fresh)


def _parity(report, ref_masks, ref_blob) -> dict:
    """Bit-compare the server run against the synchronous oracle."""
    masks_equal = set(report.masks) == set(ref_masks) and all(
        np.array_equal(report.masks[b], ref_masks[b]) for b in ref_masks)
    a, b = report.state_blob["arrays"], ref_blob["arrays"]
    state_equal = set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)
    return {"checked": True, "masks_equal": bool(masks_equal),
            "state_equal": bool(state_equal),
            "ok": bool(masks_equal and state_equal)}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small 3-phase run + parity/perf gates + "
                         "BENCH_serve.json (the CI bench-serve job)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests (default 4096; 1536 under --smoke)")
    ap.add_argument("--batch", type=int, default=64,
                    help="admission micro-batch rows")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--queue-depth", type=int, default=8)
    ap.add_argument("--max-backlog", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--users", type=int, default=1 << 20,
                    help="persistent synthetic user identities")
    ap.add_argument("--phase-requests", type=int, default=None,
                    help="rows per traffic phase (default requests//3: the "
                         "run sweeps organic → abuse storm → enterprise)")
    ap.add_argument("--guarded", action="store_true",
                    help="wrap the gate in the self-healing GuardedSession "
                         "(always on under --smoke so BENCH_serve.json "
                         "carries real GuardHealth counters)")
    ap.add_argument("--promote-after", type=int, default=4,
                    help="clean validated boundaries before a degraded "
                         "rung re-promotes (guarded runs)")
    ap.add_argument("--executor", choices=("sim", "model"), default="sim")
    ap.add_argument("--arch", default="gemma2-9b",
                    help="model arch for --executor model")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    ap.add_argument("--state-out", default="/tmp/repro_serve_state.json",
                    help="where a graceful SIGINT/SIGTERM flushes the "
                         "guardrail OrderState (versioned session blob)")
    ap.add_argument("--no-reference", action="store_true",
                    help="skip the synchronous parity reference run")
    ap.add_argument("--gate-rps", type=float, default=100.0,
                    help="smoke gate: minimum sustained requests/sec")
    ap.add_argument("--gate-p99-ms", type=float, default=2500.0,
                    help="smoke gate: maximum p99 admission latency")
    args = ap.parse_args(argv)

    requests = args.requests if args.requests is not None \
        else (1536 if args.smoke else 4096)
    requests = (requests // args.batch) * args.batch or args.batch
    phase_requests = args.phase_requests or max(requests // 3, args.batch)
    guarded = args.guarded or args.smoke

    tcfg = TrafficConfig(seed=args.seed, n_users=args.users,
                         phase_requests=phase_requests)
    traffic = TrafficGenerator(tcfg)
    n_batches = requests // args.batch
    phases_seen = sorted({phase_of(tcfg, b * args.batch + args.batch / 2)
                          for b in range(n_batches)})

    # the guardrail stage is ONE declarative plan: compile it to a session
    # and drive the single step entry point (same API the data pipelines
    # use, so serve/train metrics agree field-for-field)
    plan = FilterPlan(
        predicates=guardrail_chain(),
        ordering=OrderingConfig(collect_rate=4, calculate_rate=64,
                                momentum=0.3))
    session = build_session(plan)
    if guarded:
        session = GuardedSession(
            session, GuardPolicy(promote_after=args.promote_after))

    if args.executor == "model":
        executor = ModelSlotExecutor(args.arch, args.smoke, args.prompt_len,
                                     args.new_tokens)
    else:
        executor = SimExecutor(max_decode_steps=args.new_tokens)

    server = AdmissionServer(
        session,
        RequestStream(traffic.gen, total_rows=requests,
                      batch_rows=args.batch),
        ServerConfig(num_slots=args.slots, queue_depth=args.queue_depth,
                     max_backlog=args.max_backlog),
        executor=executor,
        warmup_batch=traffic.gen(0, 0, args.batch))

    stop = GracefulShutdown()
    t0 = time.time()
    with stop:
        report = server.run(stop=stop)
    dt = time.time() - t0

    if stop.requested:
        # graceful shutdown: the server drained in-flight slots and
        # flushed the final checkpoint into the report — persist it
        blob = report.state_blob
        payload = {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                   for k, v in blob.items() if k != "arrays"}
        payload["arrays"] = {k: np.asarray(v).tolist()
                             for k, v in blob["arrays"].items()}
        payload["dtypes"] = {k: str(np.asarray(v).dtype)
                             for k, v in blob["arrays"].items()}
        with open(args.state_out, "w") as f:
            json.dump(payload, f)
        print(f"[serve] shutdown requested: drained {len(report.results)} "
              f"results; guardrail state flushed to {args.state_out}")
        print("[serve] resume: restores via FilterSession.restore_state")

    parity = {"checked": False, "ok": None}
    if not args.no_reference and not stop.requested:
        ref_session = build_session(plan)
        ref_state, ref_masks = synchronous_reference(
            ref_session,
            RequestStream(traffic.gen, total_rows=requests,
                          batch_rows=args.batch))
        parity = _parity(report, ref_masks, ref_session.save_state(ref_state))

    m = report.metrics
    payload = {
        **m,
        "parity": parity,
        "config": {
            "requests": requests, "batch": args.batch, "slots": args.slots,
            "queue_depth": args.queue_depth, "seed": args.seed,
            "n_users": args.users, "phase_requests": phase_requests,
            "phases_seen": phases_seen, "guarded": guarded,
            "executor": args.executor, "smoke": args.smoke,
        },
    }
    with open(args.bench_out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

    lat = m["admission_latency_ms"]
    health = f" guard[{report.health_line}]" if report.health_line else ""
    print(f"[serve] {m['decided']}/{m['requests']} decided "
          f"(admitted={m['admit_rate']:.2f} rejected={m['reject_rate']:.2f} "
          f"quarantined={m['quarantine_rate']:.2f}) "
          f"{m['requests_per_sec']:.0f} req/s "
          f"p99 admission {lat['p99']:.1f}ms "
          f"occupancy {m['slot_occupancy']:.2f} "
          f"phases {phases_seen} ({dt:.1f}s){health}")
    print(f"[serve] wrote {args.bench_out}")

    failures = []
    if parity["checked"] and not parity["ok"]:
        failures.append(f"PARITY: queued admission diverged from the "
                        f"synchronous reference ({parity})")
    if args.smoke and args.executor == "sim" and not stop.requested:
        if m["requests_per_sec"] < args.gate_rps:
            failures.append(f"requests/sec {m['requests_per_sec']:.0f} "
                            f"< floor {args.gate_rps:.0f}")
        if lat["p99"] > args.gate_p99_ms:
            failures.append(f"p99 admission {lat['p99']:.1f}ms "
                            f"> ceiling {args.gate_p99_ms:.0f}ms")
    for msg in failures:
        print(f"[serve] GATE FAILED: {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
