"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape × mesh)
cell — no device allocation ever happens here (contract §MULTI-POD 2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models.registry import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel import sharding as shd

SDS = jax.ShapeDtypeStruct


def batch_struct(cfg, cell) -> dict:
    """ShapeDtypeStructs for one input batch (mirrors registry.batch_for)."""
    b, s = cell.global_batch, cell.seq_len
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["enc_embeds"] = SDS((b, cfg.enc_seq, cfg.d_model),
                                      jnp.bfloat16)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    if cell.kind == "train":
        batch["labels"] = SDS((b, s), jnp.int32)
        if cfg.embeds_input:
            batch.setdefault("tokens", SDS((b, s), jnp.int32))
    if cfg.rope_style == "mrope":
        batch["positions"] = SDS((3, b, s), jnp.int32)
    return batch


def _with_shardings(tree_shape, shardings):
    return jax.tree.map(lambda sds, sh: SDS(sds.shape, sds.dtype, sharding=sh),
                        tree_shape, shardings)


def make_cell(arch: str, shape: str, mesh, *,
              opt_cfg: AdamWConfig | None = None, fsdp: bool = True,
              cfg=None):
    """Returns (step_kind, args_sds_tuple, model, cfg) for lowering."""
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape]
    from repro.parallel import hints
    hints.enable(dp=tuple(a for a in mesh.axis_names if a != "model"),
                 tp="model", mesh=mesh)
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(
        state_dtype="bfloat16" if cfg.param_count() > 5e10 else "float32")

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = shd.params_shardings(params_shape, mesh, fsdp=fsdp)
    params_sds = _with_shardings(params_shape, p_sh)

    if cell.kind == "train":
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), params_shape)
        o_sh = (shd.params_shardings(opt_shape.m, mesh, fsdp=fsdp),
                shd.params_shardings(opt_shape.v, mesh, fsdp=fsdp),
                shd.replicated(mesh))
        opt_sds = type(opt_shape)(
            _with_shardings(opt_shape.m, o_sh[0]),
            _with_shardings(opt_shape.v, o_sh[1]),
            SDS(opt_shape.step.shape, opt_shape.step.dtype,
                sharding=o_sh[2]))
        batch_shape = batch_struct(cfg, cell)
        b_sh = shd.batch_shardings(batch_shape, mesh, cell.global_batch)
        batch_sds = _with_shardings(batch_shape, b_sh)
        return "train", (params_sds, opt_sds, batch_sds), model, cfg, opt_cfg

    if cell.kind == "prefill":
        batch_shape = batch_struct(cfg, cell)
        b_sh = shd.batch_shardings(batch_shape, mesh, cell.global_batch)
        batch_sds = _with_shardings(batch_shape, b_sh)
        return "prefill", (params_sds, batch_sds), model, cfg, opt_cfg

    # decode: one new token against a seq_len cache
    b = cell.global_batch
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(b, cell.seq_len))
    c_sh = shd.cache_shardings(cache_shape, mesh, b, cell.seq_len)
    cache_sds = _with_shardings(cache_shape, c_sh)
    if cfg.embeds_input:
        tok = SDS((b, 1, cfg.d_model), jnp.bfloat16,
                  sharding=shd.batch_shardings(
                      SDS((b, 1, cfg.d_model), jnp.bfloat16), mesh, b))
    else:
        tok = SDS((b, 1), jnp.int32,
                  sharding=shd.batch_shardings(
                      SDS((b, 1), jnp.int32), mesh, b))
    pos = SDS((), jnp.int32, sharding=shd.replicated(mesh))
    return "decode", (params_sds, tok, cache_sds, pos), model, cfg, opt_cfg
