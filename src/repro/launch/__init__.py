"""Launch layer: production mesh, input specs, train/serve steps, dry-run."""
