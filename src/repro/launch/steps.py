"""Jittable train / prefill / decode steps (what the dry-run lowers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(model, opt_cfg: AdamWConfig, *, peak_lr=3e-4,
                    warmup=2000, total=100_000, remat=True):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = cosine_schedule(opt_state.step, peak_lr=peak_lr,
                             warmup_steps=warmup, total_steps=total)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg, lr)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update(metrics)
        return params, opt_state, out

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)
    return decode_step
