import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_cell
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step

# TPU v5e hardware constants (contract §ROOFLINE)
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def lower_cell(arch: str, shape: str, multi_pod: bool, *, fsdp: bool = True,
               cfg_override=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kind, args, model, cfg, opt_cfg = make_cell(arch, shape, mesh,
                                                fsdp=fsdp, cfg=cfg_override)
    if kind == "train":
        step = make_train_step(model, opt_cfg)
        donate = (0, 1)
    elif kind == "prefill":
        step = make_prefill_step(model)
        donate = ()
    else:
        step = make_decode_step(model)
        donate = (2,)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return mesh, n_chips, kind, cfg, compiled, t_lower, t_compile


def analyze_cell(arch: str, shape: str, multi_pod: bool, *, fsdp: bool = True,
                 cfg_override=None, tag: str = "") -> dict:
    cell = SHAPES[shape]
    mesh, n_chips, kind, cfg, compiled, t_lower, t_compile = lower_cell(
        arch, shape, multi_pod, fsdp=fsdp, cfg_override=cfg_override)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    loop_aware = hlo_analysis.analyze(hlo, default_group=n_chips)

    # --- roofline terms (per-chip, seconds) --------------------------------
    flops_chip = loop_aware["flops_per_chip"]
    bytes_chip = loop_aware["hbm_bytes_per_chip"]
    coll_chip = loop_aware["collective_wire_bytes_per_chip"]
    t_compute = flops_chip / PEAK_FLOPS
    t_memory = bytes_chip / HBM_BW
    t_collective = coll_chip / LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]

    # --- analytic model FLOPs (contract: 6·N·D train / 2·N·D inference) ----
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * cell.global_batch
    model_flops_chip = model_flops / n_chips

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "n_chips": n_chips, "fsdp": fsdp, "tag": tag,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes
                            + mem.temp_size_in_bytes),
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "loop_aware": loop_aware,
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_collective,
            "dominant": dominant,
            "bound_s": max(t_compute, t_memory, t_collective),
        },
        "model_flops": model_flops,
        "model_flops_per_chip": model_flops_chip,
        "useful_flops_ratio": (model_flops_chip / flops_chip
                               if flops_chip else None),
        "mfu_upper_bound": (model_flops_chip / PEAK_FLOPS
                            / max(t_compute, t_memory, t_collective)
                            if max(t_compute, t_memory, t_collective) else None),
    }
    return result


def cell_filename(arch, shape, multi_pod, tag=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return ART_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def run_one(arch, shape, multi_pod, fsdp=True, tag=""):
    runnable, why = cell_is_runnable(arch, shape)
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out_path = cell_filename(arch, shape, multi_pod, tag)
    if not runnable:
        res = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "skip", "why": why, "tag": tag}
    else:
        try:
            res = analyze_cell(arch, shape, multi_pod, fsdp=fsdp, tag=tag)
        except Exception as e:  # a failure here is a bug in the system
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc(), "tag": tag}
    out_path.write_text(json.dumps(res, indent=2))
    status = res["status"]
    extra = ""
    if status == "ok":
        r = res["roofline"]
        extra = (f" dominant={r['dominant']}"
                 f" t=({r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                 f"{r['t_collective_s']:.3e})s"
                 f" mem={res['memory']['total_bytes']/2**30:.1f}GiB/chip"
                 f" compile={res['compile_s']:.0f}s")
    elif status == "error":
        extra = " " + res["error"].splitlines()[0]
    print(f"[dryrun] {arch} × {shape} × "
          f"{'2x16x16' if multi_pod else '16x16'}: {status}{extra}",
          flush=True)
    return res


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape × mesh) cell in subprocesses")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix (perf exps)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        import subprocess
        failures = 0
        for arch in ARCHS:
            for shape in SHAPES:
                for mesh in ("single", "multi"):
                    mp = mesh == "multi"
                    if args.skip_existing and \
                            cell_filename(arch, shape, mp, args.tag).exists():
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh]
                    if args.no_fsdp:
                        cmd.append("--no-fsdp")
                    if args.tag:
                        cmd += ["--tag", args.tag]
                    rc = subprocess.call(cmd)
                    failures += rc != 0
        print(f"[dryrun --all] done, {failures} subprocess failures")
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    rc = 0
    for mp in meshes[args.mesh]:
        res = run_one(args.arch, args.shape, mp, fsdp=not args.no_fsdp,
                      tag=args.tag)
        rc |= res["status"] == "error"
    return rc


if __name__ == "__main__":
    sys.exit(main())
