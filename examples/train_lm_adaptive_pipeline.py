"""End-to-end driver (deliverable b): train a reduced LM for a few hundred
steps on CPU, fed by the adaptive-filter ingestion pipeline, with
checkpoint/restart.

    PYTHONPATH=src python examples/train_lm_adaptive_pipeline.py

Equivalent CLI (any of the 10 archs, full configs on real hardware):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
        --steps 300 --batch 8 --seq 256
"""

import sys

from repro.launch import train


def main() -> None:
    sys.argv = [sys.argv[0], "--arch", "qwen2.5-14b", "--smoke",
                "--steps", "300", "--batch", "8", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_quickstart_ckpt"]
    train.main()


if __name__ == "__main__":
    main()
