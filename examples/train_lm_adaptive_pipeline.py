"""End-to-end driver (deliverable b): train a reduced LM for a few hundred
steps on CPU, fed by the adaptive-filter ingestion pipeline (declared as
one ``FilterPlan``, compiled by ``build_session``), with
checkpoint/restart.

    PYTHONPATH=src python examples/train_lm_adaptive_pipeline.py

Equivalent CLI (any of the 10 archs, full configs on real hardware):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
        --steps 300 --batch 8 --seq 256

``EXAMPLES_SMOKE_STEPS`` shrinks the run (the CI examples-smoke job sets
it so every example stays minutes-cheap).
"""

import os
import sys

from repro.launch import train


def build_plan():
    """A representative train-ingestion plan (CNF chain + compaction +
    device tokenize) — collected by ``python -m repro.analysis --chain``
    for chain linting."""
    from repro.core import FilterPlan, OrderingConfig, TokenizeSpec
    from repro.core.predicates import paper_filters_cnf

    return FilterPlan(
        predicates=paper_filters_cnf("fig1"),
        ordering=OrderingConfig(collect_rate=1000, calculate_rate=250_000,
                                momentum=0.3),
        compact=True, tokenize=TokenizeSpec(32000))


def main() -> None:
    steps = os.environ.get("EXAMPLES_SMOKE_STEPS", "300")
    sys.argv = [sys.argv[0], "--arch", "qwen2.5-14b", "--smoke",
                "--steps", steps, "--batch", "8", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_quickstart_ckpt"]
    train.main()


if __name__ == "__main__":
    main()
