"""Drift demo: the adaptive order tracks regime flips; cumulative row-level
work is compared against static orders (best/user/worst) and the
clairvoyant per-batch oracle, for both the paper-faithful controller and
the beyond-paper snap-on-flip variant (DESIGN §3, EXPERIMENTS §Perf).

Every policy is ONE ``FilterPlan`` — adaptive vs static is the plan's
``adaptive`` flag, a static order is just a reordered predicate chain —
compiled to a session and driven through the same ``session.step``.

    PYTHONPATH=src python examples/streaming_drift_demo.py
"""

import os

import jax.numpy as jnp

from repro.core import FilterPlan, OrderingConfig, build_session, pack, \
    paper_filters_4
from repro.core.predicates import eval_all
from repro.core.stats import expected_chain_cost
from repro.data.stream import DriftConfig, gen_batch

N_BATCHES = int(os.environ.get("EXAMPLES_SMOKE_BATCHES", "60"))
DRIFT = DriftConfig(kind="regime", period_rows=1_500_000, amplitude=1.8)


def run(plan: FilterPlan):
    session = build_session(plan)
    state = session.init_state()
    work = 0.0
    perms = []
    for b in range(N_BATCHES):
        cols = gen_batch(0, b, b * 65536, 65536, DRIFT)
        state, res = session.step(state, cols)
        work += float(res.metrics.work_units)
        perms.append(list(map(int, res.metrics.perm)))
    return work, perms


def build_plan() -> FilterPlan:
    """The paper-faithful adaptive plan this demo runs — collected by
    ``python -m repro.analysis --chain`` for chain linting."""
    return FilterPlan(
        predicates=paper_filters_4("fig1"),
        ordering=OrderingConfig(collect_rate=500, calculate_rate=100_000,
                                momentum=0.3))


def main() -> None:
    preds = paper_filters_4("fig1")
    specs = pack(preds)
    costs = jnp.asarray([p.static_cost for p in preds])

    ordering = OrderingConfig(collect_rate=500, calculate_rate=100_000,
                              momentum=0.3)
    snap = OrderingConfig(collect_rate=500, calculate_rate=100_000,
                          momentum=0.3, snap_threshold=1.3)

    w_paper, perms = run(FilterPlan(predicates=preds, ordering=ordering))
    w_snap, _ = run(FilterPlan(predicates=preds, ordering=snap))
    w_user, _ = run(FilterPlan(predicates=preds, adaptive=False))
    w_worst, _ = run(FilterPlan(predicates=[preds[i] for i in (3, 2, 1, 0)],
                                adaptive=False))

    # clairvoyant oracle: best order for each batch's true selectivities
    w_oracle = 0.0
    for b in range(N_BATCHES):
        cols = jnp.asarray(gen_batch(0, b, b * 65536, 65536, DRIFT))
        s = jnp.mean(eval_all(specs, cols), axis=1)
        perm = jnp.argsort((costs / costs.max()) / (1 - s))
        w_oracle += float(expected_chain_cost(costs, s, perm)) * 65536

    n_rows = N_BATCHES * 65536
    print(f"rows processed: {n_rows:,} (regime flips every "
          f"{DRIFT.period_rows:,})")
    print("order snapshots:", perms[::12])
    print(f"\n{'policy':28s} {'work/row':>9s} {'vs oracle':>10s}")
    for name, w in [("clairvoyant oracle", w_oracle),
                    ("adaptive + snap (beyond)", w_snap),
                    ("adaptive (paper)", w_paper),
                    ("static user order", w_user),
                    ("static worst order", w_worst)]:
        print(f"{name:28s} {w/n_rows:9.3f} {w/w_oracle:9.2f}x")


if __name__ == "__main__":
    main()
