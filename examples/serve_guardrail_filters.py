"""Serving example: batched requests through an adaptive guardrail chain
(the paper's operator on the serving path) into prefill + decode of a
reduced gemma2 config.

    PYTHONPATH=src python examples/serve_guardrail_filters.py
"""

import os
import sys

from repro.launch import serve


def build_plan():
    """The guardrail plan the serving path compiles — collected by
    ``python -m repro.analysis --chain`` for chain linting."""
    from repro.core import FilterPlan, OrderingConfig

    return FilterPlan(
        predicates=serve.guardrail_chain(),
        ordering=OrderingConfig(collect_rate=4, calculate_rate=64,
                                momentum=0.3))


def main() -> None:
    requests = os.environ.get("EXAMPLES_SMOKE_REQUESTS", "64")
    sys.argv = [sys.argv[0], "--arch", "gemma2-9b", "--smoke",
                "--requests", requests, "--batch", "8",
                "--prompt-len", "64", "--new-tokens", "8"]
    serve.main()


if __name__ == "__main__":
    main()
