"""Serving example: the continuous-batching admission server — queued
ingest of a drifting traffic mix, the adaptive guardrail chain as the
admission gate, and admitted requests packed into real prefill/decode
slots of a reduced gemma2 config.

    PYTHONPATH=src python examples/serve_guardrail_filters.py
"""

import os
import sys

from repro.launch import serve


def build_plan():
    """The guardrail plan the serving path compiles — collected by
    ``python -m repro.analysis --chain`` for chain linting."""
    from repro.core import FilterPlan, OrderingConfig

    return FilterPlan(
        predicates=serve.guardrail_chain(),
        ordering=OrderingConfig(collect_rate=4, calculate_rate=64,
                                momentum=0.3))


def main() -> int:
    requests = os.environ.get("EXAMPLES_SMOKE_REQUESTS", "64")
    return serve.main([
        "--smoke", "--executor", "model", "--arch", "gemma2-9b",
        "--requests", requests, "--batch", "8", "--slots", "4",
        "--prompt-len", "64", "--new-tokens", "8",
        "--bench-out", os.environ.get("EXAMPLES_BENCH_OUT",
                                      "/tmp/BENCH_serve_example.json"),
    ])


if __name__ == "__main__":
    sys.exit(main())
