"""Quickstart: adaptive filter ordering in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's 4-predicate chain over the synthetic drifting log stream,
runs it adaptively, and prints how the evaluation order tracks the data.
"""

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig,
                        paper_filters_4)
from repro.data.stream import DriftConfig, gen_batch


def main() -> None:
    preds = paper_filters_4("fig1")
    print("predicate chain (user statement order):")
    for i, p in enumerate(preds):
        print(f"  [{i}] {p.describe()}")

    filt = AdaptiveFilter(preds, AdaptiveFilterConfig(
        ordering=OrderingConfig(collect_rate=1000, calculate_rate=250_000,
                                momentum=0.3)))
    state = filt.init_state()
    step = jax.jit(filt.step)

    drift = DriftConfig(kind="regime", period_rows=600_000, amplitude=1.8)
    print("\nstreaming 2M rows with regime drift:")
    for b in range(32):
        cols = jnp.asarray(gen_batch(0, b, b * 65536, 65536, drift))
        state, mask, m = step(state, cols)
        if b % 4 == 3:
            print(f"  rows={65536*(b+1):>9,}  epoch={int(m.epoch)}  "
                  f"order={list(map(int, m.perm))}  "
                  f"work/row={float(m.work_units)/65536:.2f}  "
                  f"pass={int(m.n_pass)/65536:.3%}")
    print("\nranks (lower runs earlier):",
          [round(float(r), 3) for r in state.adj_rank])


if __name__ == "__main__":
    main()
