"""Quickstart: one plan, one session, one step entry point.

    PYTHONPATH=src python examples/quickstart.py

Declares the paper's 4-predicate chain as a ``FilterPlan``, compiles it to
a ``FilterSession``, and streams the synthetic drifting log through the
single ``session.step`` call — printing how the evaluation order tracks
the data.
"""

from repro.core import FilterPlan, OrderingConfig, build_session, \
    paper_filters_4
from repro.data.stream import DriftConfig, gen_batch


def build_plan() -> FilterPlan:
    """The plan this example runs — collected by ``python -m
    repro.analysis --chain`` so the chain is linted alongside the configs."""
    return FilterPlan(
        predicates=paper_filters_4("fig1"),
        ordering=OrderingConfig(collect_rate=1000, calculate_rate=250_000,
                                momentum=0.3))


def main() -> None:
    preds = paper_filters_4("fig1")
    print("predicate chain (user statement order):")
    for i, p in enumerate(preds):
        print(f"  [{i}] {p.describe()}")

    # the plan is the WHOLE configuration surface (engine, scope, shards,
    # compaction, exchange, tokenize all live here too — defaults shown)
    plan = build_plan()
    session = build_session(plan)
    state = session.init_state()

    drift = DriftConfig(kind="regime", period_rows=600_000, amplitude=1.8)
    print("\nstreaming 2M rows with regime drift:")
    for b in range(32):
        cols = gen_batch(0, b, b * 65536, 65536, drift)
        state, res = session.step(state, cols)
        if b % 4 == 3:
            m = res.metrics_dict()
            print(f"  rows={65536*(b+1):>9,}  epoch={m['epoch']}  "
                  f"order={m['perm']}  "
                  f"work/row={m['work_units']/65536:.2f}  "
                  f"pass={m['n_pass']/65536:.3%}")
    print("\nranks (lower runs earlier):",
          [round(float(r), 3) for r in state.adj_rank])


if __name__ == "__main__":
    main()
