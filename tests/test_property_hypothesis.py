"""Property-based tests (hypothesis) for the system's invariants.

1. OPTIMALITY: rank-ascending order minimizes expected chain cost over all
   permutations (the theorem the paper's §2.1 relies on) — checked by
   exhaustive enumeration on random (cost, selectivity) draws.
2. ORDER-INVARIANCE: the filter's boolean outcome is identical under every
   permutation (conjunction commutes) across all three backends.
3. MONITOR UNBIASEDNESS: stride sampling counts match dense counts on the
   sampled index set exactly, for any phase.
4. MOMENTUM CONTRACTION: the adj-rank recurrence is a contraction toward
   the stationary rank (|adj - r*| shrinks by factor m per epoch).
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import np_exec, predicates as P, stats as S
from repro.core.filter_exec import run_chain
from repro.core.predicates import Predicate

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")


@given(
    costs=st.lists(st.floats(0.05, 10.0), min_size=2, max_size=5),
    sel=st.lists(st.floats(0.01, 0.99), min_size=2, max_size=5),
)
def test_rank_order_minimizes_expected_cost(costs, sel):
    n = min(len(costs), len(sel))
    costs = jnp.asarray(costs[:n], jnp.float32)
    sel = jnp.asarray(sel[:n], jnp.float32)
    nc = costs / jnp.max(costs)
    rank_perm = np.asarray(S.order_from_ranks(nc / (1 - sel)))
    best = min(
        float(S.expected_chain_cost(costs, sel, jnp.asarray(p)))
        for p in itertools.permutations(range(n)))
    got = float(S.expected_chain_cost(costs, sel, jnp.asarray(rank_perm)))
    assert got <= best * (1 + 1e-5)


def _random_chain(seed):
    r = np.random.default_rng(seed)
    preds = [
        Predicate("a", 0, P.OP_GT, float(r.normal(0, 1)), static_cost=1.0),
        Predicate("b", 1, P.OP_LT, float(r.normal(0, 1)), static_cost=2.0),
        Predicate("c", 0, P.OP_BETWEEN, -0.5, t2=1.5, static_cost=1.5),
        Predicate("d", 2, P.OP_HASHMIX, 0.4 * P.MIX_MOD, rounds=4,
                  static_cost=5.0),
    ]
    cols = np.stack([r.normal(0, 1, 400), r.normal(0, 1, 400),
                     r.uniform(0, P.MIX_MOD, 400)]).astype(np.float32)
    return preds, cols


@given(seed=st.integers(0, 10_000),
       perm=st.permutations(list(range(4))))
def test_outcome_order_invariant_all_backends(seed, perm):
    preds, cols = _random_chain(seed)
    specs = P.pack(preds)
    jperm = jnp.asarray(perm, jnp.int32)
    base = run_chain(jnp.asarray(cols), specs, jnp.arange(4, dtype=jnp.int32),
                     collect_rate=97, sample_phase=0)
    permuted = run_chain(jnp.asarray(cols), specs, jperm,
                         collect_rate=97, sample_phase=0)
    np_mask, _, _ = np_exec.run_chain_np(cols, preds, perm)
    assert np.array_equal(np.asarray(base.mask), np.asarray(permuted.mask))
    assert np.array_equal(np.asarray(base.mask), np_mask)


@given(phase=st.integers(0, 96), n_rows=st.integers(1, 400))
def test_monitor_stride_sampling_exact(phase, n_rows):
    preds, cols = _random_chain(7)
    cols = cols[:, :n_rows]
    n_rows = cols.shape[1]
    specs = P.pack(preds)
    res = run_chain(jnp.asarray(cols), specs, jnp.arange(4, dtype=jnp.int32),
                    collect_rate=97, sample_phase=phase)
    # dense reference: indices where (i + phase) % 97 == 0
    idx = np.asarray([i for i in range(n_rows) if (i + phase) % 97 == 0])
    assert float(res.n_monitored) == len(idx)
    if len(idx):
        dense = np.asarray(P.eval_all(specs, jnp.asarray(cols)))
        np.testing.assert_allclose(
            np.asarray(res.cut_counts), (~dense[:, idx]).sum(axis=1))


@given(m=st.floats(0.0, 0.9), r_star=st.floats(0.1, 10.0),
       adj0=st.floats(0.0, 20.0))
def test_momentum_contraction(m, r_star, adj0):
    adj = jnp.asarray([adj0])
    target = jnp.asarray([r_star])
    prev_err = abs(adj0 - r_star)
    for _ in range(5):
        adj = S.momentum_update(adj, target, m, first_epoch=jnp.asarray(False))
        err = float(abs(adj[0] - r_star))
        assert err <= prev_err * max(m, 1e-9) + 1e-6 or err < 1e-6
        prev_err = err


@given(frac_cut=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3))
def test_work_units_match_survivor_counts(frac_cut):
    """Row-level work == Σ cost[perm[k]] · rows alive before position k."""
    r = np.random.default_rng(3)
    n = 300
    cols = np.stack([r.uniform(0, 1, n) for _ in range(3)]).astype(np.float32)
    preds = [Predicate(f"p{i}", i, P.OP_GT, float(frac_cut[i]),
                       static_cost=float(i + 1)) for i in range(3)]
    specs = P.pack(preds)
    perm = jnp.asarray([2, 0, 1], jnp.int32)
    res = run_chain(jnp.asarray(cols), specs, perm, collect_rate=1000,
                    sample_phase=0)
    outcomes = np.asarray(P.eval_all(specs, jnp.asarray(cols)))
    alive = np.ones(n, bool)
    expect = 0.0
    for k in [2, 0, 1]:
        expect += alive.sum() * (k + 1)
        alive &= outcomes[k]
    assert float(res.work_units) == expect
