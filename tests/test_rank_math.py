"""Unit tests for the paper's §2.1 math: selectivity, normalized cost, rank,
momentum, ordering."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stats as S


def mk_stats(num_cut, cost, n):
    return S.FilterStats(jnp.asarray(num_cut, jnp.float32),
                         jnp.asarray(cost, jnp.float32),
                         jnp.asarray(n, jnp.float32))


def test_selectivity_is_pass_fraction():
    st = mk_stats([10, 90, 0], [1, 1, 1], 100.0)
    np.testing.assert_allclose(S.selectivities(st), [0.9, 0.1, 1.0],
                               rtol=1e-6)


def test_normalized_cost_in_unit_range():
    st = mk_stats([0, 0], [300.0, 100.0], 100.0)
    nc = np.asarray(S.normalized_costs(st))
    assert nc.max() == pytest.approx(1.0)
    np.testing.assert_allclose(nc, [1.0, 1/3])


def test_rank_formula_matches_paper():
    # rank = nc / (1 - s); cheap+selective (cuts most) ranks first
    st = mk_stats([80, 20], [100.0, 100.0], 100.0)
    r = np.asarray(S.ranks(st))
    assert r[0] < r[1]
    np.testing.assert_allclose(r, [1.0 / 0.8, 1.0 / 0.2])


def test_rank_allpass_predicate_is_finite_and_last():
    st = mk_stats([0, 50], [100.0, 100.0], 100.0)
    r = np.asarray(S.ranks(st))
    assert np.isfinite(r).all()
    assert r[0] > r[1]          # cuts nothing → run last


def test_momentum_first_epoch_ignores_history():
    adj = S.momentum_update(jnp.asarray([5.0, 5.0]), jnp.asarray([1.0, 2.0]),
                            0.3, first_epoch=jnp.asarray(True))
    np.testing.assert_allclose(adj, [1.0, 2.0])


def test_momentum_recurrence():
    # adj(t) = (1-m) rank + m adj(t-1)
    adj = S.momentum_update(jnp.asarray([2.0]), jnp.asarray([1.0]), 0.3,
                            first_epoch=jnp.asarray(False))
    np.testing.assert_allclose(adj, [(1 - 0.3) * 1.0 + 0.3 * 2.0])


def test_order_from_ranks_stable_ties():
    perm = np.asarray(S.order_from_ranks(jnp.asarray([1.0, 0.5, 1.0, 0.1])))
    assert perm.tolist() == [3, 1, 0, 2]   # ties broken by user order


def test_merge_stats_associative():
    a = mk_stats([1, 2], [3, 4], 5.0)
    b = mk_stats([10, 20], [30, 40], 50.0)
    m = S.merge_stats(a, b)
    np.testing.assert_allclose(m.num_cut, [11, 22])
    np.testing.assert_allclose(m.n_monitored, 55.0)


def test_expected_chain_cost_formula():
    costs = jnp.asarray([1.0, 2.0])
    pas = jnp.asarray([0.5, 0.5])
    # order (0,1): 1 + 0.5*2 = 2 ; order (1,0): 2 + 0.5*1 = 2.5
    c01 = float(S.expected_chain_cost(costs, pas, jnp.asarray([0, 1])))
    c10 = float(S.expected_chain_cost(costs, pas, jnp.asarray([1, 0])))
    assert c01 == pytest.approx(2.0)
    assert c10 == pytest.approx(2.5)
