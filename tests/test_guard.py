"""Guarded FilterSession runtime: fault injection, validation, self-healing.

Fast tier: the f32 accumulator-saturation regression (fails on the
pre-decay ``accumulate``), the fused state validator (300 seeded healthy
states pass, every ``STATE_CORRUPTIONS`` defect class is detected), the
crc32 checkpoint envelope, and every recovery path of ``GuardedSession``
(quarantine, retry+backoff, degrade ladder, storm response, ring
rollback) — plus the 1-device chaos-soak smoke with survivor bit-parity.
The full 4-forced-device soak runs in a subprocess (slow tier; CI
``chaos`` job).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import FilterPlan, OrderingConfig, build_session, \
    paper_filters_4, paper_filters_cnf
from repro.core.stats import (SAT_THRESHOLD, FilterStats, accumulate,
                              normalized_costs, selectivities)
from repro.data.pipeline import fstate_to_arrays
from repro.data.stream import DriftConfig, LogStream
from repro.runtime import (STATE_CORRUPTIONS, DataFaultInjector,
                           FailureInjector, GuardedSession, GuardPolicy,
                           GuardStateError, corrupt_blob, corrupt_state)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _ordering(**kw):
    kw.setdefault("collect_rate", 32)
    kw.setdefault("calculate_rate", 8192)
    kw.setdefault("momentum", 0.3)
    return OrderingConfig(**kw)


def _plan(**kw):
    kw.setdefault("predicates", paper_filters_4("fig1"))
    kw.setdefault("ordering", _ordering())
    return FilterPlan(**kw)


def _batches(n, rows=2048, seed=0, drift=None):
    stream = LogStream(total_rows=n * rows, batch_rows=rows, seed=seed,
                       drift=drift or DriftConfig())
    return [rb.columns for rb in stream]


def _policy(**kw):
    kw.setdefault("sleep", lambda d: None)     # never sleep real time in CI
    return GuardPolicy(**kw)


def _storm_row(plan, cols):
    """A [C] feature vector every predicate passes: any survivor row."""
    sess = build_session(plan)
    _, res = sess.step(sess.init_state(), cols)
    idx = np.flatnonzero(res.mask_np)
    assert idx.size, "no survivor in the probe batch"
    return np.array(cols[:, idx[0]])


# ========================================================== f32 saturation
def test_saturation_regression_increment_absorbed():
    """REGRESSION (fails on the pre-decay ``accumulate``): at n_monitored
    = 2^24 the f32 ulp is 2.0, so +1-sized increments were silently
    absorbed and the accumulators — hence the adaptive ordering — froze.
    The decay keeps every accumulator in the exact-integer range."""
    wall = np.float32(2.0 ** 24)
    assert np.spacing(wall) == 2.0 and wall + np.float32(1.0) == wall

    stats = FilterStats(num_cut=jnp.full((4,), 2.0 ** 23, jnp.float32),
                        cost_acc=jnp.full((4,), 2.0 ** 23, jnp.float32),
                        n_monitored=jnp.float32(wall),
                        group_cut=jnp.full((4,), 2.0 ** 23, jnp.float32))
    new = accumulate(stats, jnp.ones((4,), jnp.float32),
                     jnp.ones((4,), jnp.float32), 1.0)
    # old code: 2^24 + 1 == 2^24 (stalled); fixed: decays to 2^23 + 1
    assert float(new.n_monitored) != float(stats.n_monitored)
    assert float(new.n_monitored) == 2.0 ** 23 + 1.0
    np.testing.assert_array_equal(np.asarray(new.num_cut),
                                  np.full((4,), 2.0 ** 22 + 1.0, np.float32))
    np.testing.assert_array_equal(np.asarray(new.group_cut),
                                  np.full((4,), 2.0 ** 22 + 1.0, np.float32))


def test_saturation_decay_preserves_ratios_bitexact():
    """×0.5 only decrements the f32 exponent: selectivities and normalized
    costs — the ratios the rank math consumes — are preserved bit-for-bit,
    so the decay can never flip an ordering decision."""
    rng = np.random.default_rng(7)
    stats = FilterStats(
        num_cut=jnp.asarray(rng.uniform(0, SAT_THRESHOLD, 4), jnp.float32),
        cost_acc=jnp.asarray(rng.uniform(1, 9, 4) * SAT_THRESHOLD,
                             jnp.float32),
        n_monitored=jnp.float32(SAT_THRESHOLD),
        group_cut=jnp.asarray(rng.uniform(0, SAT_THRESHOLD, 4), jnp.float32))
    zero = jnp.zeros((4,), jnp.float32)
    decayed = accumulate(stats, zero, zero, 0.0)     # pure halving
    assert float(decayed.n_monitored) == SAT_THRESHOLD / 2
    np.testing.assert_array_equal(np.asarray(selectivities(decayed)),
                                  np.asarray(selectivities(stats)))
    np.testing.assert_array_equal(np.asarray(normalized_costs(decayed)),
                                  np.asarray(normalized_costs(stats)))


def test_saturation_below_threshold_is_bitexact_noop():
    """×1.0 is a bit-exact no-op: every paper-scale epoch accumulates
    exactly as before the guard existed."""
    rng = np.random.default_rng(3)
    stats = FilterStats(
        num_cut=jnp.asarray(rng.uniform(0, 9e5, 4), jnp.float32),
        cost_acc=jnp.asarray(rng.uniform(0, 9e5, 4), jnp.float32),
        n_monitored=jnp.float32(987654.0),
        group_cut=jnp.asarray(rng.uniform(0, 9e5, 4), jnp.float32))
    cut = jnp.asarray([3.0, 1.0, 4.0, 1.0], jnp.float32)
    cost = jnp.asarray([2.0, 7.0, 1.0, 8.0], jnp.float32)
    new = accumulate(stats, cut, cost, 128.0)
    np.testing.assert_array_equal(np.asarray(new.num_cut),
                                  np.asarray(stats.num_cut + cut))
    np.testing.assert_array_equal(np.asarray(new.cost_acc),
                                  np.asarray(stats.cost_acc + cost))
    assert float(new.n_monitored) == 987654.0 + 128.0


# ============================================================ state validator
def test_validator_passes_300_seeded_healthy_states():
    """Property: every state an honest session can reach validates — 100
    consecutive states from each of 3 seeded drifting streams, crossing
    many epoch boundaries (calculate_rate = 4 batches)."""
    plan = _plan(ordering=_ordering(calculate_rate=4096))
    sess = build_session(plan)
    for seed in (0, 1, 2):
        state = sess.init_state()
        assert sess.validate_state(state)
        for cols in _batches(100, rows=1024, seed=seed,
                             drift=DriftConfig("sine", period_rows=20_000)):
            state, _ = sess.step(state, cols)
            assert sess.validate_state(state)


def test_validator_detects_every_corruption_class():
    """Each ``STATE_CORRUPTIONS`` defect violates a distinct invariant;
    the ONE fused boolean must catch all of them, on flat and CNF chains."""
    for preds in (paper_filters_4("fig1"), paper_filters_cnf("fig1")):
        sess = build_session(_plan(predicates=preds))
        state = sess.init_state()
        for cols in _batches(3, rows=1024):
            state, _ = sess.step(state, cols)
        assert sess.validate_state(state)
        for kind in STATE_CORRUPTIONS:
            bad = corrupt_state(state, kind)
            assert not sess.validate_state(bad), \
                f"validator missed corruption {kind!r}"


# ======================================================== checkpoint crc32
def test_envelope_crc_rejects_bitflips():
    sess = build_session(_plan())
    state = sess.init_state()
    for cols in _batches(2, rows=1024):
        state, _ = sess.step(state, cols)
    blob = sess.save_state(state)
    assert "crc32" in blob
    restored = sess.restore_state(blob)            # intact blob round-trips
    assert sess.validate_state(restored)
    for seed in range(5):                          # any flipped array trips
        with pytest.raises(ValueError, match="crc32 mismatch"):
            sess.restore_state(corrupt_blob(blob, seed=seed))


def test_envelope_checksumless_v2_loads_with_warning():
    sess = build_session(_plan())
    state = sess.init_state()
    blob = sess.save_state(state)
    legacy = {k: v for k, v in blob.items() if k != "crc32"}
    with pytest.warns(UserWarning, match="checksum-less"):
        restored = sess.restore_state(legacy)
    assert sess.validate_state(restored)


# ========================================================== guard: admission
def test_quarantine_poisoned_batch():
    guard = GuardedSession(build_session(_plan()), _policy())
    state = guard.init_state()
    cols = _batches(1, rows=1024)[0].copy()
    cols[1, 100] = np.nan
    cols[2, 7] = np.inf
    before = {k: np.array(v) for k, v in fstate_to_arrays(state).items()}
    new_state, res = guard.step(state, cols)
    assert res.quarantined and res.metrics_dict()["quarantined"]
    assert not res.mask_np.any() and res.n_pass == 0
    after = fstate_to_arrays(new_state)
    for k, v in before.items():                    # state did NOT advance
        np.testing.assert_array_equal(np.asarray(after[k]), v, err_msg=k)
    assert guard.health.quarantined == 1 and guard.health.steps == 0


# ============================================================== guard: retry
def test_retry_absorbs_transient_failures():
    delays = []
    calls = {"n": 0}

    def injector(i):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("transient node failure")

    guard = GuardedSession(
        build_session(_plan()),
        _policy(max_retries=3, backoff_base_s=0.05, jitter=0.0,
                sleep=delays.append),
        step_injector=injector)
    cols = _batches(1, rows=1024)[0]
    state, res = guard.step(guard.init_state(), cols)

    ref = build_session(_plan())
    _, ref_res = ref.step(ref.init_state(), cols)
    np.testing.assert_array_equal(res.mask_np, ref_res.mask_np)
    assert guard.health.retries == 2 and not res.quarantined
    assert delays == [0.05, 0.10]                  # exponential, jitter=0


def test_backoff_is_bounded_and_jittered():
    delays = []
    guard = GuardedSession(
        build_session(_plan()),
        _policy(backoff_base_s=0.5, backoff_max_s=1.0, jitter=0.25, seed=1,
                sleep=delays.append))
    for attempt in (1, 2, 3):
        guard._backoff(attempt, 0, RuntimeError("x"))
    assert delays[0] <= 0.5 * 1.25 and all(d <= 1.25 for d in delays)
    assert len(set(delays)) == 3               # seeded jitter: all distinct


# ===================================================== guard: degrade ladder
def test_degrade_ladder_pallas_to_jnp():
    """A persistently-crashing pallas engine degrades to jnp mid-stream;
    the live OrderState survives (fingerprint excludes the engine) and the
    survivors match a pure-jnp run bit-for-bit."""
    holder = {}

    def injector(i):
        if holder["g"].session.plan.engine == "pallas":
            raise RuntimeError("pallas kernel crashed")

    guard = GuardedSession(build_session(_plan(engine="pallas")),
                           _policy(max_retries=1), step_injector=injector)
    holder["g"] = guard
    cols = _batches(1, rows=1024)[0]
    state, res = guard.step(guard.init_state(), cols)

    assert guard.session.plan.engine == "jnp"
    assert guard.health.degrades[0]["changes"] == {"engine": "jnp"}
    assert guard.health.retries == 1
    ref = build_session(_plan())
    _, ref_res = ref.step(ref.init_state(), cols)
    np.testing.assert_array_equal(res.mask_np, ref_res.mask_np)


def test_degrade_ladder_bottom_reraises():
    """jnp + no skip tier + no compaction is the bottom rung: a failure
    that survives the whole ladder surfaces to the caller."""
    guard = GuardedSession(
        build_session(_plan()), _policy(max_retries=1),
        step_injector=lambda i: (_ for _ in ()).throw(
            RuntimeError("always boom")))
    with pytest.raises(RuntimeError, match="always boom"):
        guard.step(guard.init_state(), _batches(1, rows=1024)[0])
    assert guard.health.degrades == []


# ============================================================== guard: storm
def test_storm_overflow_degrades_losslessly():
    """An all-pass column storm overflows the bounded capacity; the guard
    drops to lossless compaction and re-runs the SAME batch from the
    PRE-step state — every survivor kept, statistics folded exactly once."""
    plan = _plan(compact=True, capacity=128)
    probe = _batches(1, rows=1024)[0]
    storm = np.tile(_storm_row(plan, probe)[:, None], (1, 1024))

    guard = GuardedSession(build_session(plan), _policy())
    state, res = guard.step(guard.init_state(), storm)
    assert guard.health.overflow_events == 1
    assert guard.session.plan.capacity is None     # lossless rung
    assert res.n_pass == 1024 and res.n_dropped == 0
    assert any(e["changes"] == {"capacity": "None"}
               for e in guard.health.degrades)
    # exactly-once stat fold: one batch's worth of monitored rows
    ref = build_session(plan)
    ref_state, _ = ref.step(ref.init_state(), probe)
    assert float(np.max(np.asarray(state.stats.n_monitored))) == \
        float(np.max(np.asarray(ref_state.stats.n_monitored)))


# ======================================================= guard: re-promotion
def test_fault_clears_then_recovers_repromotes():
    """The ladder climbs back UP: a transiently-crashing pallas engine
    degrades to jnp; once the fault clears, ``promote_after`` consecutive
    clean validated boundaries re-promote the engine — and the survivors
    stay bit-identical to a pure-jnp run across the whole episode (the
    rungs never change masks)."""
    holder: dict = {}
    flaky = {"on": True}

    def injector(i):
        if flaky["on"] and holder["g"].session.plan.engine == "pallas":
            raise RuntimeError("transient pallas fault")

    guard = GuardedSession(
        build_session(_plan(engine="pallas")),
        _policy(max_retries=1, validate_every=1, promote_after=2),
        step_injector=injector)
    holder["g"] = guard
    batches = _batches(5, rows=1024)
    ref = build_session(_plan())
    ref_state = ref.init_state()

    state = guard.init_state()
    state, res = guard.step(state, batches[0])      # crash → degrade
    assert guard.session.plan.engine == "jnp"
    flaky["on"] = False                             # the fault clears

    masks = [res.mask_np]
    for cols in batches[1:]:
        state, res = guard.step(state, cols)
        masks.append(res.mask_np)

    # two clean boundaries after the degrade → back on pallas, and the
    # re-promoted engine then RAN (batches 4-5) without re-degrading
    assert guard.session.plan.engine == "pallas"
    assert len(guard.health.promotes) == 1
    assert guard.health.promotes[0]["changes"] == {"engine": "pallas"}
    assert len(guard.health.degrades) == 1
    for cols, mask in zip(batches, masks):
        ref_state, ref_res = ref.step(ref_state, cols)
        np.testing.assert_array_equal(mask, ref_res.mask_np)


def test_persistent_fault_oscillates_instead_of_pinning():
    """A fault that does NOT clear: the rung re-promotes after the
    healthy window, crashes again, and degrades again — the session
    oscillates with period ``promote_after`` (and keeps serving) rather
    than pinning at the bottom or dying."""
    holder: dict = {}

    def injector(i):
        if holder["g"].session.plan.engine == "pallas":
            raise RuntimeError("persistent pallas fault")

    guard = GuardedSession(
        build_session(_plan(engine="pallas")),
        _policy(max_retries=1, validate_every=1, promote_after=2),
        step_injector=injector)
    holder["g"] = guard
    state = guard.init_state()
    for cols in _batches(7, rows=1024):
        state, _ = guard.step(state, cols)
    assert len(guard.health.promotes) >= 1
    assert len(guard.health.degrades) == len(guard.health.promotes) + 1
    assert guard.session.plan.engine == "jnp"       # currently degraded
    assert guard.health.steps == 7                  # every batch answered


def test_storm_clears_then_capacity_repromotes():
    """The lossless storm response reverts too: after the storm passes
    and the healthy window elapses, the bounded compaction capacity is
    restored (the memory-footprint rung climbs back)."""
    plan = _plan(compact=True, capacity=128)
    probe = _batches(1, rows=1024)[0]
    storm = np.tile(_storm_row(plan, probe)[:, None], (1, 1024))

    guard = GuardedSession(
        build_session(plan),
        _policy(validate_every=1, promote_after=2))
    state = guard.init_state()
    state, _ = guard.step(state, storm)
    assert guard.session.plan.capacity is None      # lossless rung
    for cols in _batches(3, rows=1024, seed=5):
        state, _ = guard.step(state, cols)
    assert guard.session.plan.capacity == 128
    assert guard.health.promotes[0]["changes"] == {"capacity": "128"}


def test_promotion_disabled_by_default():
    """``promote_after=0`` (the default) keeps the pre-PR-10 semantics:
    a degrade is permanent for the session's lifetime."""
    holder: dict = {}
    flaky = {"on": True}

    def injector(i):
        if flaky["on"] and holder["g"].session.plan.engine == "pallas":
            raise RuntimeError("boom")

    guard = GuardedSession(build_session(_plan(engine="pallas")),
                           _policy(max_retries=1, validate_every=1),
                           step_injector=injector)
    holder["g"] = guard
    state = guard.init_state()
    batches = _batches(6, rows=1024)
    state, _ = guard.step(state, batches[0])
    flaky["on"] = False
    for cols in batches[1:]:
        state, _ = guard.step(state, cols)
    assert guard.session.plan.engine == "jnp"
    assert guard.health.promotes == []


def test_health_snapshot_exports_rungs():
    """The admission server's export: counters + the CURRENT ladder
    rungs + degrade depth, JSON-serializable as-is."""
    import json

    guard = GuardedSession(build_session(_plan(compact=True, capacity=64)),
                           _policy())
    snap = guard.health_snapshot()
    assert snap["rungs"] == {"engine": "jnp", "skip_tier": "off",
                             "compact": True, "capacity": "64",
                             "degrade_depth": 0}
    assert snap["n_promotes"] == 0 and snap["promotes"] == []
    json.dumps(snap)


# =========================================================== guard: rollback
def test_rollback_restores_from_ring():
    """Corrupt the live state in flight (validate_every=1 catches it at
    the very next boundary): the pre-step state is corrupt too, so the
    guard rolls back to the ring snapshot and re-runs the batch from it —
    the result matches the fault-free mask bit-for-bit."""
    plan = _plan()
    batches = _batches(3, rows=1024)

    ref = build_session(plan)
    ref_state = ref.init_state()
    ref_masks = []
    for cols in batches:
        ref_state, r = ref.step(ref_state, cols)
        ref_masks.append(r.mask_np)

    def state_inj(i, st):
        return corrupt_state(st, "nan_stat") if i == 2 else st

    guard = GuardedSession(build_session(plan),
                           _policy(validate_every=1, checkpoint_every=100),
                           state_injector=state_inj)
    state = guard.init_state()
    for b, cols in enumerate(batches):
        state, res = guard.step(state, cols)
        np.testing.assert_array_equal(res.mask_np, ref_masks[b])
    assert guard.health.validator_failures == 1
    assert guard.health.rollbacks == 1
    assert guard.session.validate_state(state)


def test_ring_skips_corrupt_blobs_newest_first():
    guard = GuardedSession(build_session(_plan()),
                           _policy(checkpoint_every=1, ring_size=4))
    state = guard.init_state()
    for cols in _batches(3, rows=1024):
        state, _ = guard.step(state, cols)
    assert len(guard._ring) == 4
    newest = guard._ring[-1]
    guard._ring[-1] = newest._replace(blob=corrupt_blob(newest.blob))
    entry, restored = guard._restore_newest_valid()
    assert entry.step == guard._ring[-2].step      # fell back one entry
    assert guard.health.crc_rejects == 1
    assert guard.session.validate_state(restored)

    guard._ring.clear()
    for e in [newest._replace(blob=corrupt_blob(newest.blob, seed=s))
              for s in range(3)]:
        guard._ring.append(e)
    with pytest.raises(GuardStateError, match="cannot self-heal"):
        guard._restore_newest_valid()


# ====================================================== chaos soak (1 device)
POISON_AT = frozenset({3, 11})
STORM_AT = frozenset({7})


def _soak(plan, n_batches, rows, *, drift, fail_at, corrupt_at):
    """Faulted guarded run + fault-free baseline over the same stream."""
    base_sess = build_session(plan)
    bstate = base_sess.init_state()
    base_masks = {}
    for rb in LogStream(total_rows=n_batches * rows, batch_rows=rows,
                        drift=drift):
        b = rb.row_offset // rows
        bstate, r = base_sess.step(bstate, rb.columns)
        base_masks[b] = r.mask_np

    probe = _batches(1, rows=rows)[0]
    inj = DataFaultInjector(poison_at=POISON_AT, storm_at=STORM_AT,
                            storm_row=_storm_row(plan, probe))
    kill = FailureInjector(fail_at_steps=fail_at)

    def state_inj(i, st):
        return corrupt_state(st, "nan_stat") if i in corrupt_at else st

    guard = GuardedSession(build_session(plan),
                           _policy(validate_every=1, checkpoint_every=4),
                           step_injector=kill.maybe_fail,
                           state_injector=state_inj)
    stream = LogStream(total_rows=n_batches * rows, batch_rows=rows,
                       drift=drift)
    state, results = guard.run_log_stream(stream, batch_hook=inj)
    return guard, state, results, base_masks


def _check_soak(guard, state, results, base_masks, n_batches, rows):
    assert sorted(results) == list(range(n_batches))
    for b, res in results.items():
        if b in POISON_AT:
            assert res.quarantined and not res.mask_np.any()
        elif b in STORM_AT:
            assert res.n_pass == rows              # all-pass, kept lossless
        else:                                      # SURVIVOR BIT-PARITY
            np.testing.assert_array_equal(
                res.mask_np, base_masks[b],
                err_msg=f"survivor set diverged on clean batch {b}")
    h = guard.health
    assert h.quarantined >= len(POISON_AT)
    assert h.overflow_events >= 1 and h.retries >= 1
    assert h.validator_failures >= 1 and h.rollbacks >= 1
    assert any(e["changes"] == {"capacity": "None"} for e in h.degrades)
    assert guard.session.validate_state(state)
    d = h.to_dict()
    assert d["n_degrades"] == len(h.degrades) and "rollbacks" in d
    assert "quarantined=" in h.summary()


def test_chaos_soak_smoke_1dev():
    """The full fault menu on one device (fast tier): poison, storm, an
    injected step kill, and live state corruption — the run survives, every
    recovery is accounted, and clean batches are bit-identical to the
    fault-free baseline."""
    n_batches, rows = 16, 2048
    plan = _plan(compact=True, capacity=256,
                 ordering=_ordering(calculate_rate=8192))
    guard, state, results, base_masks = _soak(
        plan, n_batches, rows,
        drift=DriftConfig("sine", period_rows=16_000),
        fail_at={5}, corrupt_at={9})
    _check_soak(guard, state, results, base_masks, n_batches, rows)


@pytest.mark.slow
def test_chaos_soak_4dev_subprocess():
    """CI ``chaos`` job: the same soak on a 4-forced-device sharded plan
    (per-shard scope, stacked [S, P] OrderState through the validator,
    ring, and rollback paths), in a subprocess so the main pytest process
    keeps seeing one device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, %r)
        import jax
        assert jax.device_count() == 4
        from test_guard import (_check_soak, _ordering, _plan, _soak,
                                DriftConfig)
        n_batches, rows = 12, 4096
        plan = _plan(shards=4, scope="per_shard", compact=True,
                     capacity=256, ordering=_ordering(calculate_rate=16384))
        guard, state, results, base_masks = _soak(
            plan, n_batches, rows,
            drift=DriftConfig("sine", period_rows=32_000),
            fail_at={5}, corrupt_at={8})
        _check_soak(guard, state, results, base_masks, n_batches, rows)
        print("CHAOS-4DEV-OK", guard.health.summary())
    """) % os.path.dirname(os.path.abspath(__file__))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, \
        f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    assert "CHAOS-4DEV-OK" in out.stdout
