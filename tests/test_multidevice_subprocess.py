"""Multi-device behaviour, each case in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest process
keeps seeing exactly 1 device (contract §MULTI-POD 0)."""

import os
import subprocess
import sys
import textwrap

import pytest

# each case forks a fresh interpreter (jax re-import + multi-device init):
# minutes, not seconds — excluded from the fast tier via -m "not slow"
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


def test_per_shard_scope_has_no_collectives():
    """Paper §2.2: per-executor scope ⇒ no network traffic. The lowered HLO
    of the sharded filter step must contain NO collective ops; the
    centralized scope must contain an all-reduce."""
    out = run_py("""
        import jax, jax.numpy as jnp
        import numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import paper_filters_4, pack
        from repro.core.filter_exec import run_chain
        from repro.core.scope import Scope, reduce_stats
        from repro.core.stats import FilterStats

        mesh = jax.make_mesh((4,), ("data",))
        specs = pack(paper_filters_4("fig1"))

        def step(cols, scope):
            res = run_chain(cols, specs, jnp.arange(4, dtype=jnp.int32),
                            collect_rate=100, sample_phase=0)
            st = FilterStats(res.cut_counts, res.monitor_cost,
                             res.n_monitored)
            st = reduce_stats(st, scope, ("data",))
            if scope is Scope.CENTRALIZED:     # identical on every shard
                return st.num_cut, st.cost_acc, st.n_monitored
            # per-shard: stack local stats on a leading device axis
            return st.num_cut[None], st.cost_acc[None], st.n_monitored[None]

        cols = jnp.zeros((3, 4096), jnp.float32)
        for scope, want_collective in ((Scope.PER_SHARD, False),
                                       (Scope.CENTRALIZED, True)):
            outs = (P(), P(), P()) if scope is Scope.CENTRALIZED \\
                else (P("data"), P("data"), P("data"))
            f = jax.jit(shard_map(partial(step, scope=scope), mesh=mesh,
                        in_specs=P(None, "data"), out_specs=outs))
            txt = f.lower(cols).compile().as_text()
            has = any(k in txt for k in
                      ("all-reduce", "all-gather", "reduce-scatter"))
            assert has == want_collective, (scope, has)
        print("SCOPE-OK")
    """)
    assert "SCOPE-OK" in out


def test_sharded_filter_matches_single_device():
    """Filter outcome and monitor stats are identical whether the batch is
    processed on 1 device or sharded 4 ways (per-shard states merged)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import paper_filters_4, pack
        from repro.core.filter_exec import run_chain
        from repro.data.stream import gen_batch

        specs = pack(paper_filters_4("fig1"))
        cols = jnp.asarray(gen_batch(0, 0, 0, 64_000))
        perm = jnp.asarray([2, 0, 3, 1], jnp.int32)

        res1 = run_chain(cols, specs, perm, collect_rate=1000, sample_phase=0)

        mesh = jax.make_mesh((4,), ("data",))
        def shard_step(c):
            # per-shard phase: shard i starts at row i*16000
            phase = (jax.lax.axis_index("data") * 16000) % 1000
            r = run_chain(c, specs, perm, collect_rate=1000,
                          sample_phase=phase)
            return r.mask, r.cut_counts[None], r.n_monitored[None]
        f = jax.jit(shard_map(shard_step, mesh=mesh,
                    in_specs=P(None, "data"),
                    out_specs=(P("data"), P("data"), P("data"))))
        mask4, cut4, nmon4 = f(cols)
        # psum-free: per-shard partials concatenate; host merges stats
        assert np.array_equal(np.asarray(mask4), np.asarray(res1.mask))
        np.testing.assert_allclose(np.asarray(cut4).sum(0),
                                   np.asarray(res1.cut_counts))
        assert float(np.asarray(nmon4).sum()) == float(res1.n_monitored)
        print("SHARD-OK")
    """)
    assert "SHARD-OK" in out


def test_pipeline_parallel_matches_reference():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.pipeline import pipeline_apply

        mesh = make_test_mesh((4,), ("stage",))
        n_stages, m, mb, d = 4, 8, 4, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, d, d), jnp.float32) * 0.3
        xs = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d), jnp.float32)

        def block(wi, x):
            return jnp.tanh(x @ wi["w"])

        got = pipeline_apply(block, {"w": w}, xs, mesh=mesh)
        ref = xs
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("PP-OK")
    """)
    assert "PP-OK" in out


def test_compressed_psum_grad_allreduce():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.parallel.compression import (compressed_psum,
            init_error_feedback, int8_decompress)

        mesh = jax.make_mesh((4,), ("data",))
        g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 64.0}

        def red(gi, scheme):
            out, _ = compressed_psum(gi, "data", scheme=scheme,
                                     residual=jax.tree.map(jnp.zeros_like, gi))
            return out
        for scheme, tol in (("none", 1e-6), ("int8", 0.05), ("topk", None)):
            f = jax.jit(shard_map(partial(red, scheme=scheme), mesh=mesh,
                        in_specs=P(), out_specs=P()))
            got = f(g)["w"]
            want = g["w"] * 4
            if scheme == "topk":
                # top-1% kept: reduced result must be a masked subset
                nz = np.asarray(got != 0)
                assert nz.sum() >= 1 and nz.sum() <= 8
                np.testing.assert_allclose(np.asarray(got)[nz],
                                           np.asarray(want)[nz], rtol=1e-5)
            else:
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=tol, atol=tol)
        print("COMP-OK")
    """)
    assert "COMP-OK" in out


def test_mini_dryrun_train_and_decode():
    """A scaled-down dry-run: reduced config, 2x2 mesh, lower+compile train
    AND decode with the production sharding rules — the same code path
    launch/dryrun.py uses for the 16x16 and 2x16x16 meshes."""
    out = run_py("""
        import jax
        from repro.configs import get_smoke_config, SHAPES
        from repro.configs.base import ShapeCell
        from repro.launch.mesh import make_test_mesh
        from repro.launch.specs import make_cell
        from repro.launch.steps import (make_decode_step, make_train_step)

        mesh = make_test_mesh((2, 2), ("data", "model"))
        cfg = get_smoke_config("dbrx-132b")
        cell_train = ShapeCell("t", 64, 4, "train")
        cell_dec = ShapeCell("d", 64, 4, "decode")
        import repro.launch.specs as specs_mod
        specs_mod.SHAPES = dict(SHAPES, t=cell_train, d=cell_dec)

        kind, args, model, cfg2, opt_cfg = specs_mod.make_cell(
            "dbrx-132b", "t", mesh, cfg=cfg)
        with mesh:
            c = jax.jit(make_train_step(model, opt_cfg),
                        donate_argnums=(0, 1)).lower(*args).compile()
            assert c.memory_analysis() is not None

        kind, args, model, cfg2, opt_cfg = specs_mod.make_cell(
            "dbrx-132b", "d", mesh, cfg=cfg)
        with mesh:
            c = jax.jit(make_decode_step(model),
                        donate_argnums=(2,)).lower(*args).compile()
            assert c.cost_analysis() is not None
        print("DRYRUN-OK")
    """)
    assert "DRYRUN-OK" in out


def test_elastic_reshard_2_to_4_devices():
    """Checkpoint written under a 2-device mesh restores onto a 4-device
    mesh (elastic rescale)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from repro.launch.mesh import make_test_mesh

        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        m2 = make_test_mesh((2,), ("data",))
        sh2 = {"w": NamedSharding(m2, P("data", None))}
        t2 = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh2)
        d = tempfile.mkdtemp()
        save_checkpoint(d, 3, t2)

        m4 = make_test_mesh((4,), ("data",))
        sh4 = {"w": NamedSharding(m4, P("data", None))}
        got, _, step = load_checkpoint(d, tree, shardings=sh4)
        assert step == 3
        assert len(got["w"].sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out
