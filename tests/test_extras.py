"""Additional coverage: chunked CE oracle, serve driver, dry-run artifact
schema, compression math, snap-on-flip behavior."""

import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_cross_entropy_chunked_matches_plain():
    from repro.models.common import (cross_entropy, cross_entropy_chunked,
                                     unembed)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 16), jnp.float32)
    table = jax.random.normal(jax.random.PRNGKey(1), (40, 16), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, 40)
    plain = cross_entropy(unembed(x, table, True), labels, final_cap=30.0)
    chunked = cross_entropy_chunked(x, table, True, labels, final_cap=30.0,
                                    chunk=16)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)
    # grads agree too (the checkpointed path rematerializes logits)
    g1 = jax.grad(lambda h: cross_entropy(
        unembed(h, table, True), labels))(x)
    g2 = jax.grad(lambda h: cross_entropy_chunked(
        h, table, True, labels, chunk=16))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4,
                               atol=1e-6)


def test_snap_on_flip_reorders_faster_than_momentum():
    """With heavy momentum and an adversarial flip, snap must adopt the
    fresh order in ONE epoch while the paper controller lags."""
    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig)
    from repro.core.predicates import OP_GT, Predicate

    preds = [Predicate("a", 0, OP_GT, 0.5, static_cost=1.0),
             Predicate("b", 1, OP_GT, 0.5, static_cost=1.0)]

    def run(snap):
        cfg = AdaptiveFilterConfig(ordering=OrderingConfig(
            collect_rate=10, calculate_rate=4000, momentum=0.9,
            snap_threshold=snap))
        filt = AdaptiveFilter(preds, cfg)
        state = filt.init_state()
        step = jax.jit(filt.step)
        r = np.random.default_rng(0)
        # phase 1: predicate 1 cuts everything → order (1, 0)
        for _ in range(3):
            cols = np.stack([r.uniform(0.4, 1.0, 4096),
                             r.uniform(0.0, 0.45, 4096)]).astype(np.float32)
            state, _, _ = step(state, jnp.asarray(cols))
        assert np.asarray(state.perm).tolist() == [1, 0]
        # phase 2 (flip): predicate 0 cuts everything — ONE epoch of data
        for _ in range(1):
            cols = np.stack([r.uniform(0.0, 0.45, 4096),
                             r.uniform(0.4, 1.0, 4096)]).astype(np.float32)
            state, _, _ = step(state, jnp.asarray(cols))
        return np.asarray(state.perm).tolist()

    assert run(snap=0.0) == [1, 0], "momentum 0.9 should still lag"
    assert run(snap=1.3) == [0, 1], "snap should adopt the fresh order"


def test_dryrun_artifacts_schema():
    art = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("no dry-run artifacts in this checkout")
    files = list(art.glob("*.json"))
    assert len(files) >= 80, "expected both baseline and opt passes"
    for p in files:
        r = json.loads(p.read_text())
        assert r["status"] in ("ok", "skip", "error")
        assert r["status"] != "error", f"{p.name}: {r.get('error')}"
        if r["status"] == "ok":
            ro = r["roofline"]
            for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
                assert ro[k] >= 0
            assert ro["dominant"] in ("compute", "memory", "collective")
            assert r["memory"]["total_bytes"] > 0
            assert r["loop_aware"]["unknown_trip_loops"] == 0


def test_serve_driver_end_to_end():
    env = {"PYTHONPATH": str(pathlib.Path(__file__).resolve().parents[1] / "src")}
    import os
    env.update({k: v for k, v in os.environ.items() if k != "PYTHONPATH"})
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2.5-14b",
         "--smoke", "--requests", "8", "--batch", "4", "--prompt-len", "16",
         "--new-tokens", "2"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "admitted=" in out.stdout


def test_int8_compression_roundtrip_error_bounded():
    from repro.parallel.compression import int8_compress, int8_decompress
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 0.1, (64, 64)),
                          jnp.float32)}
    q, s = int8_compress(g)
    back = int8_decompress(q, s)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    assert err <= float(s["w"]) * 0.51 + 1e-9   # half-ULP of the int8 grid


def test_topk_error_feedback_conserves_mass():
    from repro.parallel.compression import init_error_feedback, topk_compress
    g = {"w": jnp.arange(100, dtype=jnp.float32).reshape(10, 10)}
    res = init_error_feedback(g)
    sent, res = topk_compress(g, res, fraction=0.05)
    total = sent["w"] + res["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]))
    assert int(jnp.sum(sent["w"] != 0)) == 5


@pytest.mark.parametrize("arch", ["gemma2-9b", "deepseek-v3-671b",
                                  "rwkv6-3b", "zamba2-2.7b", "qwen2.5-14b",
                                  "chatglm3-6b", "dbrx-132b"])
def test_prefill_decode_matches_full_forward(arch):
    """The strongest serving-correctness check: prefill T-1 tokens, decode
    token T-1 against the cache, and compare the next-token logits with the
    full-sequence forward pass. Validates the absorbed-MLA decode math,
    sliding-window decode masks, GQA cache updates, and SSM/hybrid state
    handoff numerically (bf16 path, tolerance from summation-order only)."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import _grow_cache
    from repro.models import transformer as tfm
    from repro.models.registry import batch_for, build_model

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t = 24
    batch = batch_for(cfg, 2, t, kind="prefill")
    batch.pop("labels", None)
    toks = batch["tokens"]

    x, _, _ = tfm.forward(params, cfg, batch, mode="train", remat=False)
    logits_full = np.asarray(
        tfm.logits_from_hidden(params, cfg, x[:, -1]).astype(jnp.float32))
    from repro.models.common import softcap
    logits_full = np.asarray(softcap(jnp.asarray(logits_full),
                                     cfg.final_softcap))

    pf = {k: (v[:, :t - 1] if k == "tokens" else
              (v[..., :t - 1] if k == "positions" else v))
          for k, v in batch.items()}
    _, cache = model.prefill(params, pf)
    cache = _grow_cache(model, cache, 2, t)
    logits_dec, _ = model.decode_step(params, toks[:, t - 1:t], cache,
                                      jnp.asarray(t - 1))
    logits_dec = np.asarray(logits_dec.astype(jnp.float32))

    # The dense MoE dispatch is dropless, so each token's MoE output is a
    # pure function of the token — MoE archs match at the same bf16
    # summation-order noise level as the dense/SSM paths.
    tol = 0.15
    assert np.max(np.abs(logits_full - logits_dec)) < tol, arch
    np.testing.assert_array_equal(np.argmax(logits_full, -1),
                                  np.argmax(logits_dec, -1))
