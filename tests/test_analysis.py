"""repro.analysis: chain linter, HLO auditor, hot-path lint, and the
linter↔resolver cross-check.

Every seeded-defect test proves a pass DETECTS its defect class (a lint
that cannot fail is decoration); the clean-repo tests pin that the live
tree stays clean, which is what the CI ``analysis`` job enforces via
``python -m repro.analysis --all``. The cross-check property tests are
the PR's structural guarantee: the skip-tier resolver and the chain
linter share one EQ quantizer (``skip_tier.eq_round``/``bloom_key``), so
their tile proofs can never contradict.
"""

import shutil
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (Diagnostic, audit_step_text, canonicalize_chain,
                            chain_lint, collectives_in, errors, has_f64,
                            host_callbacks_in, lint_chain, lint_hotpath,
                            lint_tile_proofs)
from repro.analysis import diagnostics as diag_lib
from repro.core import FilterPlan, OrderingConfig, paper_filters_4
from repro.core import predicates as pl
from repro.core import skip_tier as st
from repro.core.predicates import (OP_BETWEEN, OP_EQ, OP_GT, OP_HASHMIX,
                                   OP_LT, Predicate)


def _codes(diags):
    return sorted(d.code for d in diags)


# ============================================================ Diagnostic ABI
def test_diagnostic_abi():
    d = Diagnostic("chain-unsat-predicate", "error", "statement 0",
                   "it cannot pass", "fix the thresholds")
    assert "chain-unsat-predicate" in d.render()
    assert "fix the thresholds" in d.render()
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("x", "fatal", "loc", "msg")
    js = diag_lib.to_json([d])
    assert js[0]["code"] == "chain-unsat-predicate"
    assert js[0]["severity"] == "error"
    assert "clean" in diag_lib.render_report([])


# ===================================================== chain linter: seeded
def test_detects_unsat_predicate():
    # open BETWEEN with t2 <= t1 admits nothing
    preds = [Predicate("dead", 0, OP_BETWEEN, 5.0, 5.0)]
    assert _codes(lint_chain(preds)) == ["chain-unsat-predicate"]
    assert lint_chain(preds)[0].severity == "error"


def test_detects_unsat_group():
    # every OR-member unsatisfiable => the group admits nothing
    preds = [Predicate("a", 0, OP_BETWEEN, 5.0, 5.0, group="g"),
             Predicate("b", 1, OP_BETWEEN, 9.0, 2.0, group="g")]
    codes = _codes(lint_chain(preds))
    assert "chain-unsat-group" in codes


def test_detects_unsat_conjunction():
    # each side satisfiable; their AND over one column is empty
    preds = [Predicate("hi", 0, OP_GT, 5.0),
             Predicate("lo", 0, OP_LT, 3.0)]
    assert _codes(lint_chain(preds)) == ["chain-unsat-conjunction"]


def test_detects_subsumption_and_canonicalizes():
    preds = [Predicate("tight", 0, OP_GT, 5.0),
             Predicate("loose", 0, OP_GT, 3.0)]   # implied by 'tight'
    found = lint_chain(preds)
    assert _codes(found) == ["chain-subsumed"]
    assert found[0].severity == "warning"

    canon = canonicalize_chain(preds)
    assert canon.changed
    assert [p.name for p in canon.predicates] == ["tight"]
    assert [(p.name, code) for _, p, code in canon.removed] == \
        [("loose", "chain-subsumed")]
    # dropping a statement changes the plan fingerprint: the canonicalizer
    # must say so (checkpoints keyed on the old chain will refuse to load)
    assert "fingerprint" in canon.fingerprint_note
    f_old = FilterPlan(predicates=preds).fingerprint()
    f_new = FilterPlan(predicates=canon.predicates).fingerprint()
    assert f_old != f_new


def test_canonicalizer_never_autofixes_unsat():
    preds = [Predicate("hi", 0, OP_GT, 5.0), Predicate("lo", 0, OP_LT, 3.0)]
    canon = canonicalize_chain(preds)
    assert not canon.changed            # errors are surfaced, not deleted
    assert any(d.severity == "error" for d in canon.diagnostics)


def test_detects_always_true_under_domain():
    preds = [Predicate("tauto", 0, OP_GT, -1.0)]
    assert lint_chain(preds) == []                       # no domain: unknown
    found = lint_chain(preds, domains={0: (0.0, 100.0)})
    assert _codes(found) == ["chain-always-true"]


def test_detects_bloom_collision():
    # same column, distinct EQ keys 1 and 129 share Bloom bit 1 mod 128;
    # OR-grouped so the pair is satisfiable (AND of two EQs would be unsat)
    preds = [Predicate("k1", 0, OP_EQ, 1.0, group="g"),
             Predicate("k129", 0, OP_EQ, 129.0, group="g")]
    found = lint_chain(preds)
    assert "chain-bloom-collision" in _codes(found)
    # different columns never collide: each column owns its Bloom bitmap
    apart = [Predicate("k1", 0, OP_EQ, 1.0, group="g"),
             Predicate("k129", 1, OP_EQ, 129.0, group="g")]
    assert "chain-bloom-collision" not in _codes(lint_chain(apart))


def test_hashmix_shadowing_is_info():
    preds = [Predicate("rx", 0, OP_HASHMIX, 3.0, rounds=2, group="g"),
             Predicate("gt", 1, OP_GT, 0.0, group="g")]
    found = lint_chain(preds)
    assert _codes(found) == ["chain-hashmix-shadows"]
    assert found[0].severity == "info"


def test_paper_chains_lint_clean():
    """The shipped configs must stay clean (errors/warnings) — the same
    invariant ``python -m repro.analysis --chain`` enforces in CI."""
    from repro.configs import paper_filters

    domains = paper_filters.paper_domains()
    for shape in paper_filters.CNF_SHAPES:
        found = lint_chain(paper_filters.filter_chain(shape),
                           domains=domains)
        assert not [d for d in found if d.severity != "info"], (
            shape, [d.render() for d in found])


# ====================================== build_session runs the chain linter
def test_build_session_raises_on_unsat_chain():
    from repro.core import build_session

    plan = FilterPlan(predicates=[Predicate("hi", 0, OP_GT, 7.0),
                                  Predicate("lo", 0, OP_LT, 1.0)])
    with pytest.raises(ValueError, match="chain-unsat-conjunction"):
        build_session(plan)


def test_build_session_warns_once_on_redundancy():
    from repro.core import build_session
    from repro.core.session import _LINT_WARNED

    preds = [Predicate("tight", 2, OP_GT, 11.75),
             Predicate("loose", 2, OP_GT, 11.25)]
    _LINT_WARNED.clear()
    with pytest.warns(UserWarning, match="chain-subsumed"):
        build_session(FilterPlan(predicates=preds))
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second compile: silent
        build_session(FilterPlan(predicates=preds))


# =========================== cross-check: linter proofs vs skip-tier resolver
def _rand_chain(rng):
    """Random CNF chain: contiguous OR-groups, every op, mixed columns."""
    ops = [OP_GT, OP_LT, OP_BETWEEN, OP_EQ, OP_HASHMIX]
    n = int(rng.integers(1, 6))
    preds, i, g = [], 0, 0
    while i < n:
        seg = min(int(rng.integers(1, 4)), n - i)
        grp = None if seg == 1 and rng.random() < 0.6 else f"g{g}"
        g += 1
        for _ in range(seg):
            op = int(ops[rng.integers(0, len(ops))])
            t1 = float(rng.uniform(-20, 20))
            preds.append(Predicate(
                f"p{i}", column=int(rng.integers(0, 3)), op=op, t1=t1,
                t2=float(t1 + rng.uniform(-5, 10)), group=grp,
                rounds=2 if op == OP_HASHMIX else 0))
            i += 1
    return preds


def _row_truth(preds, cols):
    """Brute-force row-level chain verdict (group-OR folded over AND)."""
    import jax.numpy as jnp

    m = np.asarray(pl.eval_all(pl.pack(preds), jnp.asarray(cols)))
    gids = pl.normalize_groups(preds)
    ok = np.ones(cols.shape[1], bool)
    for g in sorted(set(gids)):
        members = [i for i, x in enumerate(gids) if x == g]
        ok &= np.any(m[members], axis=0)
    return ok


def _check_one(preds, cols):
    """Both provers sound vs brute force, and never contradicting each
    other — the PR's structural guarantee (shared eq_round/bloom_key)."""
    mins, maxs, bloom = st.tile_summaries(cols, bloom=True, xp=np)
    rp, rf = st.resolve_tiles(mins, maxs, bloom, pl.pack(preds), xp=np)
    lp, lf = lint_tile_proofs(preds, mins, maxs)
    truth = _row_truth(preds, cols).reshape(-1, st.SKIP_TILE)
    t_pass, t_fail = truth.all(axis=1), (~truth).all(axis=1)
    for name, (p, f) in {"resolver": (np.asarray(rp), np.asarray(rf)),
                         "linter": (lp, lf)}.items():
        assert not np.any(p & ~t_pass), (name, "pass-unsound", preds)
        assert not np.any(f & ~t_fail), (name, "fail-unsound", preds)
    assert not np.any(np.asarray(rp) & lf), ("contradiction", preds)
    assert not np.any(np.asarray(rf) & lp), ("contradiction", preds)


def test_linter_resolver_agree_seeded():
    """300 random chains × random tiles; half integer-ish data so the
    EQ/Bloom proof paths actually fire."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        preds = _rand_chain(rng)
        rows = st.SKIP_TILE * int(rng.integers(1, 5))
        cols = rng.uniform(-25, 25, (3, rows)).astype(np.float32)
        if rng.random() < 0.5:
            cols = np.round(cols).astype(np.float32)
        _check_one(preds, cols)


def test_linter_resolver_agree_hypothesis():
    """Same property under hypothesis shrinking (skipped where the package
    is not installed — the seeded variant above always runs)."""
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=hst.integers(0, 2**31 - 1),
               integerish=hst.booleans())
    @hyp.settings(max_examples=60, deadline=None)
    def prop(seed, integerish):
        rng = np.random.default_rng(seed)
        preds = _rand_chain(rng)
        cols = rng.uniform(-25, 25, (3, st.SKIP_TILE * 2)).astype(np.float32)
        if integerish:
            cols = np.round(cols).astype(np.float32)
        _check_one(preds, cols)

    prop()


# ======================================================== hot-path sync lint
def test_hotpath_repo_is_clean():
    assert lint_hotpath() == []


def _write_tree(root: Path, body: str):
    (root / "core").mkdir(parents=True)
    (root / "core" / "session.py").write_text(textwrap.dedent(body))


def test_hotpath_detects_injected_item(tmp_path):
    _write_tree(tmp_path, """
        class FilterSession:
            def step(self, state, batch):
                return self._helper(batch)

            def _helper(self, batch):
                return batch.sum().item()
    """)
    # empty allowlist: the synthetic tree resolves none of the real
    # entries, and stale entries are themselves errors now
    found = lint_hotpath(package_root=tmp_path, allowlist={})
    assert _codes(found) == ["hotpath-host-sync"]
    assert "FilterSession._helper" in found[0].message
    assert found[0].severity == "error"


def test_hotpath_detects_enable_x64(tmp_path):
    # FilterSession.step itself is allowlisted as the driver, so the flip
    # goes in a reachable helper — proving graph traversal, not just roots
    _write_tree(tmp_path, """
        import jax

        class FilterSession:
            def step(self, state, batch):
                return self._go(batch)

            def _go(self, batch):
                jax.config.update("jax_enable_x64", True)
                return batch
    """)
    found = lint_hotpath(package_root=tmp_path,
                         allowlist={"FilterSession.step": "the driver"})
    assert "hotpath-enable-x64" in _codes(found)
    assert "hotpath-stale-allowlist" not in _codes(found)


def test_hotpath_unreachable_code_not_flagged(tmp_path):
    _write_tree(tmp_path, """
        class FilterSession:
            def step(self, state, batch):
                return batch

        def offline_report(arrs):
            return [a.item() for a in arrs]     # never on the hot path
    """)
    assert lint_hotpath(package_root=tmp_path, allowlist={}) == []


def test_hotpath_injection_into_real_tree(tmp_path):
    """Copy the live package, inject one ``.item()`` into a function the
    jitted step reaches, and the lint must find exactly that site."""
    from repro.core import plan as _plan

    src_root = Path(_plan.__file__).parent.parent
    for sub in ("core", "kernels", "parallel"):
        shutil.copytree(src_root / sub, tmp_path / sub)
    target = tmp_path / "core" / "ordering.py"
    text = target.read_text()
    assert "def advance" in text
    # redefine a name the step graph calls (the rank-advance path) with a
    # sync inside: the over-approximate by-name graph must reach it
    target.write_text(text + textwrap.dedent("""

        def advance(*args, **kwargs):
            leak = args[0].sum().item()
            return leak
    """))
    found = lint_hotpath(package_root=tmp_path)
    assert any(d.code == "hotpath-host-sync"
               and "ordering.py" in d.location for d in found), found


# =============================================================== HLO auditor
def test_audit_plan_clean_single_device():
    from repro.analysis import audit_plan

    plan = FilterPlan(predicates=paper_filters_4("fig1"),
                      ordering=OrderingConfig(collect_rate=100,
                                              calculate_rate=4000))
    assert errors(audit_plan(plan)) == []


def test_audit_step_text_flags_collective_leak():
    plan = FilterPlan(predicates=paper_filters_4("fig1"), scope="per_shard",
                      shards=1)
    fake = "ENTRY main {\n  ar = f32[4] all-reduce(x), replica_groups={}\n}"
    found = audit_step_text(fake, plan, num_shards=4)
    assert _codes(found) == ["hlo-step-collective"]


def test_audit_step_text_flags_missing_collective():
    plan = FilterPlan(predicates=paper_filters_4("fig1"),
                      scope="centralized", shards=1)
    found = audit_step_text("ENTRY main { x = f32[4] add(a, b) }", plan,
                            num_shards=4)
    assert _codes(found) == ["hlo-missing-collective"]


def test_audit_detects_host_callback():
    """A real ``jax.pure_callback`` inside a jitted fn must show up in the
    compiled text via the same query the auditor uses."""
    import jax
    import jax.numpy as jnp

    def body(x):
        y = jax.pure_callback(lambda v: np.asarray(v) * 2, x, x)
        return y + 1

    text = jax.jit(body).lower(jnp.ones((4,), jnp.float32)) \
        .compile().as_text()
    assert host_callbacks_in(text), "callback invisible in compiled HLO"
    plan = FilterPlan(predicates=paper_filters_4("fig1"))
    found = audit_step_text(text, plan, num_shards=1)
    assert "hlo-host-callback" in _codes(found)


def test_audit_flags_f64_in_tokenize_plan():
    from repro.core import TokenizeSpec

    plan = FilterPlan(predicates=paper_filters_4("fig1"), compact=True,
                      tokenize=TokenizeSpec(32000))
    fake = "ENTRY main { c = f64[8] convert(x) }"
    found = audit_step_text(fake, plan, num_shards=1)
    assert "hlo-f64-in-tokenize" in _codes(found)
    assert has_f64(fake) and not has_f64("f32[8] add")
    assert collectives_in("all-reduce(x)") == ["all-reduce"]
    assert collectives_in("my_all-reducer(x)") == []


# ============================================ jit-cache recompile regression
def test_skip_tier_recompile_count_bounded():
    """Ragged ambiguous-tile widths across a stream must reuse quantized
    traces: distinct jit entries stay within the 16-tile quantization bound
    (this is the regression the auditor's hlo-unbounded-traces check pins —
    here asserted directly on the live session)."""
    from repro.core import build_session

    plan = FilterPlan(predicates=paper_filters_4("fig1"),
                      skip_tier="zonemap",
                      ordering=OrderingConfig(collect_rate=100,
                                              calculate_rate=50_000))
    session = build_session(plan)
    state = session.init_state()
    rows = 4096
    n_tiles = rows // st.SKIP_TILE
    bound = len({st.quantize_amb_cap(k, n_tiles)
                 for k in range(n_tiles + 1)})
    rng = np.random.default_rng(3)
    for i in range(8):
        cols = rng.uniform(-64, 64, (3, rows)).astype(np.float32)
        n_flat = (i * n_tiles) // 7
        cols[:, :n_flat * st.SKIP_TILE] = 1e9   # provably-fail tiles
        state, _ = session.step(state, cols)
    n_traces = session.filter._jit_step_skip._cache_size()
    assert 1 <= n_traces <= bound, (n_traces, bound)


# ============================================================ validate_combo
def test_validate_combo_aggregates_all_problems():
    from repro.core.plan import validate_combo

    with pytest.raises(ValueError) as ei:
        validate_combo(scope="per_shard", cost_mode="guess", backend="jnp",
                       compact_output=False, compact_capacity=None,
                       compact_slack=0.5, exchange="sometimes")
    msg = str(ei.value)
    assert "3 invalid plan field combinations" in msg
    assert "bad cost_mode" in msg and "compact_slack" in msg \
        and "bad exchange" in msg


def test_validate_combo_enumerates_choices():
    from repro.core.plan import validate_combo

    with pytest.raises(ValueError, match=r"'static', 'measured'"):
        validate_combo(scope="per_shard", cost_mode="guess", backend="jnp",
                       compact_output=False, compact_capacity=None,
                       compact_slack=1.5, exchange="eager")
    # single violation raises the bare message, no aggregation preamble
    with pytest.raises(ValueError) as ei:
        validate_combo(scope="per_shard", cost_mode="guess", backend="jnp",
                       compact_output=False, compact_capacity=None,
                       compact_slack=1.5, exchange="eager")
    assert "invalid plan field combinations" not in str(ei.value)


def test_validate_combo_skips_dependent_checks():
    from repro.core.plan import validate_combo

    # unknown backend: engine-capability checks must not pile on
    with pytest.raises(ValueError) as ei:
        validate_combo(scope="per_shard", cost_mode="static",
                       backend="tpu-v9", compact_output=True,
                       compact_capacity=None, compact_slack=1.5,
                       exchange="eager", shards=4)
    msg = str(ei.value)
    assert "bad backend" in msg
    assert "host engine" not in msg      # traceability unknown -> skipped


# ====================================================================== CLI
def test_cli_clean_on_repo(capsys):
    """``python -m repro.analysis --chain --hotpath`` exits 0 on the live
    tree (the --hlo pass has its own compile-heavy tests above)."""
    from repro.analysis.__main__ import main

    rc = main(["--chain", "--hotpath", "--examples",
               str(Path(__file__).resolve().parent.parent / "examples")])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 error(s)" in out


def test_cli_json_output(capsys):
    import json as json_lib

    from repro.analysis.__main__ import main

    rc = main(["--hotpath", "--json"])
    assert rc == 0
    payload = json_lib.loads(capsys.readouterr().out)
    assert payload == []                    # clean tree -> empty findings
