"""Single-pass ingestion: fused in-kernel compaction, O(R) gather parity,
deferred epoch exchange, auto capacity, device tokenize.

Fast cases run on the default 1-device CPU (shard_map live where needed);
the collective-cadence HLO pins fork 4-forced-device subprocesses like
tests/test_sharded_filter.py (slow tier).
"""

import logging
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


# ====================================================== compaction parity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("capacity", [None, 512, 8])
def test_three_way_compaction_parity(backend, capacity):
    """Fused in-kernel (pallas) / O(R) cumsum (jnp) / legacy argsort /
    host boolean mask all agree — including capacity saturation."""
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4)
    from repro.core.filter_exec import compact_fixed, compact_fixed_argsort
    from repro.kernels.filter_chain.ref import compact_fixed_ref
    from repro.data.stream import gen_batch

    rows = 4096
    cap = capacity or rows
    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
        backend=backend, compact_output=True, compact_capacity=capacity,
        ordering=OrderingConfig(collect_rate=100, calculate_rate=50_000)))
    state = filt.init_state()
    cols = jnp.asarray(gen_batch(0, 0, 0, rows))

    _, packed, n_kept, mask, metrics = filt._jit_compact(state, cols)
    mask_np = np.asarray(mask)

    ref, n_ref = compact_fixed_ref(cols, mask_np, cap)          # host oracle
    jf, jn = compact_fixed(cols, jnp.asarray(mask_np), cap)     # O(R) cumsum
    af, an = compact_fixed_argsort(cols, jnp.asarray(mask_np), cap)  # legacy

    assert int(n_kept) == n_ref == int(jn) == int(an)
    np.testing.assert_array_equal(np.asarray(packed), ref)
    np.testing.assert_array_equal(np.asarray(jf), ref)
    np.testing.assert_array_equal(np.asarray(af), ref)
    # saturation accounting: dropped = popcount - kept, surfaced in metrics
    assert int(metrics.n_dropped) == int(mask_np.sum()) - int(n_kept)
    if capacity == 8:
        assert int(metrics.n_dropped) > 0


def test_compact_fixed_edge_masks():
    """Cumsum scatter == argsort gather on degenerate masks."""
    import jax.numpy as jnp

    from repro.core.filter_exec import compact_fixed, compact_fixed_argsort

    cols = jnp.asarray(np.arange(3 * 64, dtype=np.float32).reshape(3, 64))
    for mask in (np.zeros(64, bool), np.ones(64, bool),
                 np.arange(64) % 7 == 0):
        for cap in (1, 16, 64, 128):
            a, na = compact_fixed(cols, jnp.asarray(mask), cap, fill=-1.0)
            b, nb = compact_fixed_argsort(cols, jnp.asarray(mask), cap,
                                          fill=-1.0)
            assert int(na) == int(nb)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ================================================== deferred epoch exchange
def _perm_trace(exchange, steps=8):
    import jax
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilterConfig, OrderingConfig,
                            ShardedAdaptiveFilter, paper_filters_4)
    from repro.data.stream import DriftConfig, gen_batch

    mesh = jax.make_mesh((1,), ("data",))
    cfg = AdaptiveFilterConfig(
        scope="centralized", exchange=exchange,
        ordering=OrderingConfig(collect_rate=50, calculate_rate=6000))
    sf = ShardedAdaptiveFilter(paper_filters_4("fig1"), cfg, mesh=mesh)
    st = sf.init_state()
    drift = DriftConfig(kind="regime", period_rows=8192)
    out = []
    for b in range(steps):
        cols = jnp.asarray(gen_batch(0, b, b * 2048, 2048, drift))
        st, _, _ = sf.jit_step(st, cols)
        st = sf.maybe_exchange(st)
        out.append((int(np.asarray(st.epoch)[0]),
                    tuple(np.asarray(st.perm)[0].tolist())))
    return out


def test_deferred_matches_eager_exactly():
    """Sums are associative: deferring the merge to the boundary must adopt
    the IDENTICAL perm at the identical epoch, drift and all."""
    assert _perm_trace("eager") == _perm_trace("deferred")


def test_deferred_async_lags_at_most_one_epoch():
    """deferred-async folds merged stats one boundary late: each epoch's
    perm equals the eager perm of the same or the previous epoch."""
    eager = _perm_trace("eager", steps=10)
    async_ = _perm_trace("deferred-async", steps=10)
    by_epoch = {}
    for ep, perm in eager:
        by_epoch[ep] = perm
    for ep, perm in async_:
        allowed = {by_epoch.get(ep), by_epoch.get(ep - 1)}
        assert perm in allowed, (ep, perm, allowed)
    # and it does converge: same final epoch count
    assert async_[-1][0] == eager[-1][0] > 0


def test_exchange_config_validation():
    from repro.core import AdaptiveFilterConfig

    with pytest.raises(ValueError, match="exchange"):
        AdaptiveFilterConfig(exchange="sometimes", scope="centralized")
    with pytest.raises(ValueError, match="CENTRALIZED"):
        AdaptiveFilterConfig(exchange="deferred", scope="per_shard")
    with pytest.raises(ValueError, match="compact_capacity"):
        AdaptiveFilterConfig(compact_output=True, compact_capacity="huge")
    with pytest.raises(ValueError, match="compact_slack"):
        AdaptiveFilterConfig(compact_output=True, compact_capacity="auto",
                             compact_slack=0.5)


@pytest.mark.slow
def test_deferred_per_step_hlo_has_no_collectives():
    """The point of deferral: the per-STEP compiled module is collective-
    free (indistinguishable from PER_SHARD on the wire); the one all-reduce
    lives in the boundary exchange module. Pinned through the shared HLO
    auditor (``repro.analysis.hlo_audit``) so this test and the CI
    ``analysis`` job enforce the identical contract."""
    out = run_py("""
        from repro.analysis import audit_plan, collectives_in, errors
        from repro.core import FilterPlan, OrderingConfig, paper_filters_4

        ordering = OrderingConfig(collect_rate=10, calculate_rate=2000)
        for exchange in ("eager", "deferred", "deferred-async"):
            plan = FilterPlan(predicates=paper_filters_4("fig1"),
                              scope="centralized", shards=4,
                              exchange=exchange, ordering=ordering)
            diags = audit_plan(plan)
            assert not errors(diags), [d.render() for d in diags]
        # and the auditor is not vacuous: an eager CENTRALIZED step audited
        # as if it were deferred must flag the in-step collective
        from repro.analysis import audit_step_text
        from repro.core import build_session
        plan = FilterPlan(predicates=paper_filters_4("fig1"),
                          scope="centralized", shards=4, ordering=ordering)
        session = build_session(plan)
        import jax.numpy as jnp
        from repro.data.stream import gen_batch
        cols = jnp.asarray(gen_batch(0, 0, 0, 4096 * 4))
        txt = session.compiled_step_text(session.init_state(), cols)
        assert collectives_in(txt)
        deferred = FilterPlan(predicates=paper_filters_4("fig1"),
                              scope="centralized", shards=4,
                              exchange="deferred", ordering=ordering)
        found = audit_step_text(txt, deferred, num_shards=4)
        assert [d.code for d in found] == ["hlo-step-collective"], found
        print("DEFERRED-HLO-OK")
    """)
    assert "DEFERRED-HLO-OK" in out


@pytest.mark.slow
def test_deferred_converges_across_shards():
    """4 heterogeneous shards: deferred CENTRALIZED adopts the same single
    global perm eager does, with one collective per epoch instead of one
    per step."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (AdaptiveFilterConfig, OrderingConfig,
                                ShardedAdaptiveFilter)
        from repro.core.predicates import OP_GT, Predicate

        preds = [Predicate(f"c{i}", i, OP_GT, 0.5, static_cost=1.0)
                 for i in range(3)]
        R = 4096
        ordering = OrderingConfig(collect_rate=10, calculate_rate=2000)
        cols_np = np.full((3, R * 4), 1.0, np.float32)
        for s in range(4):
            cols_np[s % 3, s * R:(s + 1) * R] = 0.0
        cols = jnp.asarray(cols_np)

        def run(exchange):
            sf = ShardedAdaptiveFilter(preds, AdaptiveFilterConfig(
                scope="centralized", exchange=exchange, ordering=ordering))
            st = sf.init_state()
            for _ in range(3):
                st, mask, met = sf.jit_step(st, cols)
                st = sf.maybe_exchange(st)
            return (np.asarray(st.perm), np.asarray(st.epoch),
                    np.asarray(mask))

        perm_e, ep_e, mask_e = run("eager")
        perm_d, ep_d, mask_d = run("deferred")
        assert (ep_e > 0).all() and (ep_d > 0).all()
        assert len({tuple(p) for p in perm_d}) == 1, perm_d
        assert np.array_equal(perm_e, perm_d), (perm_e, perm_d)
        assert np.array_equal(ep_e, ep_d)
        assert np.array_equal(mask_e, mask_d)
        print("DEFERRED-CONV-OK")
    """)
    assert "DEFERRED-CONV-OK" in out


# ======================================================== capacity auto-tune
def test_auto_capacity_tracks_pass_rate():
    """compact_capacity='auto' re-quantizes to a 128-multiple near
    pass_rate × batch × slack at the first epoch boundary."""
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4)
    from repro.data.stream import gen_batch

    rows = 4096
    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
        compact_output=True, compact_capacity="auto", compact_slack=1.5,
        ordering=OrderingConfig(collect_rate=20, calculate_rate=8192)))
    assert filt.resolve_capacity(rows) == rows          # lossless cold start
    # auto mode must not let a capacity=None trace pin a stale width —
    # callers have to thread resolve_capacity() per call
    with pytest.raises(ValueError, match="resolve_capacity"):
        filt._step_compact(filt.init_state(),
                           jnp.zeros((4, 256), jnp.float32))

    batches = [np.asarray(gen_batch(0, b, b * rows, rows)) for b in range(6)]
    metrics = [m for _, _, m in filt.process_stream(batches)]
    assert metrics[-1]["epoch"] >= 1
    cap = filt.resolve_capacity(rows)
    assert cap < rows and cap % 128 == 0
    pass_rate = np.mean([m["n_pass"] / rows for m in metrics])
    want = pass_rate * rows * 1.5
    assert abs(cap - want) <= 256 + want * 0.5, (cap, want)
    # tuned capacity never saturated on this stream (slack did its job)
    assert all(m["n_dropped"] == 0 for m in metrics)


def test_overflow_surfaced_and_warned(caplog):
    """Tiny fixed capacity: n_dropped lands in the metrics dict and
    process_stream logs a one-line warning."""
    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4)
    from repro.data.stream import gen_batch

    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
        compact_output=True, compact_capacity=8,
        ordering=OrderingConfig(collect_rate=100, calculate_rate=50_000)))
    batch = np.asarray(gen_batch(0, 0, 0, 2048))
    with caplog.at_level(logging.WARNING):
        survivors, mask, m = next(iter(filt.process_stream([batch])))
    assert m["n_dropped"] == int(mask.sum()) - 8 > 0
    assert survivors.shape[1] == 8
    assert any("compaction overflow" in r.message for r in caplog.records)


# ========================================================== device tokenize
def test_device_tokenize_matches_host_pipeline():
    """Pipeline + ShardedPipeline with device_tokenize=True emit LM batches
    bit-identical to the host tokenizer path."""
    import jax

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, ShardedAdaptiveFilter,
                            paper_filters_4)
    from repro.core.plan import TokenizeSpec
    from repro.core.session import FilterSession
    from repro.data.pipeline import Pipeline, make_pipeline
    from repro.data.stream import DriftConfig, LogStream

    ordering = OrderingConfig(collect_rate=100, calculate_rate=100_000)

    def mk_plain(compact, devtok):
        cfg = AdaptiveFilterConfig(ordering=ordering, compact_output=compact)
        stream = LogStream(total_rows=131072, batch_rows=16384)
        return Pipeline(stream, AdaptiveFilter(paper_filters_4("fig1"), cfg),
                        batch_size=4, seq_len=64, vocab_size=1000,
                        device_tokenize=devtok)

    host = [b for _, b in zip(range(3), iter(mk_plain(False, False)))]
    dev = [b for _, b in zip(range(3), iter(mk_plain(True, True)))]
    assert len(host) == len(dev) == 3
    for a, b in zip(host, dev):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])

    def mk_sharded(devtok):
        cfg = AdaptiveFilterConfig(ordering=ordering, compact_output=True)
        mesh = jax.make_mesh((1,), ("data",))
        filt = ShardedAdaptiveFilter(paper_filters_4("fig1"), cfg, mesh=mesh)
        session = FilterSession.from_filter(
            filt, tokenize=TokenizeSpec(1000, 8) if devtok else None)
        return make_pipeline(
            session, total_rows=131072, batch_rows=16384, batch_size=4,
            seq_len=64, vocab_size=1000, drift=DriftConfig())

    sh = [b for _, b in zip(range(3), iter(mk_sharded(False)))]
    sd = [b for _, b in zip(range(3), iter(mk_sharded(True)))]
    for a, b in zip(sh, sd):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_device_tokenize_needs_compact():
    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            paper_filters_4)
    from repro.data.pipeline import Pipeline
    from repro.data.stream import LogStream

    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig())
    with pytest.raises(ValueError, match="device_tokenize"):
        Pipeline(LogStream(total_rows=1024, batch_rows=1024), filt,
                 batch_size=2, seq_len=16, vocab_size=100,
                 device_tokenize=True)
