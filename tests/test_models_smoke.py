"""Per-arch smoke tests (contract §ARCHITECTURES): reduced same-family
configs, one forward/train step on CPU, output shapes + no NaNs; plus a
decode step against a small cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.registry import batch_for, build_model


@pytest.fixture(scope="module")
def jitted():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(jitted, arch):
    cfg, model, params = jitted(arch)
    batch = batch_for(cfg, 2, 32, kind="train")
    loss, metrics = jax.jit(
        lambda p, b: model.train_loss(p, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_flow_everywhere(jitted, arch):
    cfg, model, params = jitted(arch)
    batch = batch_for(cfg, 2, 16, kind="train")
    grads = jax.jit(jax.grad(
        lambda p, b: model.train_loss(p, b)[0]))(params, batch)
    flat = jax.tree_util.tree_leaves_with_path(grads)
    nonzero = sum(bool(jnp.any(g != 0)) for _, g in flat)
    finite = all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
                 for _, g in flat)
    assert finite, f"{arch}: non-finite grads"
    assert nonzero >= 0.5 * len(flat), \
        f"{arch}: only {nonzero}/{len(flat)} grad tensors non-zero"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_finite_and_cache_updates(jitted, arch):
    cfg, model, params = jitted(arch)
    cache = model.init_cache(2, 64)
    if cfg.embeds_input:
        tok = jnp.ones((2, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok = jnp.ones((2, 1), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(
        params, tok, cache, jnp.asarray(3))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a.astype(jnp.float32)
                                  != b.astype(jnp.float32))),
        cache, new_cache)
    assert any(jax.tree.leaves(changed)), f"{arch}: cache did not update"


@pytest.mark.parametrize("arch", ["gemma2-9b", "rwkv6-3b", "zamba2-2.7b",
                                  "deepseek-v3-671b"])
def test_prefill_then_decode_consistent(jitted, arch):
    """Prefill + decode of token t must match the full forward logits."""
    cfg, model, params = jitted(arch)
    batch = batch_for(cfg, 2, 16, kind="prefill")
    batch.pop("labels", None)
    logits_prefill, _ = jax.jit(model.prefill)(params, batch)
    assert logits_prefill.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_prefill.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    """The FULL configs' analytic param counts must land near the advertised
    sizes (they drive MODEL_FLOPS in the roofline)."""
    targets = {
        "deepseek-v3-671b": (600e9, 760e9),
        "dbrx-132b": (115e9, 150e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "rwkv6-3b": (2.5e9, 3.6e9),
        "gemma2-9b": (8e9, 11e9),
        "qwen2.5-14b": (12e9, 16e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "glm4-9b": (8e9, 10.5e9),
        "qwen2-vl-2b": (1.2e9, 2.4e9),
        "whisper-base": (0.05e9, 0.13e9),
    }
    lo, hi = targets[arch]
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]B"
