"""Data substrate tests: stream determinism/sharding, selectivity targets,
pipeline restart, tokenizer determinism, optimizer + hlo analyzer units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig,
                        pack, paper_filters_4)
from repro.core.predicates import eval_all
from repro.data import tokenizer
from repro.data.pipeline import Pipeline
from repro.data.stream import (DriftConfig, LogStream, gen_batch, norm_ppf,
                               threshold_for_quantile)


def test_norm_ppf_accuracy():
    # spot-check against known quantiles
    assert norm_ppf(0.5) == pytest.approx(0.0, abs=1e-9)
    assert norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert norm_ppf(0.0013498980316300933) == pytest.approx(-3.0, abs=1e-6)


@pytest.mark.parametrize("target,want", [("fig1", 0.0451), ("sens", 0.1614)])
def test_paper_selectivity_targets(target, want):
    preds = paper_filters_4(target)
    cols = gen_batch(0, 0, 0, 400_000)
    res = np.asarray(eval_all(pack(preds), jnp.asarray(cols)))
    got = res.all(axis=0).mean()
    assert got == pytest.approx(want, abs=0.004)


def test_stream_counter_based_determinism():
    a = gen_batch(7, 3, 3 * 1000, 1000)
    b = gen_batch(7, 3, 3 * 1000, 1000)
    np.testing.assert_array_equal(a, b)
    c = gen_batch(7, 4, 4 * 1000, 1000)
    assert not np.array_equal(a, c)


def test_stream_sharding_partitions_batches():
    total = LogStream(total_rows=16 * 65536)
    shards = [LogStream(total_rows=16 * 65536, shard_id=i, num_shards=4)
              for i in range(4)]
    all_offsets = sorted(rb.row_offset for s in shards for rb in s)
    want = sorted(rb.row_offset for rb in total)
    assert all_offsets == want


def test_drift_changes_selectivities():
    preds = paper_filters_4("fig1")
    specs = pack(preds)
    drift = DriftConfig(kind="regime", period_rows=500_000, amplitude=1.8)
    s_a = np.asarray(eval_all(specs, jnp.asarray(
        gen_batch(0, 0, 0, 100_000, drift)))).mean(axis=1)
    s_b = np.asarray(eval_all(specs, jnp.asarray(
        gen_batch(0, 9, 520_000, 100_000, drift)))).mean(axis=1)  # regime 1
    assert np.max(np.abs(s_a - s_b)) > 0.15   # regimes genuinely differ


def test_tokenizer_deterministic_and_in_range():
    cols = gen_batch(1, 0, 0, 1000)
    t1 = tokenizer.rows_to_tokens(cols, 5000, 4)
    t2 = tokenizer.rows_to_tokens(cols, 5000, 4)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (4000,)
    assert t1.min() >= 0 and t1.max() < 5000


def test_pipeline_restart_bit_identical():
    def mk():
        filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
            ordering=OrderingConfig(calculate_rate=200_000)))
        stream = LogStream(total_rows=2_000_000,
                           drift=DriftConfig("sine", period_rows=400_000))
        return Pipeline(stream, filt, batch_size=2, seq_len=64,
                        vocab_size=1000)

    p1 = mk()
    it1 = iter(p1)
    for _ in range(3):
        next(it1)
    st = p1.state()
    a = next(it1)

    p2 = mk()
    p2.restore(st)
    b = next(iter(p2))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_adamw_decreases_simple_loss():
    from repro.optim import AdamWConfig, adamw_update, init_opt_state

    w = {"w": jnp.asarray([2.0, -3.0])}
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=10.0)
    st = init_opt_state(w, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, st, _ = adamw_update(w, g, st, cfg, 0.1)
    assert float(loss(w)) < 0.05 * l0
    assert int(st.step) == 50


def test_adamw_bf16_state_dtype():
    from repro.optim import AdamWConfig, adamw_update, init_opt_state

    w = {"w": jnp.ones((4,), jnp.bfloat16)}
    cfg = AdamWConfig(state_dtype="bfloat16")
    st = init_opt_state(w, cfg)
    assert st.m["w"].dtype == jnp.bfloat16
    w2, st2, _ = adamw_update(w, {"w": jnp.ones((4,), jnp.float32)}, st,
                              cfg, 1e-2)
    assert w2["w"].dtype == jnp.bfloat16
    assert st2.v["w"].dtype == jnp.bfloat16


def test_hlo_analyzer_multiplies_loops():
    """The analyzer must recover the unrolled FLOPs from a scanned loop —
    the property cost_analysis() lacks (EXPERIMENTS §methodology)."""
    from repro.launch import hlo_analysis

    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    comp = jax.jit(f_scan).lower(x, w).compile()
    res = hlo_analysis.analyze(comp.as_text())
    want = 2 * 128 ** 3 * 8              # 8 iterations of a 128³ matmul
    assert res["flops_per_chip"] == pytest.approx(want, rel=0.01)
    assert res["unknown_trip_loops"] == 0
    # and bytes must cover at least one read+write of the weight stack
    assert res["hbm_bytes_per_chip"] >= 8 * 128 * 128 * 4


def test_agreedy_handles_correlated_predicates():
    """With two perfectly correlated cut-heavy predicates, rank order runs
    them back-to-back (wasted); conditional greedy interleaves the
    independent one. Verify A-greedy's order differs and its true expected
    cost is no worse."""
    from repro.core import agreedy
    from repro.core.predicates import OP_GT, Predicate

    r = np.random.default_rng(0)
    n = 40_000
    x = r.uniform(0, 1, n).astype(np.float32)
    y = r.uniform(0, 1, n).astype(np.float32)
    cols = jnp.asarray(np.stack([x, x, y]))   # col1 duplicates col0
    preds = [Predicate("a", 0, OP_GT, 0.7, static_cost=1.0),
             Predicate("a2", 1, OP_GT, 0.69, static_cost=1.0),
             Predicate("b", 2, OP_GT, 0.65, static_cost=1.0)]
    specs = pack(preds)
    outcomes = eval_all(specs, cols)
    stats = agreedy.accumulate_pairs(
        agreedy.init_pair_stats(3), outcomes, jnp.ones((n,), bool))
    order = np.asarray(agreedy.conditional_greedy_order(
        stats, specs.static_cost))
    # after picking one of the correlated pair, the OTHER must NOT be next:
    # P(pass a2 | pass a) ≈ 0.97 → nearly useless as second filter
    assert order[1] == 2, f"conditional order {order} kept correlated pair"
