"""Admission server: queued ingest → adaptive guardrail gate → packed
prefill/decode slots (``src/repro/serving/``).

Pins the subsystem's contracts:

  * ADMISSION DETERMINISM — the queued, threaded server produces an
    admit/reject sequence and final OrderState bit-identical to a
    synchronous reference loop over the same seeded traffic; queuing
    changes latency, never decisions.
  * ACCOUNTING — bounded queues block, never drop: every ingested
    request gets exactly one RequestResult with a reason code.
  * DRAIN — a stop request (incl. a real SIGTERM through
    GracefulShutdown) stops ingest, finishes gating what's queued, lets
    in-flight slots decode to completion, and flushes a restorable
    final checkpoint + health line.
  * TRAFFIC — the drifting 3-phase mix is counter-pure, restartable,
    and actually drifts (selectivities shift per phase).
"""

import json
import os
import signal
import threading
import types

import numpy as np
import pytest

from repro.core import FilterPlan, OrderingConfig, build_session
from repro.data.stream import RequestStream
from repro.runtime import (DataFaultInjector, GracefulShutdown,
                           GuardedSession, GuardPolicy)
from repro.serving import (REASON_ADMITTED, REASON_QUARANTINED,
                           REASON_REJECTED, AdmissionServer, ServerConfig,
                           SimExecutor, TrafficConfig, TrafficGenerator,
                           guardrail_chain, phase_of, synchronous_reference)
from repro.serving.traffic import (COL_ABUSE, COL_ALLOW, COL_PROMPT_LEN,
                                   gen_requests)


def _plan():
    return FilterPlan(
        predicates=guardrail_chain(),
        ordering=OrderingConfig(collect_rate=4, calculate_rate=256,
                                momentum=0.3))


def _traffic(seed=3, phase_requests=256):
    return TrafficConfig(seed=seed, phase_requests=phase_requests)


def _stream(tcfg, requests, batch):
    return RequestStream(TrafficGenerator(tcfg).gen, total_rows=requests,
                         batch_rows=batch)


def _blob_arrays_equal(a: dict, b: dict) -> bool:
    aa, bb = a["arrays"], b["arrays"]
    return set(aa) == set(bb) and all(
        np.array_equal(np.asarray(aa[k]), np.asarray(bb[k])) for k in aa)


def _check_accounting(report, reason_counts=True):
    """Every ingested request answered exactly once, with a known reason."""
    m = report.metrics
    ids = [r.request_id for r in report.results]
    assert len(ids) == len(set(ids)), "a request was answered twice"
    assert len(ids) == m["requests"], \
        f"{m['requests']} ingested but {len(ids)} answered"
    assert all(r.reason in (REASON_ADMITTED, REASON_REJECTED,
                            REASON_QUARANTINED) for r in report.results)
    if reason_counts:
        by = {REASON_ADMITTED: 0, REASON_REJECTED: 0, REASON_QUARANTINED: 0}
        for r in report.results:
            by[r.reason] += 1
        assert by[REASON_ADMITTED] == m["admitted"] == m["completed"]
        assert by[REASON_REJECTED] == m["rejected"]
        assert by[REASON_QUARANTINED] == m["quarantined"]


# ================================================================= traffic
def test_traffic_counter_pure():
    cfg = _traffic()
    a = gen_requests(cfg, 5, 5 * 64, 64)
    b = gen_requests(cfg, 5, 5 * 64, 64)
    np.testing.assert_array_equal(a, b)
    c = gen_requests(cfg, 6, 6 * 64, 64)
    assert not np.array_equal(a, c)


def test_traffic_three_phases_drift():
    """The mix schedule must MOVE the chain's selectivities: allowlist
    fraction jumps in the enterprise phase, abuse/length failures spike
    in the storm phase — the drift the adaptive ordering exists for."""
    cfg = _traffic(phase_requests=4096)
    rows = {p: gen_requests(cfg, p, p * 4096, 4096) for p in range(3)}
    allow = {p: (rows[p][COL_ALLOW] > 0.5).mean() for p in rows}
    abuse = {p: (rows[p][COL_ABUSE] >= 0.92).mean() for p in rows}
    long_ = {p: (rows[p][COL_PROMPT_LEN] >= 900.0).mean() for p in rows}
    assert allow[2] > allow[0] + 0.3, allow
    assert abuse[1] > abuse[0] + 0.1, abuse
    assert long_[1] > long_[0] + 0.15, long_
    assert phase_of(cfg, 100) == 0 and phase_of(cfg, 5000) == 1 \
        and phase_of(cfg, 9000) == 2


def test_traffic_users_persistent():
    """Allowlist membership hangs off the user id hash, not the draw:
    the same user id always carries the same membership bit."""
    from repro.serving.traffic import gen_requests_with_users

    cfg = _traffic()
    seen: dict[int, float] = {}
    for b in range(8):
        feats, users = gen_requests_with_users(cfg, b, b * 128, 128)
        for uid, bit in zip(users.tolist(), feats[COL_ALLOW].tolist()):
            assert seen.setdefault(uid, bit) == bit, \
                f"user {uid} changed allowlist membership"


def test_request_stream_restartable():
    cfg = _traffic()
    s1 = _stream(cfg, 8 * 64, 64)
    it = iter(s1)
    for _ in range(3):
        next(it)
    snap = s1.state()
    rest1 = [rb.columns for rb in it]
    s2 = _stream(cfg, 8 * 64, 64)
    s2.restore(snap)
    rest2 = [rb.columns for rb in s2]
    assert len(rest1) == len(rest2) == 5
    for a, b in zip(rest1, rest2):
        np.testing.assert_array_equal(a, b)


# ====================================================== server determinism
def test_server_matches_synchronous_reference():
    """THE acceptance pin: the queued, threaded, slot-packed server's
    admitted set and final OrderState are bit-identical to a synchronous
    loop over the same seeded traffic — and a second server run is
    bit-identical to the first (thread timing never leaks in)."""
    tcfg = _traffic()
    plan = _plan()

    def run_server():
        server = AdmissionServer(
            build_session(plan), _stream(tcfg, 768, 64),
            ServerConfig(num_slots=4, queue_depth=4))
        return server.run(), server

    report1, _ = run_server()
    report2, _ = run_server()
    ref_session = build_session(plan)
    ref_state, ref_masks = synchronous_reference(
        ref_session, _stream(tcfg, 768, 64))

    for rep in (report1, report2):
        assert set(rep.masks) == set(ref_masks)
        for b in ref_masks:
            np.testing.assert_array_equal(rep.masks[b], ref_masks[b])
        assert _blob_arrays_equal(rep.state_blob,
                                  ref_session.save_state(ref_state))
        _check_accounting(rep)

    # reason codes agree with the oracle masks, request by request
    by_id = report1.results_by_id()
    for b, mask in ref_masks.items():
        for off, bit in enumerate(mask.tolist()):
            want = REASON_ADMITTED if bit else REASON_REJECTED
            assert by_id[b * 64 + off].reason == want
    # admitted requests actually decoded in a slot
    assert all(r.decode_steps >= 1 for r in report1.results
               if r.reason == REASON_ADMITTED)
    assert report1.metrics["slot_occupancy"] > 0.0
    assert report1.metrics["admission_latency_ms"]["p99"] >= \
        report1.metrics["admission_latency_ms"]["p50"] >= 0.0
    assert report1.metrics["guard"] is None  # unguarded gate: key present


def test_guarded_server_quarantines_with_reason_codes():
    """A poisoned batch is answered immediately with QUARANTINED for
    every row, GuardHealth flows into the metrics snapshot, and every
    clean batch stays bit-identical to a fault-free reference."""
    tcfg = _traffic()
    plan = _plan()
    hook = DataFaultInjector(poison_at=(2,))
    server = AdmissionServer(
        GuardedSession(build_session(plan)), _stream(tcfg, 512, 64),
        ServerConfig(num_slots=4), batch_hook=hook)
    report = server.run()
    _check_accounting(report)
    by_id = report.results_by_id()
    for off in range(64):
        assert by_id[2 * 64 + off].reason == REASON_QUARANTINED
    assert not report.masks[2].any()
    g = report.metrics["guard"]
    assert g["quarantined"] == 1 and g["steps"] == 7
    assert g["rungs"]["engine"] == "jnp"
    assert report.health_line and "quarantined=1" in report.health_line

    _, clean_masks = synchronous_reference(
        build_session(plan), _stream(tcfg, 512, 64))
    for b, mask in clean_masks.items():
        if b != 2:
            np.testing.assert_array_equal(report.masks[b], mask)


# ============================================================ backpressure
def test_backpressure_bounded_queues_never_drop():
    """Tight queues + slow slots: ingest must BLOCK (bounded memory) and
    every request still gets exactly one answer."""
    tcfg = _traffic()
    server = AdmissionServer(
        build_session(_plan()), _stream(tcfg, 20 * 16, 16),
        ServerConfig(num_slots=2, queue_depth=1, max_backlog=4),
        executor=SimExecutor(max_decode_steps=4, tick_s=0.001))
    report = server.run()
    _check_accounting(report)
    assert report.metrics["requests"] == 20 * 16
    assert len(server._backlog) == 0
    assert server.request_q.empty() and server.result_q.empty()


# =================================================================== drain
def test_drain_on_stop_finishes_inflight():
    """A stop request raised mid-run (from the ingest thread's pure
    batch hook, deterministically at batch 3): ingest stops, everything
    already queued is still gated and answered, in-flight slots finish,
    and the flushed final checkpoint restores into a fresh session."""
    tcfg = _traffic()
    plan = _plan()
    stop = types.SimpleNamespace(requested=False)

    def hook(b, cols):
        if b == 3:
            stop.requested = True
        return cols

    total = 64 * 32
    server = AdmissionServer(
        build_session(plan), _stream(tcfg, total, 32),
        ServerConfig(num_slots=4, queue_depth=4), batch_hook=hook)
    report = server.run(stop=stop)
    assert report.drained
    _check_accounting(report)
    assert 0 < report.metrics["requests"] < total, \
        "stop must land mid-stream (ingest neither empty nor complete)"
    assert all(r.decode_steps >= 1 for r in report.results
               if r.reason == REASON_ADMITTED), "in-flight slots must finish"
    restored = build_session(plan).restore_state(report.state_blob)
    assert build_session(plan).validate_state(restored)


def test_sigterm_drains_and_flushes():
    """A real SIGTERM through GracefulShutdown mid-run: the server
    drains (slots finish, accounting exact) and flushes the final
    checkpoint + health line instead of dying with work in flight."""
    tcfg = _traffic()
    server = AdmissionServer(
        GuardedSession(build_session(_plan())),
        _stream(tcfg, 256 * 16, 16),
        ServerConfig(num_slots=4, queue_depth=4),
        executor=SimExecutor(max_decode_steps=8, tick_s=0.002))
    timer = threading.Timer(
        0.3, lambda: os.kill(os.getpid(), signal.SIGTERM))
    stop = GracefulShutdown()
    with stop:
        timer.start()
        report = server.run(stop=stop)
    timer.cancel()
    assert stop.requested and report.drained
    _check_accounting(report)
    assert report.metrics["requests"] < 256 * 16
    assert report.state_blob is not None and report.health_line is not None


# ================================================================= the CLI
def test_serve_cli_smoke(tmp_path):
    """The BENCH_serve.json contract: the smoke CLI runs the 3-phase
    mix through the queued server, the parity gate passes, and the
    payload carries requests/sec + p99 admission latency + GuardHealth
    counters (the CI bench-serve job's schema)."""
    from repro.launch import serve

    out = tmp_path / "BENCH_serve.json"
    rc = serve.main(["--smoke", "--requests", "192", "--batch", "32",
                     "--slots", "4", "--bench-out", str(out),
                     "--gate-rps", "1", "--gate-p99-ms", "600000"])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["parity"]["ok"] is True
    assert payload["requests_per_sec"] > 0
    assert payload["admission_latency_ms"]["p99"] >= 0
    assert payload["guard"]["steps"] == 6
    assert set(payload["config"]["phases_seen"]) == {0, 1, 2}
    assert payload["decided"] == payload["requests"] == 192
