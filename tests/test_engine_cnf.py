"""CNF (AND-of-OR) conformance across the engine registry.

Pins the tentpole contract: jnp ≡ pallas-interpret ≡ numpy ≡ dense oracle
on OR-group chains — masks exactly, counters bit-close — plus the engine
registry surface and the group-aware ordering behavior."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, MonitorSpec,
                        OrderingConfig, available_engines, get_engine, pack)
from repro.core import predicates as P
from repro.core import stats as S
from repro.core.predicates import Predicate
from repro.kernels.filter_chain.ref import filter_chain_ref

ENGINES = ("jnp", "pallas", "numpy")


def cnf_chain(shape="pair"):
    """Chains over 4 columns with OR-groups of different widths."""
    base = dict(static_cost=1.0)
    if shape == "pair":
        # (gt OR lt) AND between AND (eq OR mix)
        return [
            Predicate("gt", 0, P.OP_GT, 0.6, group="a", **base),
            Predicate("lt", 1, P.OP_LT, 0.3, group="a", static_cost=1.3),
            Predicate("bet", 0, P.OP_BETWEEN, 0.1, t2=0.9, static_cost=2.0),
            Predicate("eq", 2, P.OP_EQ, 3.0, group="b", static_cost=0.7),
            Predicate("mix", 3, P.OP_HASHMIX, 0.45 * P.MIX_MOD, rounds=6,
                      group="b", static_cost=6.0),
        ]
    if shape == "wide":
        # gt AND (lt OR bet OR eq)
        return [
            Predicate("gt", 0, P.OP_GT, 0.2, **base),
            Predicate("lt", 1, P.OP_LT, 0.2, group="w", static_cost=1.3),
            Predicate("bet", 0, P.OP_BETWEEN, 0.4, t2=0.6, group="w",
                      static_cost=2.0),
            Predicate("eq", 2, P.OP_EQ, 5.0, group="w", static_cost=0.7),
        ]
    if shape == "single_group":
        # one big OR over everything
        return [
            Predicate("gt", 0, P.OP_GT, 0.9, group="o", **base),
            Predicate("lt", 1, P.OP_LT, 0.05, group="o", static_cost=1.3),
            Predicate("eq", 2, P.OP_EQ, 7.0, group="o", static_cost=0.7),
        ]
    raise ValueError(shape)


def cols_for(n_rows, seed=0):
    r = np.random.default_rng(seed)
    return np.stack([
        r.uniform(0, 1, n_rows),
        r.uniform(0, 1, n_rows),
        r.integers(0, 8, n_rows).astype(np.float64),
        r.uniform(0, P.MIX_MOD, n_rows),
    ]).astype(np.float32)


def group_contig_perms(specs, seed):
    """A few random perms that keep group members contiguous."""
    r = np.random.default_rng(seed)
    members = [list(m) for m in specs.group_members]
    perms = []
    for _ in range(3):
        order = r.permutation(len(members))
        perm = []
        for g in order:
            mem = list(members[g])
            r.shuffle(mem)
            perm.extend(mem)
        perms.append(np.asarray(perm, np.int32))
    return perms


@pytest.mark.parametrize("shape", ["pair", "wide", "single_group"])
@pytest.mark.parametrize("n_rows", [64, 2048, 5000])
def test_engines_agree_on_cnf(shape, n_rows):
    preds = cnf_chain(shape)
    specs = pack(preds)
    cols_np = cols_for(n_rows, seed=n_rows)
    cols = jnp.asarray(cols_np)
    for perm in group_contig_perms(specs, seed=n_rows):
        mon = MonitorSpec(collect_rate=37, sample_phase=5)
        ref = filter_chain_ref(cols, specs, jnp.asarray(perm),
                               collect_rate=37, sample_phase=5)
        for name in ENGINES:
            eng = get_engine(name)
            data = cols if eng.traceable else cols_np
            got = eng.run_chain(data, specs, jnp.asarray(perm), mon)
            for field in got._fields:
                kw = {} if field in ("mask", "cut_counts", "n_monitored",
                                     "group_cut_counts") else {"rtol": 1e-6}
                cmp = np.testing.assert_array_equal if not kw \
                    else np.testing.assert_allclose
                cmp(np.asarray(getattr(got, field)),
                    np.asarray(getattr(ref, field)),
                    err_msg=f"{name} vs oracle mismatch in {field} "
                            f"(shape={shape}, perm={perm.tolist()})", **kw)


def test_cnf_mask_is_and_of_ors():
    """Hand-checked truth table on a tiny batch."""
    preds = [Predicate("x_hi", 0, P.OP_GT, 0.5, group="g"),
             Predicate("y_hi", 1, P.OP_GT, 0.5, group="g"),
             Predicate("z_hi", 2, P.OP_GT, 0.5)]
    specs = pack(preds)
    cols = np.asarray([[0.9, 0.1, 0.9, 0.1],
                       [0.9, 0.9, 0.1, 0.1],
                       [0.9, 0.9, 0.9, 0.9]], np.float32)
    want = [(0.9 > 0.5 or 0.9 > 0.5) and True,
            (0.1 > 0.5 or 0.9 > 0.5) and True,
            (0.9 > 0.5 or 0.1 > 0.5) and True,
            (0.1 > 0.5 or 0.1 > 0.5) and True]
    mon = MonitorSpec(collect_rate=2, sample_phase=0)
    for name in ENGINES:
        eng = get_engine(name)
        data = cols if not eng.traceable else jnp.asarray(cols)
        got = eng.run_chain(data, specs, jnp.arange(3, dtype=jnp.int32), mon)
        assert np.asarray(got.mask).tolist() == want, name


def test_or_short_circuit_work_accounting():
    """Rows that pass the first OR member must not be charged the second."""
    preds = [Predicate("always", 0, P.OP_GT, -1.0, group="g"),
             Predicate("mix", 3, P.OP_HASHMIX, 0.5 * P.MIX_MOD, rounds=8,
                       group="g", static_cost=9.0)]
    specs = pack(preds)
    cols = cols_for(4096, seed=1)
    mon = MonitorSpec(collect_rate=1 << 20, sample_phase=1)
    for name in ENGINES:
        eng = get_engine(name)
        data = cols if not eng.traceable else jnp.asarray(cols)
        got = eng.run_chain(data, specs, jnp.arange(2, dtype=jnp.int32), mon)
        np.testing.assert_allclose(np.asarray(got.active_before),
                                   [4096.0, 0.0], err_msg=name)
        assert float(got.work_units) == pytest.approx(4096.0)
        assert int(np.asarray(got.mask).sum()) == 4096


def test_flat_chain_is_singleton_groups():
    specs = pack([Predicate("a", 0, P.OP_GT, 0.5),
                  Predicate("b", 1, P.OP_LT, 0.5)])
    assert specs.is_flat
    assert specs.groups == (0, 1)
    assert specs.group_members == ((0,), (1,))


def test_group_normalization_first_appearance():
    preds = [Predicate("a", 0, P.OP_GT, 0.1, group="z"),
             Predicate("b", 1, P.OP_GT, 0.2, group="z"),
             Predicate("c", 2, P.OP_GT, 0.3),
             Predicate("d", 3, P.OP_GT, 0.4, group=7)]
    assert P.normalize_groups(preds) == (0, 0, 1, 2)


def test_non_adjacent_group_members_rejected():
    """The jit-traced engines can't detect interleaved group layouts at
    runtime, so pack() must reject them eagerly."""
    preds = [Predicate("a", 0, P.OP_GT, 0.1, group="z"),
             Predicate("b", 1, P.OP_GT, 0.2),
             Predicate("c", 2, P.OP_GT, 0.3, group="z")]
    with pytest.raises(ValueError, match="not contiguous"):
        pack(preds)
    # ...including layouts produced by static_filter's up-front reorder
    from repro.core import static_filter
    ok = [Predicate("a", 0, P.OP_GT, 0.1, group="z"),
          Predicate("b", 1, P.OP_GT, 0.2, group="z"),
          Predicate("c", 2, P.OP_GT, 0.3)]
    with pytest.raises(ValueError, match="not contiguous"):
        static_filter(ok, order=[0, 2, 1])
    static_filter(ok, order=[2, 0, 1])      # group stays adjacent: fine


def test_registry_surface():
    assert set(ENGINES) <= set(available_engines())
    with pytest.raises(ValueError, match="unknown filter engine"):
        get_engine("cuda")
    with pytest.raises(ValueError, match="bad backend"):
        AdaptiveFilterConfig(backend="cuda")


def test_cnf_order_keeps_groups_contiguous():
    groups = (0, 1, 1, 2, 2, 2)
    r = np.random.default_rng(0)
    for _ in range(20):
        grank = jnp.asarray(r.uniform(0, 1, 3), jnp.float32)
        mrank = jnp.asarray(r.uniform(0, 1, 6), jnp.float32)
        perm, gperm = S.cnf_order(grank, mrank, groups)
        seq = [groups[i] for i in np.asarray(perm)]
        runs = [x for j, x in enumerate(seq) if j == 0 or seq[j - 1] != x]
        assert len(set(runs)) == len(runs), seq
        assert sorted(np.asarray(perm).tolist()) == list(range(6))
        # groups appear in gperm (rank-ascending) order
        assert runs == np.asarray(gperm).tolist()


def test_adaptive_learns_within_group_order():
    """In an OR group (cheap rare-pass, expensive frequent-pass), member
    ordering must converge to the cost-aware miss-rate rule nc/s — and the
    whole group (cuts almost nothing) must sink behind the selective
    singleton."""
    preds = [
        Predicate("sel", 0, P.OP_LT, 0.3),                       # cuts 70%
        Predicate("rare", 1, P.OP_GT, 0.9, group="o"),           # passes 10%
        Predicate("often", 1, P.OP_GT, 0.1, group="o",
                  static_cost=1.0),                              # passes 90%
    ]
    filt = AdaptiveFilter(preds, AdaptiveFilterConfig(
        ordering=OrderingConfig(collect_rate=20, calculate_rate=40_000,
                                momentum=0.3)))
    state = filt.init_state()
    r = np.random.default_rng(0)
    for b in range(8):
        cols = np.stack([r.uniform(0, 1, 16_384),
                         r.uniform(0, 1, 16_384)]).astype(np.float32)
        state, _, _ = filt.jit_step(state, jnp.asarray(cols))
    assert int(state.epoch) >= 2
    perm = np.asarray(state.perm).tolist()
    # selective singleton first; "often" resolves the OR for 90% of rows at
    # equal cost, so it must precede "rare" inside the group
    assert perm[0] == 0
    assert perm.index(2) < perm.index(1)
