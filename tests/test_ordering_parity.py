"""jnp-vs-numpy parity of the SINGLE ordering implementation.

The seed carried a handwritten numpy mirror (``_HostOrderState``) of the
jnp epoch controller; it is gone — ``ordering.advance``/``epoch_update``
now run the identical code on either array namespace. These tests pin the
two namespaces bit-close (replacing the mirror's implicit contract) on
flat and CNF chains, with and without snap-on-flip, and check the host
streaming path end-to-end against the jitted one."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig,
                        paper_filters_4, paper_filters_cnf)
from repro.core import ordering as O
from repro.data.stream import gen_batch


def synthetic_batches(n_preds, n_batches, seed=0):
    """Deterministic per-batch monitor results (cut, costs, n_mon)."""
    r = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        n_mon = int(r.integers(50, 80))
        cut = r.integers(0, n_mon, n_preds).astype(np.float32)
        costs = (r.uniform(0.5, 4.0, n_preds) * n_mon).astype(np.float32)
        out.append((cut, costs, np.float32(n_mon)))
    return out


@pytest.mark.parametrize("snap", [0.0, 1.05])
@pytest.mark.parametrize("groups", [None, (0, 0, 1, 2), (0, 1, 1, 1)])
def test_advance_parity_jnp_vs_numpy(snap, groups):
    n_preds = 4
    n_groups = max(groups) + 1 if groups else n_preds
    cfg = OrderingConfig(collect_rate=100, calculate_rate=150,
                         momentum=0.3, snap_threshold=snap)
    st_j = O.init_order_state(n_preds, n_groups, xp=jnp)
    st_n = O.init_order_state(n_preds, n_groups, xp=np)
    for cut, costs, n_mon in synthetic_batches(n_preds, 12):
        gcut = None
        if groups is not None:
            # synthetic group cut: min of member cuts (any member passing
            # saves the row, so the group cut can't exceed any member's)
            gcut = np.asarray([cut[[i for i, g in enumerate(groups)
                                    if g == gg]].min()
                               for gg in range(n_groups)], np.float32)
        st_j = O.advance(st_j, cfg, jnp.asarray(cut), jnp.asarray(costs),
                         jnp.asarray(n_mon), n_rows=64,
                         group_cut=None if gcut is None else jnp.asarray(gcut),
                         groups=groups, xp=jnp)
        st_n = O.advance(st_n, cfg, cut, costs, n_mon, n_rows=64,
                         group_cut=gcut, groups=groups, xp=np)
        np.testing.assert_array_equal(np.asarray(st_j.perm),
                                      np.asarray(st_n.perm))
        np.testing.assert_array_equal(np.asarray(st_j.group_perm),
                                      np.asarray(st_n.group_perm))
        np.testing.assert_allclose(np.asarray(st_j.adj_rank),
                                   np.asarray(st_n.adj_rank),
                                   rtol=1e-5, atol=1e-6)
        assert int(st_j.epoch) == int(st_n.epoch)
        assert int(st_j.rows_into_epoch) == int(st_n.rows_into_epoch)
    assert int(st_j.epoch) >= 3          # the boundary actually fired


def test_advance_parity_under_jit():
    """The jnp namespace path must trace (lax.cond boundary) and agree with
    the eager numpy path."""
    import jax

    cfg = OrderingConfig(collect_rate=50, calculate_rate=100, momentum=0.3)
    adv = jax.jit(lambda s, c, k, m: O.advance(s, cfg, c, k, m, n_rows=64))
    st_j = O.init_order_state(3, xp=jnp)
    st_n = O.init_order_state(3, xp=np)
    for cut, costs, n_mon in synthetic_batches(3, 6, seed=7):
        st_j = adv(st_j, jnp.asarray(cut), jnp.asarray(costs),
                   jnp.asarray(n_mon))
        st_n = O.advance(st_n, cfg, cut, costs, n_mon, n_rows=64, xp=np)
        np.testing.assert_array_equal(np.asarray(st_j.perm),
                                      np.asarray(st_n.perm))
    assert int(st_j.epoch) >= 2


def test_zero_evidence_epoch_keeps_order():
    cfg = OrderingConfig(collect_rate=10, calculate_rate=20, momentum=0.3)
    for xp in (jnp, np):
        st = O.init_order_state(3, xp=xp)
        st = O.advance(st, cfg, xp.zeros(3, xp.float32),
                       xp.zeros(3, xp.float32), xp.zeros((), xp.float32),
                       n_rows=32, xp=xp)
        assert int(st.epoch) == 0
        np.testing.assert_array_equal(np.asarray(st.perm), [0, 1, 2])


@pytest.mark.parametrize("chain", ["flat", "cnf"])
def test_host_stream_matches_jit_stream(chain):
    """End-to-end: numpy engine + xp=numpy ordering vs jitted jnp step must
    produce the same permutation trajectory and the same masks."""
    preds = (paper_filters_4 if chain == "flat" else paper_filters_cnf)("fig1")
    ordering = OrderingConfig(collect_rate=500, calculate_rate=100_000,
                              momentum=0.3)
    batches = [gen_batch(0, b, b * 65536, 65536) for b in range(6)]
    out = {}
    for backend in ("jnp", "numpy"):
        filt = AdaptiveFilter(preds, AdaptiveFilterConfig(
            backend=backend, ordering=ordering))
        res = list(filt.process_stream(batches))
        out[backend] = res
    for (_, m_j, d_j), (_, m_n, d_n) in zip(out["jnp"], out["numpy"]):
        np.testing.assert_array_equal(np.asarray(m_j), np.asarray(m_n))
        assert d_j["perm"] == d_n["perm"]
        assert d_j["epoch"] == d_n["epoch"]
        assert d_j["work_units"] == pytest.approx(d_n["work_units"],
                                                  rel=1e-5)
