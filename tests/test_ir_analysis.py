"""IR-tier static analysis: seeded defects + clean-repo pins.

Every detector class ships with a test that INJECTS its defect and
asserts detection — a verifier nobody has seen fire is a comment, not a
check. The kernel defects are hand-built ``Launch`` records (the audit's
geometry checks are pure functions of the record); the jaxpr defects are
tiny traced closures; the fingerprint defects are simulated drifted
declarations via the ``runtime_only=`` override. The clean-repo pins
lock the shipped tree's expected findings exactly (one sanctioned
interpret-only warning, nothing else), so any new finding is a visible
diff here before it is a CI failure.
"""

import json

import numpy as np
import pytest

from repro.analysis import diagnostics as diag_lib
from repro.analysis import hotpath_lint, jaxpr_lint, kernel_audit, plan_matrix
from repro.analysis.kernel_audit import BlockInfo, Launch

jax = pytest.importorskip("jax")
jnp = jax.numpy


def codes(diags):
    return [d.code for d in diags]


# ===================================================== kernel seeded defects
def _launch(name="seeded_kernel", grid=(2,), in_specs=(), out_specs=(),
            in_shapes=(), out_shapes=(), ctx=None):
    return Launch(name=name, grid=grid, in_specs=list(in_specs),
                  out_specs=list(out_specs), in_shapes=list(in_shapes),
                  out_shapes=list(out_shapes), ctx=ctx or {})


def test_kernel_audit_catches_off_by_one_index_map():
    # 4096 rows in 2048-blocks = 2 blocks; map i -> i+1 walks off the end
    bad = _launch(
        grid=(2,),
        in_specs=[BlockInfo((4, 2048), lambda i: (0, i + 1), "vmem")],
        in_shapes=[((4, 4096), "float32")])
    found = kernel_audit.audit_launches([bad])
    assert "kernel-oob-access" in codes(found)
    assert all(d.severity == "error" for d in found)

    good = _launch(
        grid=(2,),
        in_specs=[BlockInfo((4, 2048), lambda i: (0, i), "vmem")],
        in_shapes=[((4, 4096), "float32")])
    assert kernel_audit.audit_launches([good]) == []


def test_kernel_audit_catches_misaligned_lane_tile():
    # lane block dim 100: not a multiple of 128, not the full extent
    bad = _launch(
        grid=(1,),
        in_specs=[BlockInfo((8, 100), lambda i: (0, 0), "vmem")],
        in_shapes=[((64, 4096), "float32")])
    found = kernel_audit.audit_launches([bad])
    assert codes(found) == ["kernel-misaligned-tile"]
    assert found[0].severity == "error"


def test_kernel_audit_warns_misaligned_sublane():
    # sublane 12: not 1, not the full 64, not a multiple of 8
    bad = _launch(
        grid=(1,),
        in_specs=[BlockInfo((12, 128), lambda i: (0, 0), "vmem")],
        in_shapes=[((64, 4096), "float32")])
    found = kernel_audit.audit_launches([bad])
    assert codes(found) == ["kernel-misaligned-sublane"]
    assert found[0].severity == "warning"


def test_kernel_audit_catches_vmem_blowout():
    # 8 x 4096 x 2048 f32 = 256 MiB block, x2 double-buffer >> 16 MiB
    bad = _launch(
        grid=(1,),
        in_specs=[BlockInfo((8, 4096, 2048), lambda i: (0, 0, 0), "vmem")],
        in_shapes=[((8, 4096, 2048), "float32")])
    found = kernel_audit.audit_launches([bad])
    assert "kernel-vmem-pressure" in codes(found)


def test_kernel_audit_catches_narrow_gather_ring():
    # the guarded dynamic store needs capacity + tile of ring slack
    cap, tile = 1024, 2048
    bad = _launch(
        name="compact_gather_seeded", grid=(2,),
        out_specs=[BlockInfo(None, None, "vmem")],
        out_shapes=[((4, cap + tile - 128), "float32")],
        ctx={"capacity": cap, "tile": tile})
    found = kernel_audit.audit_launches([bad])
    assert "kernel-oob-access" in codes(found)
    assert "ring width" in [d for d in found
                            if d.code == "kernel-oob-access"][0].message


def test_kernel_audit_clean_on_shipped_kernels():
    """The shipped Pallas launches prove in-bounds + aligned + in-budget
    across the ragged shape sweep; the ONLY expected finding is the
    sanctioned interpret-only warning on the gather's dynamic store."""
    found = kernel_audit.audit_kernels()
    assert diag_lib.errors(found) == []
    assert set(codes(found)) == {"kernel-interpret-only"}


def test_kernel_audit_model_drift_detected(monkeypatch):
    """Skewing the roofline model must trip the byte-contract check."""
    launches = kernel_audit.capture_launches(
        shapes=((2048, 2048, 128, 2048),))
    real = kernel_audit._load_roofline().filter_ingest_model

    class _Skewed:
        @staticmethod
        def filter_ingest_model(**kw):
            m = real(**kw)
            m["bytes_chain_only"] += 512     # model now over-charges
            return m

    monkeypatch.setattr(kernel_audit, "_load_roofline", lambda: _Skewed)
    found = kernel_audit.crosscheck_roofline(launches)
    assert "kernel-model-drift" in codes(found)


def test_kernel_geometry_matches_roofline_exactly():
    launches = kernel_audit.capture_launches()
    assert kernel_audit.crosscheck_roofline(launches) == []


# ====================================================== jaxpr seeded defects
def test_jaxpr_lint_catches_f64():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(jnp.ones((4,)))
        found = jaxpr_lint.lint_jaxpr(closed, name="seeded_f64")
    assert "jaxpr-f64" in codes(found)
    assert all(d.severity == "error"
               for d in found if d.code == "jaxpr-f64")


def test_jaxpr_lint_catches_scalar_capture():
    captured = jnp.float32(3.0)          # 0-d device constant in closure
    closed = jax.make_jaxpr(lambda x: x * captured)(jnp.ones((4,)))
    found = jaxpr_lint.lint_jaxpr(closed, name="seeded_capture")
    assert "jaxpr-scalar-capture" in codes(found)

    # python scalars inline as literals — NOT flagged
    closed = jax.make_jaxpr(lambda x: x * 3.0)(jnp.ones((4,)))
    found = jaxpr_lint.lint_jaxpr(closed, name="literal")
    assert "jaxpr-scalar-capture" not in codes(found)


def test_jaxpr_lint_catches_dead_code():
    def f(x):
        _ = jnp.sin(x) + 1.0             # computed, thrown away
        return x * 2.0

    closed = jax.make_jaxpr(f)(jnp.ones((4,)))
    found = jaxpr_lint.lint_jaxpr(closed, name="seeded_dead")
    assert "jaxpr-dead-code" in codes(found)


def test_jaxpr_lint_catches_degenerate_broadcast():
    # current jax elides no-op broadcasts at staging, so seed the rule
    # with a hand-built record shaped like a jaxpr (the lint reads only
    # primitive.name / params / invars / outvars / effects)
    from types import SimpleNamespace as NS

    aval = NS(shape=(4,), dtype=jnp.float32, ndim=1)
    var_in, var_out = NS(aval=aval), NS(aval=aval)
    eqn = NS(primitive=NS(name="broadcast_in_dim"), params={},
             invars=[var_in], outvars=[var_out], effects=frozenset())
    jaxpr = NS(eqns=[eqn], invars=[var_in], outvars=[var_out])
    closed = NS(jaxpr=jaxpr, consts=[])
    found = jaxpr_lint.lint_jaxpr(closed, name="seeded_bcast")
    assert "jaxpr-degenerate-broadcast" in codes(found)


def test_jaxpr_lint_catches_host_callback():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0

    closed = jax.make_jaxpr(f)(jnp.ones((4,)))
    found = jaxpr_lint.lint_jaxpr(closed, name="seeded_cb")
    assert "jaxpr-host-callback" in codes(found)


def test_session_jaxprs_clean_on_shipped_plans():
    """Every traced session callable of the representative plan families
    lints clean (info-level donation advisories only)."""
    from repro.core.plan import FilterPlan, TokenizeSpec
    from repro.core.predicates import paper_filters_4

    preds = paper_filters_4("fig1")
    for plan in (FilterPlan(predicates=preds),
                 FilterPlan(predicates=preds, compact=True,
                            tokenize=TokenizeSpec(32000),
                            skip_tier="zonemap")):
        found = jaxpr_lint.lint_plan_jaxprs(plan, rows_per_shard=256)
        assert [d for d in found if d.severity != "info"] == []


def test_make_jaxprs_covers_every_jitted_entry():
    from repro.core.plan import FilterPlan, TokenizeSpec
    from repro.core.predicates import paper_filters_4
    from repro.core.session import build_session

    preds = paper_filters_4("fig1")
    batch = np.random.default_rng(0).uniform(
        -64, 64, (4, 512)).astype(np.float32)

    plan = FilterPlan(predicates=preds, compact=True,
                      tokenize=TokenizeSpec(32000), skip_tier="zonemap")
    traced = build_session(plan).make_jaxprs(batch)
    assert {"step", "exchange", "compact", "tokenize", "validate_state",
            "skip_compact"} <= set(traced)

    plain = FilterPlan(predicates=preds, skip_tier="zonemap")
    traced = build_session(plain).make_jaxprs(batch)
    assert {"step", "exchange", "validate_state", "skip_step"} \
        <= set(traced)


def test_tokenizer_trace_then_execute_is_safe():
    """Tracing the tokenizer FIRST must not poison its cached closure for
    later real execution (the functools.cache tracer-leak class the
    scalar-capture rule exists for)."""
    from repro.data import tokenizer

    packed = jnp.ones((1, 2, 128), jnp.float32)
    counts = jnp.asarray([5], jnp.int32)
    closed = jax.make_jaxpr(
        lambda p, c: tokenizer.tokens_from_padded(p, c, 1000))(packed, counts)
    found = jaxpr_lint.lint_jaxpr(closed, name="tokenize")
    assert "jaxpr-scalar-capture" not in codes(found)
    toks, n = tokenizer.tokens_from_padded(packed, counts, 1000)
    assert int(n) == 5 * 8               # executes fine after the trace


# ================================================= deterministic diagnostics
def _sample_diags():
    return [
        diag_lib.Diagnostic("z-code", "warning", "b.py:2", "msg", "hint"),
        diag_lib.Diagnostic("a-code", "error", "b.py:2", "msg", ""),
        diag_lib.Diagnostic("a-code", "error", "a.py:1", "msg", ""),
        diag_lib.Diagnostic("a-code", "error", "b.py:2", "msg", ""),  # dup
        diag_lib.Diagnostic("m-code", "info", "plan:x", "n", ""),
    ]


def test_canonical_is_order_invariant_and_deduped():
    diags = _sample_diags()
    fwd = diag_lib.canonical(diags)
    rev = diag_lib.canonical(list(reversed(diags)))
    assert fwd == rev
    assert len(fwd) == 4                 # exact duplicate removed
    assert json.dumps(diag_lib.to_json(fwd)) \
        == json.dumps(diag_lib.to_json(rev))     # byte-reproducible


def test_canonical_sorts_by_location_then_code():
    out = diag_lib.canonical(_sample_diags())
    assert [(d.location, d.code) for d in out] == [
        ("a.py:1", "a-code"), ("b.py:2", "a-code"), ("b.py:2", "z-code"),
        ("plan:x", "m-code")]


# ----------------------------------------------------------------- SARIF
def test_sarif_export_shape():
    sarif = diag_lib.to_sarif(diag_lib.canonical(_sample_diags()))
    assert sarif["version"] == "2.1.0"
    run = sarif["runs"][0]
    assert [r["id"] for r in run["tool"]["driver"]["rules"]] \
        == ["a-code", "m-code", "z-code"]
    levels = {r["ruleId"]: r["level"] for r in run["results"]}
    assert levels == {"a-code": "error", "z-code": "warning",
                      "m-code": "note"}
    by_rule = {r["ruleId"]: r for r in run["results"]}
    # file:line findings carry a physicalLocation; semantic ones logical
    assert "physicalLocation" in by_rule["a-code"]["locations"][0]
    assert by_rule["m-code"]["locations"][0]["logicalLocations"][0][
        "fullyQualifiedName"] == "plan:x"
    # fix hints ride along in the message text
    assert "hint: hint" in by_rule["z-code"]["message"]["text"]


# ======================================================== stale allowlist
def test_stale_allowlist_entry_is_an_error():
    allow = dict(hotpath_lint.ALLOWLIST)
    allow["AdaptiveFilter.renamed_long_ago"] = "a dangling exemption"
    found = hotpath_lint.lint_hotpath(allowlist=allow)
    stale = [d for d in found if d.code == "hotpath-stale-allowlist"]
    assert len(stale) == 1
    assert stale[0].severity == "error"
    assert "renamed_long_ago" in stale[0].message


def test_shipped_allowlist_has_no_stale_entries():
    found = hotpath_lint.lint_hotpath()
    assert [d for d in found
            if d.code == "hotpath-stale-allowlist"] == []


# ==================================================== fingerprint coverage
def test_fingerprint_coverage_clean_on_shipped_plan():
    assert plan_matrix.fingerprint_coverage() == []


def test_fingerprint_coverage_catches_conflict():
    # declare a HASHED field (scope) runtime-only: declaration vs. hash
    from repro.core.plan import FINGERPRINT_RUNTIME_ONLY
    drifted = FINGERPRINT_RUNTIME_ONLY | {"scope"}
    found = plan_matrix.fingerprint_coverage(runtime_only=drifted)
    assert codes(found) == ["plan-fingerprint-conflict"]
    assert "scope" in found[0].message


def test_fingerprint_coverage_catches_uncovered():
    # drop an unhashed field (engine) from the declaration: now uncovered
    from repro.core.plan import FINGERPRINT_RUNTIME_ONLY
    drifted = FINGERPRINT_RUNTIME_ONLY - {"engine"}
    found = plan_matrix.fingerprint_coverage(runtime_only=drifted)
    assert codes(found) == ["plan-fingerprint-uncovered"]
    assert "engine" in found[0].message


# =========================================================== plan matrix
def test_plan_enumeration_and_identity_dedupe():
    named = plan_matrix.enumerate_plans()
    assert len(named) > 100              # the space is genuinely large
    deduped = plan_matrix.dedupe_plans(named)
    assert 0 < len(deduped) < len(named)
    # identity really is a dedupe key: re-keying loses nothing
    assert len({key for _, _, key in deduped}) == len(deduped)


def test_budget_selection_covers_every_axis_value():
    deduped = plan_matrix.dedupe_plans(plan_matrix.enumerate_plans())
    selected, skipped = plan_matrix.select_within_budget(deduped, 12)
    assert len(selected) == 12
    assert len(selected) + len(skipped) == len(deduped)
    covered = set().union(*(set(key) for _, _, key in selected))
    everything = set().union(*(set(key) for _, _, key in deduped))
    assert covered == everything         # no axis value left unaudited


# ================================================================== CLI
def test_cli_kernels_flag_clean(capsys):
    from repro.analysis.__main__ import main

    assert main(["--kernels"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s)" in out


def test_cli_sarif_output(tmp_path, capsys):
    from repro.analysis.__main__ import main

    sarif_path = tmp_path / "out.sarif"
    assert main(["--hotpath", "--json", "--sarif", str(sarif_path)]) == 0
    payload = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert payload == []                 # clean repo
    sarif = json.loads(sarif_path.read_text())
    assert sarif["version"] == "2.1.0"
    assert sarif["runs"][0]["results"] == []


def test_cli_json_is_byte_reproducible(capsys):
    from repro.analysis.__main__ import main

    def run():
        assert main(["--kernels", "--json"]) == 0
        return capsys.readouterr().out

    assert run() == run()
