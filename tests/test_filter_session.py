"""FilterPlan → FilterSession: the one declarative entry point.

Fast tier: plan validation (single-sourced cross-field rules), the
four-way ``session.step`` parity pin (jnp/pallas × sharded/unsharded,
mask and compact paths), the uniform StepResult ABI, shim end-of-life,
versioned checkpoints (v1 blobs, fingerprint guard), and the pure
elastic-reshard math. The multi-device 2↔4-shard elastic restores fork
4-forced-device subprocesses (slow tier, like tests/test_sharded_filter.py).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


def _ordering(**kw):
    from repro.core import OrderingConfig
    kw.setdefault("collect_rate", 100)
    kw.setdefault("calculate_rate", 5000)
    return OrderingConfig(**kw)


# ================================================================ validation
def test_plan_aggregates_all_violations():
    """A plan with several bad fields reports every one in a single
    ValueError (one round trip), each enumerating its valid choices."""
    from repro.core import FilterPlan, paper_filters_4

    with pytest.raises(ValueError) as ei:
        FilterPlan(predicates=paper_filters_4("fig1"), cost_mode="guess",
                   exchange="sometimes", slack=0.5)
    msg = str(ei.value)
    assert "invalid plan field combinations" in msg
    assert "bad cost_mode" in msg and "'static', 'measured'" in msg
    assert "bad exchange" in msg and "compact_slack" in msg


def test_tokenize_plan_audits_clean():
    """The u32-limb contract (zero f64 ops in step + tokenizer modules, no
    host callbacks, collective-free step) pinned through the shared HLO
    auditor — the same pass the CI ``analysis`` job runs."""
    from repro.analysis import audit_plan
    from repro.core import FilterPlan, TokenizeSpec, paper_filters_4

    plan = FilterPlan(predicates=paper_filters_4("fig1"), compact=True,
                      tokenize=TokenizeSpec(32000), ordering=_ordering())
    assert audit_plan(plan) == []


def test_plan_validates_whole_matrix():
    """FilterPlan is the single source of truth for valid combinations —
    same messages the legacy config surfaces raise (they delegate here)."""
    from repro.core import FilterPlan, TokenizeSpec, paper_filters_4
    preds = paper_filters_4("fig1")

    with pytest.raises(ValueError, match="bad cost_mode"):
        FilterPlan(predicates=preds, cost_mode="guess")
    with pytest.raises(ValueError, match="bad backend"):
        FilterPlan(predicates=preds, engine="cuda9000")
    with pytest.raises(ValueError, match="host"):
        FilterPlan(predicates=preds, cost_mode="measured")
    with pytest.raises(ValueError, match="host engine"):
        FilterPlan(predicates=preds, engine="numpy", cost_mode="measured",
                   shards=2)
    with pytest.raises(ValueError, match="compact_output"):
        FilterPlan(predicates=preds, engine="numpy", cost_mode="measured",
                   compact=True)
    with pytest.raises(ValueError, match="compact_capacity"):
        FilterPlan(predicates=preds, capacity=64)
    with pytest.raises(ValueError, match="compact_capacity"):
        FilterPlan(predicates=preds, compact=True, capacity="huge")
    with pytest.raises(ValueError, match="compact_slack"):
        FilterPlan(predicates=preds, compact=True, capacity="auto",
                   slack=0.2)
    with pytest.raises(ValueError, match="exchange"):
        FilterPlan(predicates=preds, exchange="sometimes",
                   scope="centralized")
    with pytest.raises(ValueError, match="CENTRALIZED"):
        FilterPlan(predicates=preds, exchange="deferred")
    with pytest.raises(ValueError, match="device_tokenize"):
        FilterPlan(predicates=preds, tokenize=TokenizeSpec(1000))
    with pytest.raises(ValueError, match="vocab_size"):
        TokenizeSpec(1 << 25)
    with pytest.raises(ValueError, match="shards"):
        FilterPlan(predicates=preds, shards=0)
    with pytest.raises(ValueError, match="predicate"):
        FilterPlan(predicates=[])


def test_legacy_config_delegates_to_plan_rules():
    """AdaptiveFilterConfig and ShardedAdaptiveFilter funnel through the
    same validate_combo (no drift between the surfaces)."""
    import jax

    from repro.core import AdaptiveFilterConfig, ShardedAdaptiveFilter, \
        paper_filters_4

    with pytest.raises(ValueError, match="CENTRALIZED"):
        AdaptiveFilterConfig(exchange="deferred", scope="per_shard")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="host engine"):
        ShardedAdaptiveFilter(
            paper_filters_4("fig1"),
            AdaptiveFilterConfig(backend="numpy", cost_mode="measured"),
            mesh=mesh)


def test_fingerprint_covers_semantics_not_execution():
    from repro.core import FilterPlan, paper_filters_4, paper_filters_cnf
    preds = paper_filters_4("fig1")
    base = FilterPlan(predicates=preds, ordering=_ordering())
    # execution details don't change identity (elastic/engine-portable)
    same = FilterPlan(predicates=preds, ordering=_ordering(),
                      engine="pallas", compact=True, capacity=128)
    assert base.fingerprint() == same.fingerprint()
    # semantic fields do
    other_chain = FilterPlan(predicates=paper_filters_cnf("fig1"),
                             ordering=_ordering())
    other_rate = FilterPlan(predicates=preds,
                            ordering=_ordering(calculate_rate=999))
    assert base.fingerprint() != other_chain.fingerprint()
    assert base.fingerprint() != other_rate.fingerprint()


# ============================================================ four-way parity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("sharded", [False, True])
def test_session_step_matches_legacy(backend, sharded):
    """Acceptance pin: session.step is bit-identical to the legacy
    step/step_compact surfaces on both traceable engines, sharded (live
    1-device shard_map) and unsharded, mask and compact paths."""
    import jax
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            FilterPlan, FilterSession, ShardedAdaptiveFilter,
                            build_session, paper_filters_4)
    from repro.data.stream import gen_batch

    preds = paper_filters_4("fig1")
    ordering = _ordering(calculate_rate=3000)
    rows = 2048

    def legacy_pair(compact):
        cfg = AdaptiveFilterConfig(ordering=ordering, backend=backend,
                                   compact_output=compact)
        if sharded:
            mesh = jax.make_mesh((1,), ("data",))
            return ShardedAdaptiveFilter(preds, cfg, mesh=mesh)
        return AdaptiveFilter(preds, cfg)

    def session_for(compact):
        if sharded:
            return FilterSession.from_filter(legacy_pair(compact))
        return build_session(FilterPlan(
            predicates=preds, engine=backend, ordering=ordering,
            compact=compact))

    for compact in (False, True):
        legacy = legacy_pair(compact)
        sess = session_for(compact)
        lstate, sstate = legacy.init_state(), sess.init_state()
        for b in range(3):
            cols = jnp.asarray(gen_batch(0, b, b * rows, rows))
            if compact:
                lstate, lpacked, lkept, lmask, lmet = \
                    legacy._jit_compact(lstate, cols)
            else:
                lstate, lmask, lmet = legacy.jit_step(lstate, cols)
            sstate, res = sess.step(sstate, cols)
            np.testing.assert_array_equal(np.asarray(lmask), res.mask_np)
            np.testing.assert_array_equal(np.asarray(lmet.perm),
                                          np.asarray(res.metrics.perm))
            if compact:
                np.testing.assert_array_equal(np.asarray(lpacked),
                                              np.asarray(res.packed))
                np.testing.assert_array_equal(np.asarray(lkept),
                                              np.asarray(res.n_kept))
        for l, s in zip(jax.tree.leaves(lstate), jax.tree.leaves(sstate)):
            np.testing.assert_array_equal(np.asarray(l), np.asarray(s))


# =============================================================== StepResult
def test_step_result_uniform_abi():
    """One ABI across mask / compact / tokenize modes: n_pass, survivors,
    metrics_dict always answer; tokens only on tokenize plans."""
    from repro.core import FilterPlan, TokenizeSpec, build_session, \
        paper_filters_4
    from repro.data import tokenizer
    from repro.data.stream import gen_batch

    preds = paper_filters_4("fig1")
    cols = gen_batch(0, 0, 0, 2048)

    plain = build_session(FilterPlan(predicates=preds, ordering=_ordering()))
    st, res = plain.step(plain.init_state(), cols)
    want_rows = cols[:, res.mask_np]
    np.testing.assert_array_equal(res.survivors(cols), want_rows)
    assert res.packed is None and res.tokens is None
    assert res.n_pass == int(res.mask_np.sum())
    with pytest.raises(ValueError, match="columns"):
        res.survivors()
    with pytest.raises(ValueError, match="tokenize"):
        res.host_tokens()
    d = res.metrics_dict()
    assert set(d) >= {"work_units", "n_pass", "perm", "epoch", "n_dropped"}

    comp = build_session(FilterPlan(predicates=preds, ordering=_ordering(),
                                    compact=True))
    st, cres = comp.step(comp.init_state(), cols)
    np.testing.assert_array_equal(cres.survivors(), want_rows)

    tok = build_session(FilterPlan(predicates=preds, ordering=_ordering(),
                                   compact=True,
                                   tokenize=TokenizeSpec(1000, 4)))
    st, tres = tok.step(tok.init_state(), cols)
    want_toks = tokenizer.rows_to_tokens(want_rows, 1000, 4)
    np.testing.assert_array_equal(tres.host_tokens(), want_toks)
    # the packed buffer still answers on tokenize plans (rows stay packed)
    np.testing.assert_array_equal(tres.survivors(), want_rows)


def test_step_result_reports_dropped():
    from repro.core import FilterPlan, build_session, paper_filters_4
    from repro.data.stream import gen_batch

    sess = build_session(FilterPlan(predicates=paper_filters_4("fig1"),
                                    ordering=_ordering(), compact=True,
                                    capacity=8))
    _, res = sess.step(sess.init_state(), gen_batch(0, 0, 0, 2048))
    popcount = int(res.mask_np.sum())
    assert res.n_pass == 8
    assert res.n_dropped == popcount - 8 > 0
    assert res.metrics_dict()["n_dropped"] == popcount - 8


# ============================================================== deprecation
def test_shims_are_gone():
    """Deprecation end-of-life: the warn-once shims removed at their EOL
    must STAY removed (no resurrection in a refactor)."""
    from repro.core import AdaptiveFilter, ShardedAdaptiveFilter
    from repro.data import pipeline as pipeline_lib

    assert not hasattr(AdaptiveFilter, "step_compact")
    assert not hasattr(AdaptiveFilter, "jit_step_compact")
    assert not hasattr(ShardedAdaptiveFilter, "jit_step_compact")
    assert not hasattr(pipeline_lib, "make_sharded_pipeline")


def test_internal_callers_are_shim_free():
    """Acceptance grep: no internal caller (launch/, benchmarks/,
    examples/, data/) invokes the removed step_compact /
    jit_step_compact surfaces — everything routes through build_session."""
    root = os.path.join(os.path.dirname(__file__), "..")
    offenders = []
    for sub in ("src/repro/launch", "src/repro/data", "benchmarks",
                "examples"):
        for dirpath, _, files in os.walk(os.path.join(root, sub)):
            for f in files:
                if not f.endswith(".py"):
                    continue
                path = os.path.join(dirpath, f)
                text = open(path, encoding="utf-8").read()
                for needle in (".step_compact(", ".jit_step_compact("):
                    if needle in text:
                        offenders.append((path, needle))
    assert not offenders, offenders


# ============================================================== checkpoints
def _run_session(sess, n=4, rows=2048):
    from repro.data.stream import gen_batch
    st = sess.init_state()
    for b in range(n):
        st, _ = sess.step(st, gen_batch(0, b, b * rows, rows))
    return st


def test_v1_blob_loads_into_v2_session():
    """The raw ``fstate_to_arrays`` dicts every pre-session checkpoint
    holds restore verbatim (bit-identical)."""
    import jax

    from repro.core import FilterPlan, build_session, paper_filters_4
    from repro.data.pipeline import fstate_to_arrays

    sess = build_session(FilterPlan(predicates=paper_filters_4("fig1"),
                                    ordering=_ordering(calculate_rate=4000)))
    st = _run_session(sess)
    v1 = fstate_to_arrays(st)                     # unversioned legacy blob
    got = sess.restore_state(v1)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_v2_roundtrip_and_fingerprint_guard():
    import jax

    from repro.core import FilterPlan, build_session, paper_filters_4

    plan = FilterPlan(predicates=paper_filters_4("fig1"),
                      ordering=_ordering(calculate_rate=4000))
    sess = build_session(plan)
    st = _run_session(sess)
    blob = sess.save_state(st)
    assert blob["version"] == 2 and blob["fingerprint"] == plan.fingerprint()
    got = sess.restore_state(blob)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    other = build_session(FilterPlan(
        predicates=paper_filters_4("fig1"),
        ordering=_ordering(calculate_rate=999)))
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore_state(blob)
    with pytest.raises(ValueError, match="version"):
        sess.restore_state({"arrays": blob["arrays"], "version": 99})


def _stacked_arrays(n_shards, seed=0):
    """Synthetic stacked [S, ...] state arrays with per-shard stats."""
    rng = np.random.default_rng(seed)
    P = G = 4
    return {
        "perm": np.tile(np.arange(P, dtype=np.int32), (n_shards, 1)),
        "group_perm": np.tile(np.arange(G, dtype=np.int32), (n_shards, 1)),
        "adj_rank": np.tile(rng.random(G, np.float32) * 3, (n_shards, 1)),
        "rows_into_epoch": np.full((n_shards,), 1536, np.int32),
        "sample_phase": np.full((n_shards,), 36, np.int32),
        "epoch": np.full((n_shards,), 2, np.int32),
        "stats.num_cut": rng.random((n_shards, P), np.float32) * 100,
        "stats.cost_acc": rng.random((n_shards, P), np.float32) * 50,
        "stats.n_monitored": (rng.random(n_shards, np.float32) * 40 + 1),
        "stats.group_cut": rng.random((n_shards, G), np.float32) * 100,
    }


@pytest.mark.parametrize("s_old,s_new", [(2, 4), (4, 2), (2, 1), (1, 4)])
def test_reshard_sums_exact_power_of_two(s_old, s_new):
    """Partitioned (locally-accumulated) epoch stats are sums: the S→S′
    split/merge preserves the global totals EXACTLY for power-of-two
    rescales; the per-shard epoch PHASE (rows_into_epoch) and consensus
    perm/ranks survive verbatim when the source shards agree."""
    from repro.core.session import reshard_state_arrays

    arrays = _stacked_arrays(s_old)
    groups = (0, 1, 2, 3)
    out = reshard_state_arrays(arrays, s_new, groups=groups)
    for k in ("stats.num_cut", "stats.cost_acc", "stats.n_monitored",
              "stats.group_cut"):
        np.testing.assert_array_equal(
            out[k].sum(axis=0, dtype=np.float64)
            if out[k].ndim > arrays[k].ndim - 1 else out[k],
            arrays[k].sum(axis=0, dtype=np.float64).astype(np.float32),
            err_msg=k)
        assert out[k].dtype == arrays[k].dtype
    # non-sum leaves: broadcast consensus (shards agreed → verbatim)
    np.testing.assert_array_equal(np.atleast_2d(out["perm"])[0],
                                  arrays["perm"][0])
    np.testing.assert_array_equal(np.atleast_2d(out["adj_rank"])[0],
                                  arrays["adj_rank"][0])
    # boundary cadence: every new shard adopts the max source phase
    rows = np.atleast_1d(out["rows_into_epoch"])
    assert np.all(rows == arrays["rows_into_epoch"].max())
    if s_new:
        assert rows.shape[0] == s_new
    else:
        assert out["rows_into_epoch"].ndim == 0


def test_reshard_layouts_replicated_vs_partitioned():
    """Eager CENTRALIZED shards hold psum-merged GLOBAL accumulators
    (replicated): merging must take ONE copy, not the S× sum, and a
    replicated target must receive the whole value, not a split."""
    from repro.core.session import reshard_state_arrays

    groups = (0, 1, 2, 3)
    arrays = _stacked_arrays(2)
    for k in ("stats.num_cut", "stats.cost_acc", "stats.n_monitored",
              "stats.group_cut"):
        arrays[k] = np.broadcast_to(arrays[k][:1],
                                    arrays[k].shape).copy()  # replicated G

    # replicated 2-shard → replicated 4-shard: every shard keeps G
    out = reshard_state_arrays(arrays, 4, groups=groups,
                               src_replicated=True, tgt_replicated=True)
    for s in range(4):
        np.testing.assert_array_equal(out["stats.num_cut"][s],
                                      arrays["stats.num_cut"][0])

    # replicated (eager blob) → partitioned (deferred session), same S:
    # each shard gets G/S so the boundary psum recovers exactly G
    out = reshard_state_arrays(arrays, 2, groups=groups,
                               src_replicated=True, tgt_replicated=False)
    np.testing.assert_array_equal(
        out["stats.num_cut"].sum(axis=0), arrays["stats.num_cut"][0])

    # partitioned (deferred blob) → replicated (eager session): every
    # shard adopts the full merged total
    part = _stacked_arrays(2, seed=3)
    out = reshard_state_arrays(part, 2, groups=groups,
                               src_replicated=False, tgt_replicated=True)
    want = part["stats.num_cut"].astype(np.float64).sum(0).astype(np.float32)
    for s in range(2):
        np.testing.assert_array_equal(out["stats.num_cut"][s], want)


def test_v2_blob_records_stats_layout():
    from repro.core import FilterPlan, build_session, paper_filters_4

    sess = build_session(FilterPlan(predicates=paper_filters_4("fig1"),
                                    ordering=_ordering()))
    blob = sess.save_state(sess.init_state())
    assert blob["stats_layout"] == "partitioned"   # unsharded


def test_reshard_rederives_perm_when_shards_disagree():
    """PER_SHARD sources diverge; the reshard re-derives one consensus
    order from the merged stats with the same cnf_order math the epoch
    boundary uses."""
    from repro.core import stats as stats_lib
    from repro.core.session import reshard_state_arrays

    arrays = _stacked_arrays(2)
    arrays["perm"] = np.asarray([[0, 1, 2, 3], [3, 2, 1, 0]], np.int32)
    groups = (0, 1, 2, 3)
    out = reshard_state_arrays(arrays, 4, groups=groups)
    merged = stats_lib.FilterStats(
        num_cut=arrays["stats.num_cut"].astype(np.float64).sum(0)
        .astype(np.float32),
        cost_acc=arrays["stats.cost_acc"].astype(np.float64).sum(0)
        .astype(np.float32),
        n_monitored=arrays["stats.n_monitored"].astype(np.float64).sum()
        .astype(np.float32),
        group_cut=arrays["stats.group_cut"].astype(np.float64).sum(0)
        .astype(np.float32))
    want, _ = stats_lib.cnf_order(
        stats_lib.group_ranks(merged, groups, xp=np),
        stats_lib.member_ranks(merged, xp=np), groups, xp=np)
    assert len({tuple(p) for p in out["perm"]}) == 1
    np.testing.assert_array_equal(out["perm"][0], want)


def test_pipeline_checkpoint_carries_fingerprint():
    """The production pipeline/TrainDriver checkpoint path writes the
    versioned blob, so restoring into a semantically different plan is
    refused instead of silently loading stale adaptive state."""
    from repro.core import FilterPlan, build_session, paper_filters_4
    from repro.data.pipeline import Pipeline
    from repro.data.stream import LogStream

    def mk(calculate_rate):
        sess = build_session(FilterPlan(
            predicates=paper_filters_4("fig1"),
            ordering=_ordering(calculate_rate=calculate_rate)))
        return Pipeline(LogStream(total_rows=131072, batch_rows=16384),
                        sess, batch_size=2, seq_len=32, vocab_size=500)

    p1 = mk(100_000)
    next(iter(p1))
    st = p1.state()
    assert st.filter_state["fingerprint"]
    p_same = mk(100_000)
    p_same.restore(st)                       # same plan → loads
    with pytest.raises(ValueError, match="fingerprint"):
        mk(999).restore(st)                  # different ordering → refused


def test_unsharded_session_loads_sharded_blob():
    """A stacked checkpoint merges down to one executor (S→1 of the
    elastic path, no mesh needed)."""
    from repro.core import FilterPlan, build_session, paper_filters_4

    sess = build_session(FilterPlan(predicates=paper_filters_4("fig1"),
                                    ordering=_ordering()))
    arrays = _stacked_arrays(2)
    st = sess.restore_state(arrays)
    assert np.asarray(st.rows_into_epoch).ndim == 0
    np.testing.assert_array_equal(
        np.asarray(st.stats.num_cut),
        arrays["stats.num_cut"].astype(np.float64).sum(0).astype(np.float32))


# ===================================================== slow: live 2↔4 shards
_ELASTIC_PRELUDE = textwrap.dedent("""
    import jax, numpy as np
    from repro.core import FilterPlan, OrderingConfig, build_session, \\
        paper_filters_4
    from repro.data.stream import gen_batch

    ordering = OrderingConfig(collect_rate=10, calculate_rate=4000)
    preds = paper_filters_4("fig1")
    R = 1024

    def sess(shards):
        return build_session(FilterPlan(
            predicates=preds, ordering=ordering, scope="centralized",
            exchange="deferred", shards=shards))

    def feed(s, st, steps, rows_total):
        for b in range(steps):
            st, _ = s.step(st, gen_batch(0, b, b * rows_total, rows_total))
        return st
""")


@pytest.mark.slow
@pytest.mark.parametrize("s_old,s_new", [(2, 4), (4, 2)])
def test_elastic_restore_rederives_same_perm(s_old, s_new):
    """Acceptance pin: a 2-shard checkpoint restores onto a 4-shard mesh
    (and back); the global stat sums are preserved exactly, and firing the
    boundary exchange on the restored state adopts the IDENTICAL
    permutation the unresharded run adopts (sums are associative)."""
    out = run_py(_ELASTIC_PRELUDE + textwrap.dedent(f"""
        s_old, s_new = {s_old}, {s_new}
        a = sess(s_old)
        # cross one epoch boundary (nontrivial perm), then accumulate a
        # partial epoch of per-shard-divergent deferred evidence
        st = feed(a, a.init_state(), 6, R * s_old)
        assert int(np.asarray(st.epoch).max()) >= 1
        assert float(np.asarray(st.stats.n_monitored).sum()) > 0
        blob = a.save_state(st)

        b = sess(s_new)
        st2 = b.restore_state(blob)
        # perm carried over verbatim (centralized shards agree)
        assert np.asarray(st2.perm).shape[0] == s_new
        for row in np.asarray(st2.perm):
            assert np.array_equal(row, np.asarray(st.perm)[0]), (row,)
        # merged accumulators exactly preserved
        for k in ("num_cut", "cost_acc", "n_monitored", "group_cut"):
            got = np.asarray(getattr(st2.stats, k)).sum(axis=0)
            want = np.asarray(getattr(st.stats, k)).sum(axis=0)
            assert np.array_equal(got, want), (k, got, want)
        # the boundary exchange re-derives the SAME permutation on both
        # meshes — the machine-checkable "sums are associative" claim
        na, _ = a.filter.jit_exchange(st)
        nb, _ = b.filter.jit_exchange(st2)
        pa, pb = np.asarray(na.perm), np.asarray(nb.perm)
        assert len({{tuple(p) for p in pa}} | {{tuple(p) for p in pb}}) == 1, \\
            (pa, pb)
        ra, rb = np.asarray(na.adj_rank), np.asarray(nb.adj_rank)
        assert np.array_equal(ra[0], rb[0]), (ra, rb)
        print("ELASTIC-OK")
    """))
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_sharded_device_tokenize_4dev_matches_host():
    """4-shard tokenize plans run the hash+pack PER SHARD under shard_map
    (a global pack over the shard-sharded buffer hangs the SPMD
    partitioner — the pre-session code path was never drivable on a real
    mesh) and the shard-major token stream is bit-identical to the host
    tokenizer."""
    out = run_py("""
        import numpy as np
        from repro.core import FilterPlan, OrderingConfig, TokenizeSpec, \\
            build_session, paper_filters_4
        from repro.data import tokenizer
        from repro.data.stream import gen_batch

        plan = FilterPlan(
            predicates=paper_filters_4("fig1"),
            ordering=OrderingConfig(collect_rate=100, calculate_rate=50_000),
            scope="centralized", shards=4, compact=True,
            tokenize=TokenizeSpec(1000, 4))
        sess = build_session(plan)
        st = sess.init_state()
        R = 8192
        for b in range(2):
            cols = gen_batch(0, b, b * 4 * R, 4 * R)
            st, res = sess.step(st, cols)
            toks = res.host_tokens()
            want = tokenizer.rows_to_tokens(res.survivors(), 1000, 4)
            assert np.array_equal(toks, want), (toks.shape, want.shape)
        print("TOK-4DEV-OK")
    """)
    assert "TOK-4DEV-OK" in out


@pytest.mark.slow
def test_sharded_pipeline_elastic_restore_2_to_4():
    """ROADMAP closure: a 2-shard ShardedPipeline checkpoint restores onto
    a 4-shard pipeline (filter state resharded, streams resumed at the next
    unconsumed global batch) and keeps emitting LM batches."""
    out = run_py("""
        import numpy as np
        from repro.core import FilterPlan, OrderingConfig, build_session, \\
            paper_filters_4
        from repro.data.pipeline import make_pipeline

        ordering = OrderingConfig(collect_rate=100, calculate_rate=50_000)

        def mk(shards):
            session = build_session(FilterPlan(
                predicates=paper_filters_4("fig1"), ordering=ordering,
                scope="centralized", shards=shards, compact=True))
            return make_pipeline(session, total_rows=2_097_152,
                                 batch_rows=65536, batch_size=4, seq_len=64,
                                 vocab_size=1000)

        p2 = mk(2)
        it = iter(p2)
        head = [next(it) for _ in range(3)]
        ckpt = p2.state()

        p4 = mk(4)
        p4.restore(ckpt)
        assert np.asarray(p4._fstate.perm).shape[0] == 4
        # stream cursors: every new partition resumes at the next
        # unconsumed global batch index
        assert all(s.cursor == max(ckpt.stream_cursors)
                   for s in p4.streams)
        assert p4.rows_in == p2.rows_in and p4.rows_pass == p2.rows_pass
        got = [b for _, b in zip(range(3), iter(p4))]
        assert len(got) == 3
        for b in got:
            assert b["tokens"].shape == (4, 64)
        print("PIPE-ELASTIC-OK")
    """)
    assert "PIPE-ELASTIC-OK" in out
