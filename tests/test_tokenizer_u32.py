"""u32-limb device tokenizer: bit-exact vs the u64 host hash, no x64.

The device path re-expresses the splitmix64 hash — u64 add/xor/shift/mul
and the f32→f64 widening it is defined on — as u32 limb arithmetic, so it
traces without ``jax.experimental.enable_x64`` (TPU-lowerable). These pins
hold the contract: every limb primitive matches numpy's u64 math exactly,
including IEEE edge cases (±0, subnormals, inf, NaN).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.data import tokenizer  # noqa: E402


def _to_u64(hi, lo) -> np.ndarray:
    return (np.asarray(hi, np.uint64) << np.uint64(32)) \
        | np.asarray(lo, np.uint64)


def _limbs(x: np.ndarray):
    x = np.asarray(x, np.uint64)
    return (jnp.asarray((x >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((x & np.uint64(0xFFFFFFFF)).astype(np.uint32)))


def test_splitmix_limbs_match_u64():
    splitmix64, _, _ = tokenizer._limb_ops()
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2 ** 64, 20_000, dtype=np.uint64)
    x[:4] = [0, 1, 2 ** 63, 2 ** 64 - 1]
    h, l = jax.jit(splitmix64)(*_limbs(x))
    np.testing.assert_array_equal(_to_u64(h, l), tokenizer._splitmix(x))


def test_f32_to_f64_bits_exact_including_edge_cases():
    _, f64_bits, _ = tokenizer._limb_ops()
    edge = np.array([0.0, -0.0, 1.0, -1.0, 0.5, 2.0, 3.14159, 65504.0,
                     np.inf, -np.inf, np.nan,
                     np.float32(2 ** -149),          # smallest subnormal
                     -np.float32(2 ** -149),
                     np.float32(1.1754942e-38),      # largest subnormal
                     np.float32(2 ** -126),          # smallest normal
                     np.float32(3.4028235e38)],      # largest normal
                    np.float32)
    # signaling NaNs: hardware f32→f64 conversion QUIETS them (sets the
    # quiet bit) — the limb path must match that, payload preserved
    edge = np.concatenate([edge, np.array(
        [0x7F800001, 0xFF800001, 0x7FBFFFFF, 0x7FC00001],
        np.uint32).view(np.float32)])
    rng = np.random.default_rng(1)
    vals = np.concatenate([
        edge,
        rng.normal(0, 1e3, 20_000).astype(np.float32),
        rng.uniform(-1e-40, 1e-40, 5_000).astype(np.float32),  # subnormals
        rng.uniform(-1e-30, 1e30, 5_000).astype(np.float32)])
    hi, lo = jax.jit(f64_bits)(jnp.asarray(vals))
    np.testing.assert_array_equal(
        _to_u64(hi, lo), vals.astype(np.float64).view(np.uint64))


@pytest.mark.parametrize("vocab", [2, 7, 1000, 50_257, 151_936,
                                   (1 << 24) - 1])
def test_mod_u64_byte_fold(vocab):
    _, _, mod_u64 = tokenizer._limb_ops()
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2 ** 64, 10_000, dtype=np.uint64)
    got = jax.jit(lambda h, l: mod_u64(h, l, vocab))(*_limbs(x))
    np.testing.assert_array_equal(np.asarray(got, np.uint64),
                                  x % np.uint64(vocab))


def test_tokens_from_padded_traces_without_x64():
    """The whole device tokenizer runs with x64 DISABLED and matches the
    host stream bit-for-bit (zero-padding, multi-shard, odd counts)."""
    assert not jax.config.jax_enable_x64
    rng = np.random.default_rng(3)
    packed = rng.normal(0, 100, (3, 4, 128)).astype(np.float32)
    packed[0, :, 100:] = 0.0            # padding slots hash-then-masked
    counts = np.asarray([100, 0, 127], np.int32)
    toks, n = tokenizer.tokens_from_padded(
        jnp.asarray(packed), jnp.asarray(counts), 5000, 8)
    assert int(n) == (100 + 0 + 127) * 8
    host = np.concatenate([packed[s][:, :int(counts[s])]
                           for s in range(3)], axis=1)
    np.testing.assert_array_equal(
        np.asarray(toks)[:int(n)],
        tokenizer.rows_to_tokens(host, 5000, 8))


def test_tokens_from_padded_rejects_giant_vocab():
    with pytest.raises(ValueError, match="vocab_size"):
        tokenizer.tokens_from_padded(
            jnp.zeros((1, 2, 8), jnp.float32), jnp.zeros((1,), jnp.int32),
            1 << 24)
