"""Behavioural tests of the adaptive controller: convergence to the oracle
order, drift tracking, scope policies, executor-sim lock semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig,
                        paper_filters_4, static_filter)
from repro.core import executor_sim, predicates as P, stats as S
from repro.core.predicates import Predicate
from repro.data.stream import DriftConfig, LogStream, gen_batch


def drive(filt, n_batches=10, batch_rows=65536, drift=DriftConfig(),
          seed=0):
    state = filt.init_state()
    step = jax.jit(filt.step)
    work = 0.0
    for b in range(n_batches):
        cols = jnp.asarray(gen_batch(seed, b, b * batch_rows, batch_rows,
                                     drift))
        state, mask, metrics = step(state, cols)
        work += float(metrics.work_units)
    return state, work


def test_converges_to_oracle_order_stationary():
    preds = paper_filters_4("fig1")
    cfg = AdaptiveFilterConfig(ordering=OrderingConfig(
        collect_rate=500, calculate_rate=120_000, momentum=0.3))
    filt = AdaptiveFilter(preds, cfg)
    state, _ = drive(filt, n_batches=12)
    # oracle: measure true pass fractions, compute rank order
    cols = jnp.asarray(gen_batch(0, 99, 0, 200_000))
    outcomes = np.asarray(P.eval_all(filt.specs, cols))
    s = outcomes.mean(axis=1)
    c = np.asarray([p.static_cost for p in preds])
    oracle = np.argsort((c / c.max()) / (1 - s), kind="stable")
    assert int(state.epoch) >= 3
    # near-tied ranks may swap under sampling noise — require the adaptive
    # order's EXPECTED COST to match the oracle's (the paper's objective)
    def expected(perm):
        surv = np.concatenate([[1.0], np.cumprod(s[perm])[:-1]])
        return float(np.sum(c[perm] * surv))
    got = expected(np.asarray(state.perm))
    assert got <= expected(oracle) * 1.03, \
        (np.asarray(state.perm).tolist(), oracle.tolist())


def test_adaptive_beats_static_under_drift():
    """Regime drift flips which int predicate cuts more; the adaptive chain
    must do less row-level work than the user (identity) static order."""
    preds = paper_filters_4("fig1")
    drift = DriftConfig(kind="regime", period_rows=400_000, amplitude=1.8)
    ordering = OrderingConfig(collect_rate=500, calculate_rate=100_000,
                              momentum=0.3)
    filt = AdaptiveFilter(preds, AdaptiveFilterConfig(ordering=ordering))
    _, adaptive_work = drive(filt, n_batches=16, drift=drift)

    # worst static order: expensive string predicate first
    bad = static_filter(preds, order=[3, 2, 1, 0])
    _, bad_work = drive(bad, n_batches=16, drift=drift)
    assert adaptive_work < 0.6 * bad_work


def test_per_batch_scope_forgets():
    """Per-task scope: the *evidence* dies with each batch — every re-rank
    sees one batch of accumulators and the momentum memory is zeroed — but
    the stream-level counters persist: epoch counts every re-rank and the
    monitor stride keeps walking (tests/test_sharded_filter.py pins the
    stride; resetting it would resample the same row offsets every batch)."""
    preds = paper_filters_4("fig1")
    cfg = AdaptiveFilterConfig(
        scope="per_batch",
        ordering=OrderingConfig(collect_rate=500, calculate_rate=60_000,
                                momentum=0.3))
    filt = AdaptiveFilter(preds, cfg)
    state, _ = drive(filt, n_batches=4)
    # 65536-row batches ≥ calculate_rate: one re-rank per batch, counted
    # cumulatively across resets
    assert int(state.epoch) == 4
    # the last re-rank consumed exactly one batch of evidence and reset the
    # accumulators — nothing carried over
    assert float(state.stats.n_monitored) == 0.0
    assert int(state.rows_into_epoch) <= 65536
    # stride walked the whole stream, not one batch
    assert int(state.sample_phase) == (4 * 65536) % 500


def test_executor_sim_lock_and_deferral():
    preds = paper_filters_4("fig1")
    parts = [gen_batch(0, b, b * 32768, 32768) for b in range(24)]
    cfg = OrderingConfig(collect_rate=500, calculate_rate=100_000,
                         momentum=0.3)
    res = executor_sim.run_executor(preds, parts, cfg, n_tasks=4,
                                    cost_mode="static")
    assert res.rows_processed == 24 * 32768
    assert res.epochs >= 1
    # with 4 tasks racing, SOME epochs defer, and deferred metrics are kept
    # (deferral count is timing-dependent; assert non-crash + sane history)
    assert all(sorted(p) == [0, 1, 2, 3] for p in res.perm_history)


def test_executor_sim_matches_functional_outcome():
    """The sim and the functional path must agree on filter OUTPUT rows."""
    preds = paper_filters_4("fig1")
    parts = [gen_batch(0, b, b * 32768, 32768) for b in range(4)]
    res = executor_sim.run_executor(preds, parts, OrderingConfig(),
                                    n_tasks=1, adaptive=False)
    outcomes = [np.asarray(P.eval_all(P.pack(preds), jnp.asarray(p)))
                for p in parts]
    want = sum(int(o.all(axis=0).sum()) for o in outcomes)
    assert res.rows_passed == want
