"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(contract §MULTI-POD 0); multi-device tests run in subprocesses."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
