"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(contract §MULTI-POD 0); multi-device tests run in subprocesses."""

import importlib.util
import os
import sys

import numpy as np
import pytest

# make ``repro`` importable for a plain ``pytest`` invocation when the
# package is not pip-installed (no PYTHONPATH=src needed)
if importlib.util.find_spec("repro") is None:
    sys.path.insert(0, os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
