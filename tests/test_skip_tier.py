"""Tile-statistics skip tier: zone maps + Bloom bits in front of the chain.

Fast tier. The tier's one invariant — survivors, tokens, and ordering
statistics are BIT-IDENTICAL with the tier on or off, on every engine —
plus the tri-state proof edge cases (all-pass / all-fail / boundary-value
tiles), the monitor lane's immunity to skipping, the ``auto`` tuner's
structural fallback to "off" on shuffled layouts, and the layout
generator's row-set invariance.
"""

import math

import numpy as np
import pytest

from repro.core.ordering import OrderingConfig


def _ordering(collect_rate=100, calculate_rate=4096):
    return OrderingConfig(collect_rate=collect_rate,
                          calculate_rate=calculate_rate)


# ===================================================== engine-level parity
@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
@pytest.mark.parametrize("layout", ["clustered", "zordered", "shuffled"])
@pytest.mark.parametrize("bloom", [False, True])
def test_skip_mask_bit_identical(engine, layout, bloom):
    """run_chain_skip == run_chain on mask AND monitor counters, for every
    engine × layout × bloom — aligned and ragged widths."""
    import jax.numpy as jnp

    from repro.core import paper_filters_4
    from repro.core import skip_tier
    from repro.core.engine import get_engine
    from repro.core.engine.base import MonitorSpec
    from repro.core.predicates import pack
    from repro.data.stream import gen_batch

    specs = pack(paper_filters_4("fig1"))
    perm = np.arange(specs.n, dtype=np.int32)
    mon = MonitorSpec(collect_rate=100, sample_phase=0)
    eng = get_engine(engine)

    for rows in (4096, 4000):            # aligned + ragged tail
        cols = gen_batch(0, 0, 0, rows, layout=layout)
        c = cols if engine == "numpy" else jnp.asarray(cols)
        base = eng.run_chain(c, specs, perm, mon)
        info = eng.triage(c, specs, bloom=bloom)
        if engine == "jnp":
            cap = skip_tier.quantize_amb_cap(int(info.n_ambiguous),
                                             math.ceil(rows / 128))
            res = eng.run_chain_skip(c, specs, perm, mon, info, amb_cap=cap)
        else:
            res = eng.run_chain_skip(c, specs, perm, mon, info)
        np.testing.assert_array_equal(np.asarray(base.mask),
                                      np.asarray(res.mask))
        for field in ("cut_counts", "group_cut_counts", "n_monitored",
                      "monitor_cost"):
            np.testing.assert_allclose(np.asarray(getattr(base, field)),
                                       np.asarray(getattr(res, field)))
        # triage must have decided something on clustered data (aligned
        # widths only — ragged-tail tile counts may differ per engine)
        if layout in ("clustered", "zordered") and rows == 4096:
            assert int(np.asarray(res.n_tiles_fail)) > 0
        n_amb = int(np.asarray(res.n_tiles_ambiguous))
        assert n_amb >= 1                # hashmix is never provable... but
        # decided tiles contribute zero row-level work
        assert float(np.asarray(res.work_units)) \
            <= float(np.asarray(base.work_units)) + 1e-6


def test_skip_counters_agree_across_engines():
    """Same batch → identical (pass, fail, ambiguous) tile counts from the
    numpy reference, the jnp triage, and the pallas stats kernel."""
    import jax.numpy as jnp

    from repro.core import paper_filters_4
    from repro.core.engine import get_engine
    from repro.core.predicates import pack
    from repro.data.stream import gen_batch

    specs = pack(paper_filters_4("fig1"))
    cols = gen_batch(0, 0, 0, 4096, layout="clustered")
    outs = []
    for engine in ("numpy", "jnp", "pallas"):
        c = cols if engine == "numpy" else jnp.asarray(cols)
        info = get_engine(engine).triage(c, specs, bloom=True)
        outs.append((int(np.sum(np.asarray(info.pass_tiles))),
                     int(np.sum(np.asarray(info.fail_tiles))),
                     int(np.asarray(info.n_ambiguous))))
    assert outs[0] == outs[1] == outs[2]
    assert outs[0][1] > 0                 # clustered data resolves tiles


# ============================================== tri-state proof edge cases
def _triage_np(cols, preds, *, bloom=False):
    import numpy as np

    from repro.core import skip_tier
    from repro.core.predicates import pack
    return skip_tier.triage(np.asarray(cols, np.float32), pack(preds),
                            bloom=bloom, xp=np)


def test_all_pass_all_fail_boundary_tiles():
    """Hand-built 128-row tiles: provably-pass, provably-fail, and
    boundary-value (threshold sitting exactly on the tile extremum) tiles
    classify exactly as the tri-state table says."""
    from repro.core.predicates import OP_GT, OP_LT, Predicate

    t = 0.5
    preds = [Predicate("gt", column=0, op=OP_GT, t1=t)]
    tiles = np.concatenate([
        np.full(128, 1.0),      # all > t        → provably pass
        np.full(128, 0.0),      # all <= t       → provably fail
        np.full(128, t),        # max == t: x > t false everywhere → fail
        np.linspace(0.0, 1.0, 128),   # straddles → ambiguous
        np.full(128, np.nextafter(np.float32(t), np.float32(1.0))),
        # ^ min one f32 ulp above t → pass
    ])
    info = _triage_np(np.stack([tiles]), preds)
    assert list(np.asarray(info.pass_tiles)) == [True, False, False, False,
                                                 True]
    assert list(np.asarray(info.fail_tiles)) == [False, True, True, False,
                                                 False]

    # LT flips the boundary: a tile pinned AT the threshold fails (x < t
    # false), a tile just below passes
    preds = [Predicate("lt", column=0, op=OP_LT, t1=t)]
    info = _triage_np(np.stack([tiles]), preds)
    assert list(np.asarray(info.pass_tiles)) == [False, True, False, False,
                                                 False]
    assert list(np.asarray(info.fail_tiles)) == [True, False, True, False,
                                                 True]


def test_between_and_eq_tiles():
    from repro.core.predicates import OP_BETWEEN, OP_EQ, Predicate

    preds = [Predicate("bt", column=0, op=OP_BETWEEN, t1=1.0, t2=2.0)]
    tiles = np.concatenate([
        np.full(128, 1.5),                  # inside (1,2)   → pass
        np.full(128, 0.5),                  # below          → fail
        np.full(128, 2.0),                  # min == t2      → fail
        np.linspace(0.5, 1.5, 128),         # straddles t1   → ambiguous
    ])
    info = _triage_np(np.stack([tiles]), preds)
    assert list(np.asarray(info.pass_tiles)) == [True, False, False, False]
    assert list(np.asarray(info.fail_tiles)) == [False, True, True, False]

    # EQ (round-to-nearest equality): a constant tile at the value passes,
    # a tile whose rounded range excludes it fails, zone maps alone leave
    # a covering range ambiguous — and Bloom bits then prove the miss
    preds = [Predicate("eq", column=0, op=OP_EQ, t1=7.0)]
    tiles = np.concatenate([
        np.full(128, 7.2),                  # rounds to 7    → pass
        np.full(128, 9.0),                  # range excludes → fail
        np.linspace(0.0, 20.0, 128),        # covers 7       → ambiguous
        # range covers 7 but no value ROUNDS to 7 (even values only):
        np.repeat([2.0, 4.0, 6.0, 8.0], 32),
    ])
    info = _triage_np(np.stack([tiles]), preds)
    assert list(np.asarray(info.pass_tiles)) == [True, False, False, False]
    assert list(np.asarray(info.fail_tiles)) == [False, True, False, False]
    info = _triage_np(np.stack([tiles]), preds, bloom=True)
    # Bloom turns the no-value-rounds-to-7 tile into a provable fail
    assert list(np.asarray(info.fail_tiles)) == [False, True, False, True]


def test_hashmix_never_provable():
    from repro.core.predicates import OP_HASHMIX, Predicate

    preds = [Predicate("mix", column=0, op=OP_HASHMIX, t1=0.5, rounds=4)]
    tiles = np.concatenate([np.full(128, 1.0), np.zeros(128)])
    info = _triage_np(np.stack([tiles]), preds, bloom=True)
    assert not np.asarray(info.pass_tiles).any()
    assert not np.asarray(info.fail_tiles).any()


def test_cnf_group_proofs():
    """OR-group: the group passes a tile iff ANY member provably passes,
    fails iff EVERY member provably fails."""
    from repro.core.predicates import OP_GT, OP_LT, Predicate

    preds = [Predicate("a", column=0, op=OP_GT, t1=0.5, group="or"),
             Predicate("b", column=1, op=OP_LT, t1=0.5, group="or")]
    col0 = np.concatenate([
        np.full(128, 1.0),   # a passes       → group passes
        np.full(128, 0.0),   # a fails...
        np.full(128, 0.0),   # a fails...
    ])
    col1 = np.concatenate([
        np.full(128, 1.0),   # (b fails — irrelevant, a already passed)
        np.full(128, 0.0),   # ...but b passes → group passes
        np.full(128, 1.0),   # ...and b fails  → group fails
    ])
    info = _triage_np(np.stack([col0, col1]), preds)
    assert list(np.asarray(info.pass_tiles)) == [True, True, False]
    assert list(np.asarray(info.fail_tiles)) == [False, False, True]


# ================================================= session-level invariance
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
@pytest.mark.parametrize("compact", [False, True])
def test_session_skip_bit_identical(engine, compact):
    """session.step with skip_tier=zonemap: masks, survivors, monitor
    statistics, and the ADOPTED PERMUTATION all bit-identical to off,
    across epoch boundaries."""
    from repro.core import FilterPlan, build_session, paper_filters_4

    preds = paper_filters_4("fig1")
    rows = 2048

    def run(tier):
        sess = build_session(FilterPlan(
            predicates=preds, ordering=_ordering(calculate_rate=4096),
            engine=engine, compact=compact, skip_tier=tier))
        st = sess.init_state()
        out = []
        for b in range(4):
            from repro.data.stream import gen_batch
            cols = gen_batch(0, b, b * rows, rows, layout="clustered")
            st, res = sess.step(st, cols)
            # NOT work_units: decided tiles charging zero row-level work is
            # the tier's point — the ORDERING inputs (ranks from the
            # monitor lane) and outputs (perm) must match, not the work
            out.append((res.mask_np.copy(), np.asarray(res.metrics.perm),
                        np.asarray(res.metrics.adj_rank),
                        np.asarray(res.metrics.epoch),
                        np.asarray(res.metrics.n_pass),
                        None if not compact else np.asarray(res.packed)))
        return out, res

    off, _ = run("off")
    on, last = run("zonemap")
    for a, b in zip(off, on):
        for x, y in zip(a, b):
            if x is not None:
                np.testing.assert_array_equal(x, y)
    # the tier genuinely engaged (counters surfaced through StepResult)
    assert last.n_tiles_skipped_fail > 0
    assert "n_tiles_skipped_fail" in last.metrics_dict()


def test_session_skip_counters_off_are_zero():
    from repro.core import FilterPlan, build_session, paper_filters_4
    from repro.data.stream import gen_batch

    sess = build_session(FilterPlan(predicates=paper_filters_4("fig1"),
                                    ordering=_ordering()))
    _, res = sess.step(sess.init_state(),
                       gen_batch(0, 0, 0, 2048, layout="clustered"))
    assert res.n_tiles_skipped_pass == res.n_tiles_skipped_fail \
        == res.n_tiles_ambiguous == 0


def test_host_stream_skip_bit_identical():
    """numpy engine: process_stream with the tier on == off, row-exact."""
    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            paper_filters_4)
    from repro.data.stream import gen_batch

    preds = paper_filters_4("fig1")
    batches = [gen_batch(0, b, b * 2048, 2048, layout="clustered")
               for b in range(3)]

    def run(tier):
        filt = AdaptiveFilter(preds, AdaptiveFilterConfig(
            backend="numpy", ordering=_ordering(), skip_tier=tier))
        return list(filt.process_stream(batches))

    for (sa, ma, mta), (sb, mb, mtb) in zip(run("off"), run("zonemap")):
        np.testing.assert_array_equal(ma, mb)
        np.testing.assert_array_equal(sa, sb)
        assert mta["perm"] == mtb["perm"]
    assert mtb["n_tiles_skipped_fail"] > 0


# ========================================================== plan validation
def test_skip_tier_plan_rules():
    from repro.core import FilterPlan, paper_filters_4

    preds = paper_filters_4("fig1")
    with pytest.raises(ValueError, match="skip_tier"):
        FilterPlan(predicates=preds, skip_tier="zonemaps")
    with pytest.raises(ValueError, match="shards"):
        FilterPlan(predicates=preds, shards=2, skip_tier="zonemap")
    with pytest.raises(ValueError, match="auto"):
        FilterPlan(predicates=preds, engine="numpy", skip_tier="auto")
    # fingerprint is an execution-detail-free identity: checkpoints move
    # between tiered and untiered sessions
    assert FilterPlan(predicates=preds, skip_tier="zonemap").fingerprint() \
        == FilterPlan(predicates=preds).fingerprint()


# ================================================================ auto mode
def test_auto_falls_back_to_off_on_shuffled():
    """Shuffled layout: every tile stays ambiguous, the structural override
    turns the tier off (deterministic — no timing involved)."""
    from repro.core import FilterPlan, build_session, paper_filters_4
    from repro.data.stream import gen_batch

    sess = build_session(FilterPlan(
        predicates=paper_filters_4("fig1"),
        ordering=_ordering(calculate_rate=100_000), skip_tier="auto"))
    st = sess.init_state()
    for b in range(8):                   # past the 2·warmup alternation
        st, res = sess.step(
            st, gen_batch(0, b, b * 2048, 2048, layout="shuffled"))
    assert sess.skip_tier_active == "off"
    # and the off arm genuinely runs: no tiles decided
    assert res.n_tiles_skipped_pass == res.n_tiles_skipped_fail == 0


def test_tuner_schedule_and_structural_override():
    from repro.core.skip_tier import SkipTierTuner

    t = SkipTierTuner("zonemap", warmup=2, probe_period=8)
    # warmup: alternates on/off
    arms = []
    for _ in range(4):
        m = t.choose()
        arms.append(m)
        t.observe(m, 1.0)
    assert arms == ["zonemap", "off", "zonemap", "off"]

    # tier measured faster → stays on
    for _ in range(4):
        t.observe("zonemap", 1.0)
        t.observe("off", 3.0)
    assert t.active_mode == "zonemap"

    # structural override beats the clocks, and the probe never re-arms it
    t.observe("zonemap", 1.0, ambig_frac=0.95)
    assert t.active_mode == "off"
    t.step_idx = t.probe_period          # a probe step
    assert t.choose() == "off"

    # ambiguity clearing re-enables the faster arm
    t.observe("off", 3.0, ambig_frac=0.1)
    assert t.active_mode == "zonemap"


def test_tuner_discards_first_sample_per_arm():
    from repro.core.skip_tier import SkipTierTuner

    t = SkipTierTuner("zonemap", warmup=1)
    t.observe("zonemap", 1000.0)         # compile-tainted → discarded
    t.observe("off", 1000.0)
    assert t.us_ema["zonemap"] is None and t.us_ema["off"] is None
    t.observe("zonemap", 1.0)
    t.observe("off", 2.0)
    assert t.us_ema["zonemap"] == 1.0 and t.us_ema["off"] == 2.0


def test_quantize_amb_cap():
    from repro.core.skip_tier import AMBIG_QUANTUM_TILES, quantize_amb_cap

    q = AMBIG_QUANTUM_TILES
    # floor of one quantum even with nothing ambiguous: no zero-width
    # gather special case, and the jit cache stays bounded
    assert quantize_amb_cap(0, 32) == q
    assert quantize_amb_cap(1, 32) == q
    assert quantize_amb_cap(q, 32) == q
    assert quantize_amb_cap(q + 1, 32) == 2 * q
    assert quantize_amb_cap(100, 32) == 32      # capped at the batch


# ================================================================= layouts
def test_layouts_are_row_permutations():
    """Every layout yields the SAME row multiset — only the order moves —
    and gen_batch stays counter-restartable per layout."""
    from repro.data.stream import LAYOUTS, gen_batch

    base = gen_batch(0, 3, 3 * 2048, 2048)
    for layout in LAYOUTS:
        cols = gen_batch(0, 3, 3 * 2048, 2048, layout=layout)
        assert cols.shape == base.shape
        np.testing.assert_array_equal(np.sort(cols, axis=1),
                                      np.sort(base, axis=1))
        again = gen_batch(0, 3, 3 * 2048, 2048, layout=layout)
        np.testing.assert_array_equal(cols, again)     # restartable
    # iid IS the pre-layout stream, bit-identical
    np.testing.assert_array_equal(
        gen_batch(0, 3, 3 * 2048, 2048, layout="iid"), base)


def test_clustered_layout_resolves_more_tiles():
    from repro.core import paper_filters_4
    from repro.core import skip_tier
    from repro.core.predicates import pack
    from repro.data.stream import gen_batch

    specs = pack(paper_filters_4("fig1"))

    def decided(layout):
        info = skip_tier.triage(
            gen_batch(0, 0, 0, 8192, layout=layout), specs, bloom=False,
            xp=np)
        return int(np.sum(np.asarray(info.pass_tiles))
                   + np.sum(np.asarray(info.fail_tiles)))

    assert decided("clustered") > decided("shuffled")
    assert decided("zordered") > decided("shuffled")
    assert decided("clustered") >= 8192 // 128 // 2   # most tiles resolve
