"""Pallas kernel validation: sweep shapes/dtypes/params and assert exact
agreement with the pure-jnp oracle (ref.py) and the lazy jnp path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicates as P, pack
from repro.core.filter_exec import run_chain, compact
from repro.core.predicates import Predicate
from repro.kernels.filter_chain.ops import filter_chain
from repro.kernels.filter_chain.ref import filter_chain_ref


def chain(n_preds):
    base = [
        Predicate("gt", 0, P.OP_GT, 0.2, static_cost=1.0),
        Predicate("lt", 1, P.OP_LT, 0.7, static_cost=1.3),
        Predicate("bet", 0, P.OP_BETWEEN, 0.1, t2=0.9, static_cost=2.0),
        Predicate("eq", 2, P.OP_EQ, 3.0, static_cost=0.7),
        Predicate("mix", 3, P.OP_HASHMIX, 0.45 * P.MIX_MOD, rounds=6,
                  static_cost=6.0),
        Predicate("gt2", 1, P.OP_GT, 0.05, static_cost=0.9),
    ]
    return base[:n_preds]


def cols_for(n_rows, seed=0):
    r = np.random.default_rng(seed)
    return np.stack([
        r.uniform(0, 1, n_rows),
        r.uniform(0, 1, n_rows),
        r.integers(0, 8, n_rows).astype(np.float64),
        r.uniform(0, P.MIX_MOD, n_rows),
    ]).astype(np.float32)


@pytest.mark.parametrize("n_rows", [64, 1000, 2048, 4096, 5000, 10_000])
@pytest.mark.parametrize("n_preds", [1, 3, 6])
def test_kernel_matches_oracle_shapes(n_rows, n_preds):
    specs = pack(chain(n_preds))
    cols = jnp.asarray(cols_for(n_rows))
    perm = jnp.asarray(np.random.default_rng(n_preds).permutation(n_preds),
                       jnp.int32)
    got = filter_chain(cols, specs, perm, collect_rate=37, sample_phase=5)
    ref = filter_chain_ref(cols, specs, perm, collect_rate=37, sample_phase=5)
    lazy = run_chain(cols, specs, perm, collect_rate=37, sample_phase=5)
    for name in got._fields:
        # boolean/count fields exact; f32 accumulators up to summation order
        kw = {} if name in ("mask", "cut_counts", "n_monitored") \
            else {"rtol": 1e-6}
        cmp = np.testing.assert_array_equal if not kw \
            else np.testing.assert_allclose
        cmp(np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            err_msg=f"kernel vs oracle mismatch in {name}", **kw)
        cmp(np.asarray(getattr(lazy, name)), np.asarray(getattr(ref, name)),
            err_msg=f"jnp-lazy vs oracle mismatch in {name}", **kw)


@pytest.mark.parametrize("tile", [256, 1024, 2048])
def test_kernel_tile_size_invariance(tile):
    specs = pack(chain(4))
    cols = jnp.asarray(cols_for(4096, seed=2))
    perm = jnp.asarray([3, 1, 0, 2], jnp.int32)
    got = filter_chain(cols, specs, perm, collect_rate=100, sample_phase=0,
                       tile=tile)
    ref = filter_chain_ref(cols, specs, perm, collect_rate=100,
                           sample_phase=0)
    np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(ref.mask))
    np.testing.assert_array_equal(np.asarray(got.cut_counts),
                                  np.asarray(ref.cut_counts))
    # work accounting is tile-size invariant (row-level model)
    assert float(got.work_units) == float(ref.work_units)


@pytest.mark.parametrize("phase", [0, 1, 999])
def test_kernel_sample_phase_carryover(phase):
    """The monitor stride must be continuous across batch boundaries."""
    specs = pack(chain(3))
    cols = jnp.asarray(cols_for(3000, seed=3))
    got = filter_chain(cols, specs, jnp.arange(3, dtype=jnp.int32),
                       collect_rate=1000, sample_phase=phase)
    idx = [i for i in range(3000) if (i + phase) % 1000 == 0]
    assert float(got.n_monitored) == len(idx)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernel_column_dtype(dtype):
    specs = pack(chain(4))
    cols = jnp.asarray(cols_for(2048), dtype)
    got = filter_chain(cols, specs, jnp.arange(4, dtype=jnp.int32),
                       collect_rate=64, sample_phase=0)
    assert got.mask.dtype == jnp.bool_
    assert got.mask.shape == (2048,)


def test_expensive_predicate_lazy_in_kernel():
    """Tile short-circuit: with an all-cut first predicate the later
    (expensive) predicates must not change the outcome — and work counters
    must show zero active rows after position 0."""
    preds = [Predicate("cut_all", 0, P.OP_GT, 2.0, static_cost=1.0),
             Predicate("mix", 3, P.OP_HASHMIX, 0.5 * P.MIX_MOD, rounds=24,
                       static_cost=9.0)]
    specs = pack(preds)
    cols = jnp.asarray(cols_for(4096, seed=4))
    got = filter_chain(cols, specs, jnp.arange(2, dtype=jnp.int32),
                       collect_rate=1 << 20, sample_phase=1)
    assert int(got.mask.sum()) == 0
    np.testing.assert_allclose(np.asarray(got.active_before), [4096.0, 0.0])


def test_compaction_matches_boolean_indexing():
    cols = jnp.asarray(cols_for(2048, seed=5))
    specs = pack(chain(4))
    res = filter_chain(cols, specs, jnp.arange(4, dtype=jnp.int32),
                       collect_rate=128, sample_phase=0)
    packed, n = compact(cols, res.mask)
    ref = np.asarray(cols)[:, np.asarray(res.mask)]
    np.testing.assert_array_equal(np.asarray(packed)[:, :int(n)], ref)


def test_block_monitor_mode_unbiased():
    """DESIGN §3.4: block sampling must (a) keep the chain outcome identical,
    (b) sample ≈ the same fraction, (c) estimate per-predicate selectivities
    within sampling tolerance of the row-exact mode."""
    specs = pack(chain(4))
    cols = jnp.asarray(cols_for(200_000, seed=9))
    perm = jnp.arange(4, dtype=jnp.int32)
    row = filter_chain(cols, specs, perm, collect_rate=100, sample_phase=0,
                       monitor_mode="row")
    blk = filter_chain(cols, specs, perm, collect_rate=100, sample_phase=0,
                       monitor_mode="block")
    np.testing.assert_array_equal(np.asarray(row.mask), np.asarray(blk.mask))
    frac_row = float(row.n_monitored) / 200_000
    frac_blk = float(blk.n_monitored) / 200_000
    assert abs(frac_blk - frac_row) < 0.5 * frac_row
    s_row = 1 - np.asarray(row.cut_counts) / float(row.n_monitored)
    s_blk = 1 - np.asarray(blk.cut_counts) / float(blk.n_monitored)
    np.testing.assert_allclose(s_blk, s_row, atol=0.05)
