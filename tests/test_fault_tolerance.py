"""Fault tolerance: checkpoint atomicity + bit-identical restart, failure
injection, straggler reassignment, elastic reshard."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, save_checkpoint, load_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.configs import get_smoke_config
from repro.core import AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig, paper_filters_4
from repro.data.pipeline import Pipeline
from repro.data.stream import DriftConfig, LogStream
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.runtime import FailureInjector, StragglerMonitor, TrainDriver


def make_driver(tmp_path, fail_at=(), ckpt_every=5, seed=0):
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg, peak_lr=1e-3, warmup=5,
                                   total=100))
    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
        ordering=OrderingConfig(collect_rate=500, calculate_rate=100_000,
                                momentum=0.3)))
    stream = LogStream(total_rows=4_000_000, batch_rows=65536,
                       drift=DriftConfig("sine", period_rows=600_000))
    pipe = Pipeline(stream, filt, batch_size=2, seq_len=64, vocab_size=cfg.vocab)
    return TrainDriver(step_fn=step, pipeline=pipe, params=params,
                       opt_state=opt, ckpt_dir=str(tmp_path),
                       ckpt_every=ckpt_every,
                       injector=FailureInjector(fail_at))


def test_checkpoint_atomic_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 3), np.float32)}}
    save_checkpoint(tmp_path, 7, tree, extra={"k": 1})
    got, extra, step = load_checkpoint(tmp_path, tree)
    assert step == 7 and extra == {"k": 1}
    np.testing.assert_array_equal(got["a"], tree["a"])
    # a stale .tmp dir must not be picked up
    (tmp_path / "step_0000000009.tmp").mkdir()
    assert latest_step(tmp_path) == 7


def test_restart_is_bit_identical(tmp_path):
    # uninterrupted run
    d1 = make_driver(tmp_path / "a", ckpt_every=5)
    assert d1.run(15)
    # interrupted at step 8, then resumed
    d2 = make_driver(tmp_path / "b", fail_at=(8,), ckpt_every=5)
    assert not d2.run(15)                  # injected failure
    d3 = make_driver(tmp_path / "b", ckpt_every=5)
    assert d3.try_restore()
    assert d3.step == 5                    # restart from last checkpoint
    assert d3.run(15)
    np.testing.assert_array_equal(
        np.asarray(d1.history[5:], np.float32),
        np.asarray(d3.history, np.float32),
        err_msg="loss trajectory diverged after restart")
    # adaptive filter state also restored (perm part of checkpoint)
    assert d3.pipeline.last_metrics["perm"] == d1.pipeline.last_metrics["perm"]


def test_async_checkpoint(tmp_path):
    d = make_driver(tmp_path, ckpt_every=4)
    d.async_ckpt = True
    assert d.run(8)
    d.manager.wait()
    assert latest_step(tmp_path) == 8


def test_straggler_reassignment():
    mon = StragglerMonitor(n_shards=4, threshold=1.5, window=4)
    for _ in range(4):
        for s, t in enumerate([0.1, 0.1, 0.1, 0.9]):
            mon.record(s, t)
    assert mon.stragglers() == [3]
    plan = {i: list(range(i * 10, i * 10 + 10)) for i in range(4)}
    new = mon.reassign(plan)
    assert len(new[3]) == 5                       # tail stolen
    all_batches = sorted(b for v in new.values() for b in v)
    assert all_batches == sorted(b for v in plan.values() for b in v)


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on 1 device, restore with explicit (trivial) shardings — the
    N→M path; multi-device variant runs in test_multidevice_subprocess."""
    cfg = get_smoke_config("glm4-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, params)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), params)
    got, _, _ = load_checkpoint(tmp_path, params, shardings=sh)
    same = jax.tree.map(
        lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))),
        params, got)
    assert all(jax.tree.leaves(same))
