"""Fault tolerance: checkpoint atomicity + bit-identical restart, failure
injection, straggler reassignment, elastic reshard."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, save_checkpoint, load_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.configs import get_smoke_config
from repro.core import AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig, paper_filters_4
from repro.data.pipeline import Pipeline
from repro.data.stream import DriftConfig, LogStream
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.runtime import FailureInjector, StragglerMonitor, TrainDriver


def make_driver(tmp_path, fail_at=(), ckpt_every=5, seed=0):
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg, peak_lr=1e-3, warmup=5,
                                   total=100))
    filt = AdaptiveFilter(paper_filters_4("fig1"), AdaptiveFilterConfig(
        ordering=OrderingConfig(collect_rate=500, calculate_rate=100_000,
                                momentum=0.3)))
    stream = LogStream(total_rows=4_000_000, batch_rows=65536,
                       drift=DriftConfig("sine", period_rows=600_000))
    pipe = Pipeline(stream, filt, batch_size=2, seq_len=64, vocab_size=cfg.vocab)
    return TrainDriver(step_fn=step, pipeline=pipe, params=params,
                       opt_state=opt, ckpt_dir=str(tmp_path),
                       ckpt_every=ckpt_every,
                       injector=FailureInjector(fail_at))


def test_checkpoint_atomic_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 3), np.float32)}}
    save_checkpoint(tmp_path, 7, tree, extra={"k": 1})
    got, extra, step = load_checkpoint(tmp_path, tree)
    assert step == 7 and extra == {"k": 1}
    np.testing.assert_array_equal(got["a"], tree["a"])
    # a stale .tmp dir must not be picked up
    (tmp_path / "step_0000000009.tmp").mkdir()
    assert latest_step(tmp_path) == 7


def test_restart_is_bit_identical(tmp_path):
    # uninterrupted run
    d1 = make_driver(tmp_path / "a", ckpt_every=5)
    assert d1.run(15)
    # interrupted at step 8, then resumed
    d2 = make_driver(tmp_path / "b", fail_at=(8,), ckpt_every=5)
    assert not d2.run(15)                  # injected failure
    d3 = make_driver(tmp_path / "b", ckpt_every=5)
    assert d3.try_restore()
    assert d3.step == 5                    # restart from last checkpoint
    assert d3.run(15)
    np.testing.assert_array_equal(
        np.asarray(d1.history[5:], np.float32),
        np.asarray(d3.history, np.float32),
        err_msg="loss trajectory diverged after restart")
    # adaptive filter state also restored (perm part of checkpoint)
    assert d3.pipeline.last_metrics["perm"] == d1.pipeline.last_metrics["perm"]


def test_async_checkpoint(tmp_path):
    d = make_driver(tmp_path, ckpt_every=4)
    d.async_ckpt = True
    assert d.run(8)
    d.manager.wait()
    assert latest_step(tmp_path) == 8


def test_npz_crc_fallback_to_previous_step(tmp_path):
    """Storage rot on the NEWEST committed checkpoint: the manifest's
    per-array crc32 catches the flip, and an unpinned restore falls back
    to the previous committed step instead of deserializing garbage."""
    tree = {"w": np.arange(64, dtype=np.float32)}
    save_checkpoint(tmp_path, 10, tree, extra={"step": 10})
    save_checkpoint(tmp_path, 20, tree, extra={"step": 20})

    npz = tmp_path / "step_0000000020" / "shard_0.npz"
    with np.load(npz) as z:
        arrays = {k: np.array(z[k]) for k in z.files}
    key = sorted(arrays)[0]
    arrays[key].view(np.uint8)[3] ^= 0x10          # one flipped bit
    np.savez(npz, **arrays)

    got, extra, step = load_checkpoint(tmp_path, tree)   # unpinned: falls back
    assert step == 10 and extra == {"step": 10}
    np.testing.assert_array_equal(got["w"], tree["w"])
    with pytest.raises(ValueError, match="crc32 mismatch"):
        load_checkpoint(tmp_path, tree, step=20)         # pinned: fails hard


def test_npz_all_corrupt_raises(tmp_path):
    tree = {"w": np.ones(8, np.float32)}
    save_checkpoint(tmp_path, 1, tree)
    npz = tmp_path / "step_0000000001" / "shard_0.npz"
    with np.load(npz) as z:
        arrays = {k: np.array(z[k]) for k in z.files}
    next(iter(arrays.values())).view(np.uint8)[0] ^= 1
    np.savez(npz, **arrays)
    with pytest.raises(ValueError, match="failed integrity"):
        load_checkpoint(tmp_path, tree)


def test_graceful_shutdown_signal_flow():
    """First SIGINT sets the flag; a second raises KeyboardInterrupt; the
    previous handlers come back on exit."""
    import signal

    from repro.runtime import GracefulShutdown

    prev = signal.getsignal(signal.SIGINT)
    stop = GracefulShutdown()
    with stop:
        assert not stop.requested
        os.kill(os.getpid(), signal.SIGINT)
        assert stop.requested
        with pytest.raises(KeyboardInterrupt):
            os.kill(os.getpid(), signal.SIGINT)
    assert signal.getsignal(signal.SIGINT) is prev


def test_driver_flushes_checkpoint_on_shutdown(tmp_path):
    """A pending shutdown makes run() save a final checkpoint and return
    False (resumable) instead of dying mid-epoch — and the resumed driver
    picks up exactly where the flush left it."""
    import types

    d = make_driver(tmp_path, ckpt_every=100)      # never checkpoints on its own
    assert d.run(4)                                # warm up 4 steps, no ckpt yet
    assert latest_step(tmp_path) == 4              # (final save at target)
    stop = types.SimpleNamespace(requested=False)
    d2 = make_driver(tmp_path / "b", ckpt_every=100)
    assert d2.run(3, stop=stop)
    stop.requested = True
    assert not d2.run(10, stop=stop)               # flushed + returned early
    assert latest_step(tmp_path / "b") == 3
    d3 = make_driver(tmp_path / "b", ckpt_every=100)
    assert d3.try_restore() and d3.step == 3


def test_straggler_reassignment():
    mon = StragglerMonitor(n_shards=4, threshold=1.5, window=4)
    for _ in range(4):
        for s, t in enumerate([0.1, 0.1, 0.1, 0.9]):
            mon.record(s, t)
    assert mon.stragglers() == [3]
    plan = {i: list(range(i * 10, i * 10 + 10)) for i in range(4)}
    new = mon.reassign(plan)
    assert len(new[3]) == 5                       # tail stolen
    all_batches = sorted(b for v in new.values() for b in v)
    assert all_batches == sorted(b for v in plan.values() for b in v)


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on 1 device, restore with explicit (trivial) shardings — the
    N→M path; multi-device variant runs in test_multidevice_subprocess."""
    cfg = get_smoke_config("glm4-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, params)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), params)
    got, _, _ = load_checkpoint(tmp_path, params, shardings=sh)
    same = jax.tree.map(
        lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))),
        params, got)
    assert all(jax.tree.leaves(same))
