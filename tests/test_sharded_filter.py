"""ShardedAdaptiveFilter: scope semantics under real shard_map + device-side
compaction.

Fast cases run in-process on an explicit 1-device mesh (shard_map is live,
just unreplicated). The 4-device cases fork a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest process
keeps seeing exactly 1 device (contract §MULTI-POD 0); they are ``slow``
tier and also run in CI's dedicated sharded job.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


# ================================================================= fast tier
def _one_device_filter(cfg):
    import jax

    from repro.core import ShardedAdaptiveFilter, paper_filters_4
    mesh = jax.make_mesh((1,), ("data",))
    return ShardedAdaptiveFilter(paper_filters_4("fig1"), cfg, mesh=mesh)


def test_sharded_one_device_matches_unsharded():
    """A 1-shard mesh is the degenerate case: identical mask, perm, state."""
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4, shard_slice)
    from repro.data.stream import gen_batch

    cfg = AdaptiveFilterConfig(ordering=OrderingConfig(collect_rate=100,
                                                       calculate_rate=4000))
    sharded = _one_device_filter(cfg)
    ref = AdaptiveFilter(paper_filters_4("fig1"), cfg)
    sstate, rstate = sharded.init_state(), ref.init_state()
    for b in range(3):
        cols = jnp.asarray(gen_batch(0, b, b * 8192, 8192))
        sstate, smask, smet = sharded.jit_step(sstate, cols)
        rstate, rmask, rmet = ref.jit_step(rstate, cols)
        assert np.array_equal(np.asarray(smask), np.asarray(rmask))
        assert np.array_equal(np.asarray(smet.perm)[0], np.asarray(rmet.perm))
    final = shard_slice(sstate, 0)
    assert np.array_equal(np.asarray(final.perm), np.asarray(rstate.perm))
    np.testing.assert_allclose(np.asarray(final.adj_rank),
                               np.asarray(rstate.adj_rank), rtol=1e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_compact_output_matches_boolean_mask(backend):
    """compact_output=True: padded on-device survivors are bit-identical
    (up to padding) to the host boolean-mask path — for BOTH traceable
    engines, which share the same compaction gather."""
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4)
    from repro.data.stream import gen_batch

    ordering = OrderingConfig(collect_rate=100, calculate_rate=5000)
    filt = AdaptiveFilter(paper_filters_4("fig1"),
                          AdaptiveFilterConfig(backend=backend,
                                               compact_output=True,
                                               ordering=ordering))
    state = filt.init_state()
    cols = jnp.asarray(gen_batch(0, 0, 0, 4096))
    _, packed, n_kept, mask, _ = filt._jit_compact(state, cols)
    _, mask_ref, _ = filt.jit_step(state, cols)

    assert np.array_equal(np.asarray(mask), np.asarray(mask_ref))
    n = int(n_kept)
    host_path = np.asarray(cols)[:, np.asarray(mask_ref)]
    assert np.array_equal(np.asarray(packed)[:, :n], host_path)
    assert np.all(np.asarray(packed)[:, n:] == 0.0)     # padding is fill


def test_compact_capacity_saturates():
    from repro.core import AdaptiveFilter, AdaptiveFilterConfig, \
        paper_filters_4
    from repro.data.stream import gen_batch
    import jax.numpy as jnp

    filt = AdaptiveFilter(paper_filters_4("fig1"),
                          AdaptiveFilterConfig(compact_output=True,
                                               compact_capacity=8))
    _, packed, n_kept, mask, _ = filt._jit_compact(
        filt.init_state(), jnp.asarray(gen_batch(0, 0, 0, 4096)))
    assert packed.shape[1] == 8
    assert int(n_kept) == 8                     # > 8 survivors → saturates
    first8 = np.asarray(gen_batch(0, 0, 0, 4096))[:, np.asarray(mask)][:, :8]
    assert np.array_equal(np.asarray(packed), first8)


def test_compact_output_flag_validation():
    """The flag is wired: host engines reject it, capacity needs the flag."""
    from repro.core import AdaptiveFilterConfig

    with pytest.raises(ValueError, match="compact_output"):
        AdaptiveFilterConfig(backend="numpy", compact_output=True,
                             cost_mode="measured")
    with pytest.raises(ValueError, match="compact_capacity"):
        AdaptiveFilterConfig(compact_capacity=16)
    with pytest.raises(ValueError, match="compact_capacity"):
        AdaptiveFilterConfig(compact_output=True, compact_capacity=0)


def test_per_batch_scope_preserves_sample_phase_and_epoch():
    """PER_BATCH resets *evidence* per batch, not the monitor stride or the
    re-rank counter: sample_phase must walk through the stream (same offsets
    as any other scope) and epoch must accumulate across batches."""
    import jax.numpy as jnp

    from repro.core import (AdaptiveFilter, AdaptiveFilterConfig,
                            OrderingConfig, paper_filters_4)
    from repro.data.stream import gen_batch

    n_rows, collect = 256, 100
    mk = lambda scope: AdaptiveFilter(paper_filters_4("fig1"),
                                      AdaptiveFilterConfig(
        scope=scope,
        ordering=OrderingConfig(collect_rate=collect, calculate_rate=200)))
    pb, ps = mk("per_batch"), mk("per_shard")
    pb_state, ps_state = pb.init_state(), ps.init_state()
    for b in range(4):
        cols = jnp.asarray(gen_batch(0, b, b * n_rows, n_rows))
        pb_state, _, pb_met = pb.jit_step(pb_state, cols)
        ps_state, _, _ = ps.jit_step(ps_state, cols)
        # stride position identical across scopes — the global row offset
        assert int(pb_state.sample_phase) == int(ps_state.sample_phase) \
            == ((b + 1) * n_rows) % collect
    assert int(pb_met.epoch) == 4               # one re-rank per 256-row batch


def test_sharded_rejects_host_backend():
    from repro.core import AdaptiveFilterConfig

    with pytest.raises(ValueError, match="host engine"):
        _one_device_filter(AdaptiveFilterConfig(backend="numpy",
                                                cost_mode="measured"))


# ============================================================ slow, 4 devices
_HETERO_PRELUDE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import (AdaptiveFilterConfig, OrderingConfig,
                            ShardedAdaptiveFilter)
    from repro.core.predicates import OP_GT, Predicate

    preds = [Predicate(f"c{i}", i, OP_GT, 0.5, static_cost=1.0)
             for i in range(3)]
    R = 4096
    ordering = OrderingConfig(collect_rate=10, calculate_rate=2000)

    def shard_cols(shard):
        # heterogeneous per-shard drift: shard i's column (i % 3) cuts
        # everything, the others pass everything — each shard has a
        # different optimal front-runner
        cols = np.full((3, R), 1.0, np.float32)
        cols[shard % 3] = 0.0
        return cols

    cols = jnp.asarray(np.concatenate([shard_cols(s) for s in range(4)],
                                      axis=1))

    def run(scope, steps=3):
        sf = ShardedAdaptiveFilter(preds, AdaptiveFilterConfig(
            scope=scope, ordering=ordering))
        st = sf.init_state()
        for _ in range(steps):
            st, mask, met = sf.jit_step(st, cols)
        return sf, st, np.asarray(met.perm), np.asarray(met.epoch)
""")


@pytest.mark.slow
def test_per_shard_diverges_centralized_converges():
    """Paper §2.2 executed: under heterogeneous per-shard drift the
    PER_SHARD states adapt to their own slice (divergent perms, each led by
    its shard's best cutter) while CENTRALIZED psum-merges the epoch stats
    so every shard adopts one identical global order."""
    out = run_py(_HETERO_PRELUDE + textwrap.dedent("""
        sf, st, perms, epochs = run("per_shard")
        assert (epochs > 0).all(), epochs
        # every shard leads with its own cutter...
        for s in range(4):
            assert perms[s][0] == s % 3, (s, perms[s])
        # ...and shards with different cutters genuinely diverge
        assert len({tuple(p) for p in perms}) == 3, perms

        sf, st, perms, epochs = run("centralized")
        assert (epochs > 0).all(), epochs
        assert len({tuple(p) for p in perms}) == 1, perms
        print("SCOPES-OK")
    """))
    assert "SCOPES-OK" in out


@pytest.mark.slow
def test_per_shard_hlo_has_no_collectives():
    """PER_SHARD ⇒ zero network traffic, machine-checked on the compiled
    HLO; CENTRALIZED must show the stat all-reduce. Pinned through the
    shared auditor (``repro.analysis.hlo_audit``): the plan's scope tells
    the auditor whether collectives must be absent or present, so this
    test and the CI ``analysis`` job enforce the identical contract."""
    out = run_py(textwrap.dedent("""
        from repro.analysis import audit_plan, audit_step_text, errors
        from repro.core import (FilterPlan, OrderingConfig, build_session,
                                paper_filters_4)

        ordering = OrderingConfig(collect_rate=10, calculate_rate=2000)
        for scope in ("per_shard", "per_batch", "centralized"):
            plan = FilterPlan(predicates=paper_filters_4("fig1"),
                              scope=scope, shards=4, ordering=ordering)
            diags = audit_plan(plan)
            assert not errors(diags), (scope, [d.render() for d in diags])
        # cross-audit proves the checks bite: the eager CENTRALIZED step
        # (which legitimately carries the all-reduce) must FAIL the
        # PER_SHARD collective-free contract
        import jax.numpy as jnp
        import numpy as np
        cent = FilterPlan(predicates=paper_filters_4("fig1"),
                          scope="centralized", shards=4, ordering=ordering)
        session = build_session(cent)
        cols = jnp.asarray(np.random.default_rng(0).uniform(
            -64, 64, (4, 4096 * 4)).astype(np.float32))
        txt = session.compiled_step_text(session.init_state(), cols)
        per_shard = FilterPlan(predicates=paper_filters_4("fig1"),
                               scope="per_shard", shards=4,
                               ordering=ordering)
        found = audit_step_text(txt, per_shard, num_shards=4)
        assert [d.code for d in found] == ["hlo-step-collective"], found
        print("HLO-OK")
    """))
    assert "HLO-OK" in out


@pytest.mark.slow
def test_sharded_compaction_and_pipeline_roundtrip_4dev():
    """4-shard ingestion: compacted survivors == mask-path survivors, and
    the sharded checkpoint restores to a bit-identical batch stream."""
    out = run_py("""
        import jax, numpy as np
        from repro.core import (AdaptiveFilterConfig, OrderingConfig,
                                ShardedAdaptiveFilter, paper_filters_4)
        from repro.core.session import FilterSession
        from repro.data.pipeline import make_pipeline
        from repro.data.stream import DriftConfig

        ordering = OrderingConfig(collect_rate=100, calculate_rate=50_000)
        drift = DriftConfig(kind="regime", period_rows=300_000)
        mesh = jax.make_mesh((4,), ("data",))

        def mk(compact):
            cfg = AdaptiveFilterConfig(scope="centralized", ordering=ordering,
                                       compact_output=compact)
            filt = ShardedAdaptiveFilter(paper_filters_4("fig1"), cfg,
                                         mesh=mesh)
            return make_pipeline(
                FilterSession.from_filter(filt), total_rows=1_048_576,
                batch_rows=65536, batch_size=4, seq_len=64, vocab_size=1000,
                drift=drift)

        pipe = mk(compact=True)
        it = iter(pipe)
        head = [next(it) for _ in range(3)]
        ckpt = pipe.state()
        tail = [next(it) for _ in range(3)]

        # compacted path == boolean-mask path, bit-identical LM batches
        plain = [b for _, b in zip(range(3), iter(mk(compact=False)))]
        for a, b in zip(head, plain):
            assert np.array_equal(a["tokens"], b["tokens"])

        # checkpoint round-trip: fresh pipeline resumes bit-identically
        pipe2 = mk(compact=True)
        pipe2.restore(ckpt)
        got = [b for _, b in zip(range(3), iter(pipe2))]
        for a, b in zip(tail, got):
            assert np.array_equal(a["tokens"], b["tokens"])
            assert np.array_equal(a["labels"], b["labels"])
        print("PIPE-OK")
    """)
    assert "PIPE-OK" in out
