"""Backend comparison: numpy row-exact vs jnp masked vs Pallas fused kernel.

CPU wall times for the jitted paths; the Pallas number is interpret-mode
(correctness harness, not perf — the kernel's TPU perf story is the bytes
model in EXPERIMENTS §Perf: one HBM pass instead of P)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveFilter, AdaptiveFilterConfig, OrderingConfig,
                        pack, paper_filters_4)
from repro.core import filter_exec, np_exec
from repro.data.stream import gen_batch


def main(rows: int = 262_144) -> None:
    preds = paper_filters_4("fig1")
    specs = pack(preds)
    cols_np = gen_batch(0, 0, 0, rows)
    cols = jnp.asarray(cols_np)
    perm = jnp.arange(4, dtype=jnp.int32)

    # numpy row-exact (compacted short-circuit)
    t0 = time.perf_counter()
    np_exec.run_chain_np(cols_np, preds, np.arange(4))
    t_np = time.perf_counter() - t0
    print(f"backends/numpy_compacted,{t_np*1e6/rows:.4f},row-exact")

    # jnp masked (jitted, vectorized)
    f = jax.jit(lambda c: filter_exec.run_chain(
        c, specs, perm, collect_rate=1000, sample_phase=0))
    f(cols).mask.block_until_ready()
    t0 = time.perf_counter()
    f(cols).mask.block_until_ready()
    t_jnp = time.perf_counter() - t0
    print(f"backends/jnp_masked,{t_jnp*1e6/rows:.4f},vectorized")

    # pallas fused (interpret mode on CPU)
    from repro.kernels.filter_chain.ops import filter_chain
    g = jax.jit(lambda c: filter_chain(
        c, specs, perm, collect_rate=1000, sample_phase=0))
    g(cols).mask.block_until_ready()
    t0 = time.perf_counter()
    g(cols).mask.block_until_ready()
    t_pl = time.perf_counter() - t0
    print(f"backends/pallas_interpret,{t_pl*1e6/rows:.4f},correctness-mode")

    # modeled TPU HBM traffic: unfused P passes vs fused single pass
    c_bytes = 3 * 4  # f32 columns per row
    unfused = (len(preds) + 1) * c_bytes   # read per predicate + mask write
    fused = c_bytes + 1
    print(f"backends/model_bytes_per_row,{0:.4f},"
          f"unfused={unfused}B fused={fused}B ({unfused/fused:.1f}x)")


if __name__ == "__main__":
    main()
