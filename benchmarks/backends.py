"""Backend comparison driven through the FilterEngine registry: every
registered engine runs the same paper chain through the same ABI.

CPU wall times for the jitted paths; the Pallas number is interpret-mode
(correctness harness, not perf — the kernel's TPU perf story is the bytes
model in EXPERIMENTS §Perf: one HBM pass instead of P)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MonitorSpec, available_engines, get_engine, pack,
                        paper_filters_4)


def main(rows: int = 262_144) -> None:
    preds = paper_filters_4("fig1")
    specs = pack(preds)
    from repro.data.stream import gen_batch
    cols_np = gen_batch(0, 0, 0, rows)
    cols = jnp.asarray(cols_np)
    perm = jnp.arange(4, dtype=jnp.int32)
    mon = MonitorSpec(collect_rate=1000, sample_phase=0)

    for name in available_engines():
        eng = get_engine(name)
        if eng.traceable:
            f = jax.jit(lambda c, e=eng: e.run_chain(c, specs, perm, mon))
            f(cols).mask.block_until_ready()          # compile
            t0 = time.perf_counter()
            f(cols).mask.block_until_ready()
            note = "vectorized" if name == "jnp" else "correctness-mode"
        else:
            t0 = time.perf_counter()
            eng.run_chain(cols_np, specs, np.asarray(perm), mon)
            note = "row-exact"
        dt = time.perf_counter() - t0
        print(f"backends/{name},{dt*1e6/rows:.4f},{note}")

    # modeled TPU HBM traffic: unfused P passes vs fused single pass
    c_bytes = 3 * 4  # f32 columns per row
    unfused = (len(preds) + 1) * c_bytes   # read per predicate + mask write
    fused = c_bytes + 1
    print(f"backends/model_bytes_per_row,{0:.4f},"
          f"unfused={unfused}B fused={fused}B ({unfused/fused:.1f}x)")


if __name__ == "__main__":
    main()
