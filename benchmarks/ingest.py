"""End-to-end ingestion sweep: µs/row of filter → compact → stats exchange.

The single-pass-ingestion perf baseline (ISSUE 3 acceptance + the
``bench-smoke`` CI gate). One timed cell per

    compaction ∈ {mask, argsort, fused}  ×  engine ∈ {jnp, pallas}
    scope/exchange ∈ {per_shard, centralized-eager, centralized-deferred,
                      centralized-deferred-async}   (sharded step)

where the compaction modes are:

  mask     — jitted chain only; survivors leave via the host boolean index
             (the pre-compaction baseline).
  argsort  — chain + the legacy O(R log R) ``compact_fixed_argsort``
             stable-sort gather (what ``compact_fixed`` used to be).
  fused    — the single-pass path: O(R) cumsum scatter on the jnp engine,
             in-kernel tile pack + offset-stitch gather launch on pallas.

plus the tile-statistics skip-tier sweep (jnp engine):

    layout ∈ {clustered, zordered, shuffled}  ×  skip_tier ∈ {off, zonemap}

where layout is the physical row order of the stream (``--layout`` pins
one; default sweeps all three) — clustered/zordered tiles mostly resolve
under zone maps and the row-level chain runs only on the ambiguous
remainder; shuffled resolves nothing and measures the triage overhead
alone.

Emits the CSV contract rows ``name,us_per_call,derived`` (us_per_call =
µs/row) and writes ``BENCH_ingest.json`` next to this file so the perf
trajectory has a machine-readable baseline:

  {"cells": [...], "derived": {"speedup_fused_vs_argsort_jnp": ...}}

``--smoke`` shrinks the sweep for CI (CPU, interpret-mode pallas) and FAILS
(exit 1) if (a) the fused path is slower than the unfused (argsort) path by
more than 1.15× on the jnp engine — the "adaptive-primitive overhead must
stay in the noise" regression gate — or (b) the clustered-layout
``skip_tier=zonemap`` cell is not ≥ 1.3× faster end-to-end than ``off``
(the skip-tier acceptance gate).

Usage:
  PYTHONPATH=src python benchmarks/ingest.py
  PYTHONPATH=src python benchmarks/ingest.py --smoke
  PYTHONPATH=src python benchmarks/ingest.py --devices 4   # sharded cells
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_ingest.json"


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="forced host-platform device count for the "
                         "scope/exchange cells (set before jax import); "
                         "0 = visible devices as-is")
    ap.add_argument("--batch-rows", type=int, default=65536)
    ap.add_argument("--steps", type=int, default=12,
                    help="timed steps per cell (after one compile call)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="compaction width (default: batch width)")
    ap.add_argument("--layout", default=None,
                    choices=("clustered", "zordered", "shuffled"),
                    help="pin the skip-tier sweep to one stream layout "
                         "(default: all three)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep + fused-vs-unfused regression gate")
    ap.add_argument("--out", default=str(OUT))
    return ap.parse_args()


def time_step(fn, state, cols, steps, repeats: int = 3,
              thread_state: bool = False):
    """Best-of-``repeats`` timing blocks (min is the standard noise-robust
    estimator for a shared-CPU bench; one warm block absorbs compilation).

    ``thread_state=True`` feeds each call the previous call's new state, so
    stateful cadences (epoch boundaries, deferred exchanges) actually fire
    during the timed window instead of being pinned to step 1's offsets.
    """
    import jax

    out = fn(state, cols)                      # compile + warm
    jax.block_until_ready(out)
    if thread_state:
        state = out[0]
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(steps):
            out = fn(state, cols)
            jax.block_until_ready(out)
            if thread_state:
                state = out[0]
        best = min(best, (time.perf_counter() - t0) / steps)
    return best


def bench_compaction(args, results):
    """compaction × engine cells, every mode driven through ONE
    ``session.step`` (the argsort cell composes the legacy gather onto the
    plain step — it benches a path the session no longer emits)."""
    import jax.numpy as jnp

    from repro.core import FilterPlan, OrderingConfig, build_session, \
        paper_filters_4
    from repro.core import filter_exec
    from repro.data.stream import gen_batch

    rows = args.batch_rows
    cap = args.capacity or rows
    ordering = OrderingConfig(collect_rate=1000, calculate_rate=10 * rows)
    cols = jnp.asarray(gen_batch(0, 0, 0, rows))
    ratios = {}

    for engine in ("jnp", "pallas"):
        cells = {}
        for mode in ("mask", "argsort", "fused"):
            session = build_session(FilterPlan(
                predicates=paper_filters_4("fig1"), engine=engine,
                ordering=ordering, compact=(mode == "fused"),
                capacity=cap if mode == "fused" else None))
            state = session.init_state()
            if mode == "argsort":
                import jax

                filt = session.filter

                def legacy(s, c):
                    s2, mask, met = filt.step(s, c)
                    packed, n_kept = filter_exec.compact_fixed_argsort(
                        c, mask, cap)
                    return s2, packed, n_kept, mask, met
                jit_legacy = jax.jit(legacy)

                def fn(s, c, _f=filt, _j=jit_legacy):
                    # pay the SAME per-call host driving session.step pays
                    # (asarray, capacity resolve, exchange check, retune
                    # hook) so the gated ratio compares kernels, not
                    # dispatch overhead
                    c = jnp.asarray(c, jnp.float32)
                    _f.resolve_capacity(int(c.shape[1]))
                    out = _j(s, c)
                    s2 = _f.maybe_exchange(out[0])
                    _f.observe_for_capacity(s, s2, int(c.shape[1]))
                    return (s2,) + out[1:]
            else:
                fn = session.step
            sec = time_step(fn, state, cols, args.steps)
            us_row = sec * 1e6 / rows
            cells[mode] = us_row
            name = f"ingest/{engine}/{mode}"
            derived = f"engine={engine};compaction={mode};rows={rows};cap={cap}"
            print(f"{name},{us_row:.4f},{derived}", flush=True)
            results.append({"name": name, "engine": engine,
                            "compaction": mode, "rows": rows,
                            "capacity": cap, "us_per_row": us_row})
        ratios[engine] = cells["argsort"] / cells["fused"]
    return ratios


def bench_skip_tier(args, results):
    """layout × skip_tier cells on the jnp engine, through ``session.step``
    (triage + gather + chain + the per-step host sync all inside the timed
    window — the end-to-end number the acceptance ratio gates)."""
    import jax.numpy as jnp

    from repro.core import FilterPlan, OrderingConfig, build_session, \
        paper_filters_4
    from repro.data.stream import gen_batch

    # full-width batches even under --smoke: the tier's win is compute
    # skipped per dispatch, and at tiny widths the per-step host sync
    # (ambiguous-count readback) dominates both arms equally, squeezing
    # the gated ratio into noise
    rows = max(args.batch_rows, 65536)
    ordering = OrderingConfig(collect_rate=1000, calculate_rate=10 * rows)
    layouts = (args.layout,) if args.layout else \
        ("clustered", "zordered", "shuffled")
    ratios = {}
    for layout in layouts:
        cols = jnp.asarray(gen_batch(0, 0, 0, rows, layout=layout))
        cells = {}
        for tier in ("off", "zonemap"):
            session = build_session(FilterPlan(
                predicates=paper_filters_4("fig1"), engine="jnp",
                ordering=ordering, skip_tier=tier))
            state = session.init_state()
            sec = time_step(session.step, state, cols, args.steps)
            us_row = sec * 1e6 / rows
            cells[tier] = us_row
            name = f"ingest/skip/{layout}/{tier}"
            derived = f"engine=jnp;layout={layout};skip_tier={tier};rows={rows}"
            print(f"{name},{us_row:.4f},{derived}", flush=True)
            results.append({"name": name, "engine": "jnp", "layout": layout,
                            "skip_tier": tier, "rows": rows,
                            "us_per_row": us_row})
        ratios[layout] = cells["off"] / cells["zonemap"]
    return ratios


def bench_scopes(args, results):
    """scope × exchange cells through the sharded step, state threaded so
    epoch boundaries — and therefore the deferred exchange collective —
    genuinely fire inside the timed window (one per 4 steps here; the
    exchange cost is amortized into the µs/row like production would)."""
    import jax
    import jax.numpy as jnp

    from repro.core import FilterPlan, OrderingConfig, build_session, \
        paper_filters_4
    from repro.data.stream import gen_batch

    n_dev = jax.device_count()
    rows = args.batch_rows
    ordering = OrderingConfig(collect_rate=1000, calculate_rate=4 * rows)
    mesh = jax.make_mesh((n_dev,), ("data",))
    cols = jnp.asarray(gen_batch(0, 0, 0, rows * n_dev))

    cases = [("per_shard", "eager"), ("centralized", "eager"),
             ("centralized", "deferred"), ("centralized", "deferred-async")]
    for scope, exchange in cases:
        session = build_session(FilterPlan(
            predicates=paper_filters_4("fig1"), scope=scope,
            exchange=exchange, ordering=ordering, shards=n_dev), mesh=mesh)
        state = session.init_state()
        # session.step drives the deferred exchange internally — no
        # per-mode driving code in the bench anymore
        sec = time_step(session.step, state, cols, args.steps,
                        thread_state=True)
        us_row = sec * 1e6 / (rows * n_dev)
        tag = scope if exchange == "eager" else f"{scope}-{exchange}"
        name = f"ingest/sharded{n_dev}/{tag}"
        derived = f"shards={n_dev};scope={scope};exchange={exchange};rows={rows}"
        print(f"{name},{us_row:.4f},{derived}", flush=True)
        results.append({"name": name, "shards": n_dev, "scope": scope,
                        "exchange": exchange, "rows": rows,
                        "us_per_row": us_row})


def main():
    args = parse_args()
    if args.devices:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}")
    if args.smoke:
        args.batch_rows = min(args.batch_rows, 16384)
        args.steps = min(args.steps, 5)

    results: list[dict] = []
    ratios = bench_compaction(args, results)
    skip_ratios = bench_skip_tier(args, results)
    bench_scopes(args, results)

    import jax

    derived = {f"speedup_fused_vs_argsort_{k}": v for k, v in ratios.items()}
    derived |= {f"speedup_skip_zonemap_{k}": v
                for k, v in skip_ratios.items()}
    payload = {"rows": args.batch_rows, "steps": args.steps,
               "smoke": bool(args.smoke), "backend": jax.default_backend(),
               "note": ("pallas cells run in interpret mode off-TPU: a "
                        "correctness path, not perf-representative — the "
                        "regression gate and the acceptance ratio target "
                        "the jnp engine"),
               "cells": results, "derived": derived}
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2))
    for k, v in derived.items():
        print(f"# {k} = {v:.3f}x")
    print(f"# wrote {args.out}")

    if args.smoke and ratios["jnp"] < 1 / 1.15:
        print(f"# FAIL: fused compaction {1 / ratios['jnp']:.2f}x slower "
              "than the unfused (argsort) path on the jnp engine "
              "(gate: 1.15x)", file=sys.stderr)
        return 1
    if args.smoke and skip_ratios.get("clustered", 1.3) < 1.3:
        print(f"# FAIL: clustered-layout skip_tier=zonemap is only "
              f"{skip_ratios['clustered']:.2f}x over off on the jnp engine "
              "(acceptance gate: 1.3x)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
