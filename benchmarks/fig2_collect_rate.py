"""Figure 2: impact of collectRate (sensitivity chain, 16.14% selectivity).

Expected U-shape: tiny collectRate → monitoring overhead dominates (every
row pays the full-chain evaluation); huge collectRate → too little evidence
per epoch, ordering lags the drift."""

from __future__ import annotations

from repro.core import OrderingConfig, paper_filters_4
from repro.data.stream import DriftConfig

from benchmarks.common import BENCH_ROWS, emit, run_workload

SWEEP = (10, 100, 1000, 10_000, 100_000)


def main() -> dict:
    preds = paper_filters_4("sens")
    drift = DriftConfig(kind="regime", period_rows=500_000, amplitude=1.5)
    out = {}
    for cr in SWEEP:
        ordering = OrderingConfig(collect_rate=cr,
                                  calculate_rate=max(BENCH_ROWS // 15, 50_000),
                                  momentum=0.3)
        res = run_workload(preds, adaptive=True, ordering=ordering,
                           drift=drift)
        # total cost = chain work + monitor work (all preds on sampled rows)
        monitor_work = sum(p.static_cost for p in preds) * res["rows"] / cr
        total = res["work_units"] + monitor_work
        out[cr] = {**res, "total_work": total}
        emit(f"fig2/collect_rate_{cr}", res,
             derived=f"total_work={total:.0f}")
    return out


if __name__ == "__main__":
    main()
